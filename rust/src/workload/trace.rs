//! Demand timelines: a workload that *changes over time*.
//!
//! The paper's motivating scenario (§1) is bursty demand — "the
//! analysis is needed occasionally (e.g., during emergencies)" — which
//! a single static [`Workload`](super::Workload) cannot express.  A
//! [`WorkloadTrace`] is an ordered sequence of [`Epoch`]s, each holding
//! the stream set in force for a duration; the autoscaling runner
//! (`coordinator::autoscale`) re-plans at every epoch boundary and
//! carries the provisioned fleet across them under started-hour
//! billing, so churn has the same price it has on a real cloud bill
//! (see the module docs of [`cloud::billing`](crate::cloud::billing)).
//!
//! Three composable builtin generators cover the demand shapes of the
//! related provisioning literature (crowdsourced live streaming,
//! on-demand video cost minimization):
//!
//! * [`WorkloadTrace::emergency_burst`] — quiet monitoring, a
//!   high-rate emergency burst, recovery (the paper's Houston-flood
//!   motivation, Fig. 1d);
//! * [`WorkloadTrace::diurnal`] — a 24-hour rate curve over a fixed
//!   camera fleet (day/night demand);
//! * [`WorkloadTrace::camera_churn`] — the camera population itself
//!   grows and shrinks epoch to epoch.
//!
//! Traces serialize to JSON (`util::json`) in the same row shape as
//! scenario configs, so hand-written demand curves load from disk via
//! [`WorkloadTrace::load`] and builtins can be exported with
//! [`WorkloadTrace::save`] and edited.

use super::{FleetSpec, Workload};
use crate::cloud::{Catalog, PricingModel, PricingTier, TierSpec};
use crate::config::{catalog_from_json, pricing_to_json, stream_rows_from_json, stream_to_json};
use crate::streams::{Camera, StreamSpec};
use crate::types::{Program, VGA};
use crate::util::error::{anyhow, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::path::Path;

/// A spot-market capacity reclaim inside an epoch: at `at_s` seconds
/// into the epoch, the provider revokes `fraction` of the then-running
/// spot instances.  On-demand and reserved instances are never touched.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Revocation {
    /// Offset into the epoch, seconds (`0 <= at_s <= duration_s`).
    pub at_s: f64,
    /// Fraction of running spot instances reclaimed, in `[0, 1]`.
    pub fraction: f64,
}

/// One epoch of a demand timeline: the streams in force for a span.
#[derive(Clone, Debug)]
pub struct Epoch {
    pub label: String,
    /// How long this demand holds, in simulated seconds (> 0).
    pub duration_s: f64,
    pub streams: Vec<StreamSpec>,
    /// Seeded spot-revocation events inside this epoch (usually empty;
    /// see the `spot` builtin).
    pub revocations: Vec<Revocation>,
}

/// A named demand timeline over one catalog.
#[derive(Clone, Debug)]
pub struct WorkloadTrace {
    pub name: String,
    pub catalog: Catalog,
    pub epochs: Vec<Epoch>,
}

impl WorkloadTrace {
    pub fn new(name: impl Into<String>, catalog: Catalog) -> WorkloadTrace {
        WorkloadTrace { name: name.into(), catalog, epochs: Vec::new() }
    }

    /// Append an epoch (builder style).
    pub fn epoch(
        mut self,
        label: impl Into<String>,
        duration_s: f64,
        streams: Vec<StreamSpec>,
    ) -> WorkloadTrace {
        assert!(duration_s > 0.0, "epoch duration must be positive");
        self.epochs.push(Epoch {
            label: label.into(),
            duration_s,
            streams,
            revocations: Vec::new(),
        });
        self
    }

    /// Append an epoch carrying spot-revocation events (builder style).
    pub fn epoch_with_revocations(
        mut self,
        label: impl Into<String>,
        duration_s: f64,
        streams: Vec<StreamSpec>,
        revocations: Vec<Revocation>,
    ) -> WorkloadTrace {
        assert!(duration_s > 0.0, "epoch duration must be positive");
        for r in &revocations {
            assert!(
                (0.0..=duration_s).contains(&r.at_s) && (0.0..=1.0).contains(&r.fraction),
                "revocation out of range"
            );
        }
        self.epochs.push(Epoch { label: label.into(), duration_s, streams, revocations });
        self
    }

    /// Total simulated duration across all epochs.
    pub fn total_duration_s(&self) -> f64 {
        self.epochs.iter().map(|e| e.duration_s).sum()
    }

    /// Start time (seconds) of epoch `index`.
    pub fn start_of(&self, index: usize) -> f64 {
        self.epochs[..index].iter().map(|e| e.duration_s).sum()
    }

    /// Epoch `index` as a pipeline [`Workload`].
    pub fn workload(&self, index: usize) -> Workload {
        let epoch = &self.epochs[index];
        Workload::new(
            format!("{}/{}", self.name, epoch.label),
            epoch.streams.clone(),
            self.catalog.clone(),
        )
    }

    /// Default fleet sizes of the parameterized builtins (shared with
    /// the CLI so `--trace churn` means the same thing everywhere).
    pub const DIURNAL_CAMERAS: u32 = 32;
    pub const CHURN_CAMERAS: u32 = 40;
    pub const CHURN_EPOCHS: usize = 8;
    /// Discrete rate levels of the churn pool (see [`FleetSpec::rate_levels`]).
    pub const CHURN_RATE_LEVELS: u32 = 6;

    /// Resolve a builtin generator by name (the CLI's `--trace` values).
    pub fn builtin(name: &str, seed: u64) -> Result<WorkloadTrace> {
        match name {
            "emergency" | "emergency-burst" => Ok(WorkloadTrace::emergency_burst(seed)),
            "diurnal" => Ok(WorkloadTrace::diurnal(Self::DIURNAL_CAMERAS, seed)),
            "churn" => Ok(WorkloadTrace::camera_churn(
                Self::CHURN_CAMERAS,
                Self::CHURN_EPOCHS,
                seed,
            )),
            "spot" | "spot-market" => Ok(WorkloadTrace::spot_market(seed)),
            other => Err(anyhow!(
                "unknown builtin trace {other:?} (expected emergency, diurnal, churn, or spot)"
            )),
        }
    }

    /// The paper's motivating shape: quiet monitoring of a few
    /// flood-prone intersections, a one-hour emergency burst across the
    /// whole camera network, then recovery back to quiet.
    ///
    /// The seed jitters per-stream rates inside ranges chosen so the
    /// *plan shape* stays put (normal epochs solve to one CPU instance,
    /// the burst to two GPU instances on the paper's two-type catalog):
    /// costs are reproducible per seed while the streams differ.
    pub fn emergency_burst(seed: u64) -> WorkloadTrace {
        let mut rng = Rng::new(seed);
        let normal = |rng: &mut Rng| -> Vec<StreamSpec> {
            (0..3)
                .map(|i| {
                    StreamSpec::new(
                        Camera::new(i, VGA),
                        Program::Zf,
                        rng.range_f64(0.15, 0.25),
                    )
                })
                .collect()
        };
        let quiet = normal(&mut rng);
        let burst: Vec<StreamSpec> = (0..10)
            .map(|i| {
                StreamSpec::new(
                    Camera::new(100 + i, VGA),
                    Program::Zf,
                    rng.range_f64(0.9, 1.1),
                )
            })
            .collect();
        let recovery = normal(&mut rng);
        WorkloadTrace::new(format!("emergency-{seed}"), Catalog::paper_experiments())
            .epoch("normal", 5400.0, quiet)
            .epoch("emergency", 3600.0, burst)
            .epoch("recovery", 5400.0, recovery)
    }

    /// A 24-hour diurnal rate curve over a fixed synthetic fleet: every
    /// stream's desired rate is the fleet baseline scaled by a smooth
    /// day/night multiplier in `[0.25, 1.0]` (trough at midnight, peak
    /// mid-afternoon).  Scaling never exceeds the baseline, so every
    /// epoch stays allocatable wherever the baseline fleet is.
    pub fn diurnal(cameras: u32, seed: u64) -> WorkloadTrace {
        let base = FleetSpec::new(cameras).seed(seed).build();
        let mut trace =
            WorkloadTrace::new(format!("diurnal-{seed}-{cameras}"), base.catalog.clone());
        for hour in 0..24u32 {
            // Peak at 15:00, trough at 03:00.
            let phase = (hour as f64 - 15.0) / 24.0 * std::f64::consts::TAU;
            let mult = 0.25 + 0.75 * (0.5 + 0.5 * phase.cos());
            let streams: Vec<StreamSpec> = base
                .streams
                .iter()
                .map(|s| {
                    let mut s2 = s.clone();
                    s2.desired_fps *= mult;
                    s2
                })
                .collect();
            trace = trace.epoch(format!("h{hour:02}"), 3600.0, streams);
        }
        trace
    }

    /// Camera churn: the population itself walks up and down around
    /// `cameras` across `epochs` half-hour epochs (between 50% and 200%
    /// of the base).  Stream identities are stable prefixes of one
    /// seeded fleet, mirroring cameras joining and leaving a registry.
    pub fn camera_churn(cameras: u32, epochs: usize, seed: u64) -> WorkloadTrace {
        assert!(cameras > 0, "churn needs a base camera count");
        let mut rng = Rng::new(seed ^ 0x5ca1ab1e);
        // Quantized rates: a churn fleet models one operator's camera
        // network, which configures a handful of analysis rates rather
        // than a continuum — and gives the trace the item multiplicity
        // the aggregated solver path (`packing::aggregate`) exploits,
        // so `--trace churn --solver portfolio` exercises aggregation.
        let pool = FleetSpec::new(cameras * 2)
            .seed(seed)
            .rate_levels(Self::CHURN_RATE_LEVELS)
            .build();
        let mut trace =
            WorkloadTrace::new(format!("churn-{seed}-{cameras}x{epochs}"), pool.catalog.clone());
        let mut count = cameras as i64;
        let (lo, hi) = ((cameras as i64 / 2).max(1), cameras as i64 * 2);
        for e in 0..epochs {
            let step_cap = (cameras as i64 / 4).max(1);
            let step = rng.range_u64(0, 2 * step_cap as u64) as i64 - step_cap;
            count = (count + step).clamp(lo, hi);
            let streams: Vec<StreamSpec> = pool.streams[..count as usize].to_vec();
            trace = trace.epoch(format!("e{e:02}-n{count}"), 1800.0, streams);
        }
        trace
    }

    /// The spot-market scenario: a sustained monitoring fleet priced on
    /// a two-tier catalog (on-demand plus a 35%-of-list spot tier)
    /// where the provider reclaims half the spot fleet mid-epoch twice
    /// over the timeline.  A reactive policy rides the discount and
    /// re-packs orphaned streams on each revocation; a static on-demand
    /// fleet pays list price but never churns — the trade the
    /// `spot_market` bench quantifies.
    ///
    /// Camera identities persist across epochs (rates breathe ±10%), so
    /// warm-start repacking keeps most placements at every boundary.
    pub fn spot_market(seed: u64) -> WorkloadTrace {
        let mut rng = Rng::new(seed ^ 0x0005_1d07);
        let catalog = Catalog::paper_experiments().with_pricing(PricingModel::with_tiers(vec![
            TierSpec::new(PricingTier::OnDemand),
            TierSpec::new(PricingTier::Spot),
        ]));
        let mut trace = WorkloadTrace::new(format!("spot-{seed}"), catalog);
        for e in 0..6u32 {
            let streams: Vec<StreamSpec> = (0..8)
                .map(|i| {
                    StreamSpec::new(Camera::new(i, VGA), Program::Zf, rng.range_f64(0.45, 0.55))
                })
                .collect();
            let revocations = if e == 1 || e == 3 {
                vec![Revocation { at_s: rng.range_f64(900.0, 2700.0), fraction: 0.5 }]
            } else {
                Vec::new()
            };
            trace =
                trace.epoch_with_revocations(format!("s{e:02}"), 3600.0, streams, revocations);
        }
        trace
    }

    // ----- JSON persistence ---------------------------------------------

    /// Serialize to the trace config shape:
    ///
    /// ```json
    /// {
    ///   "name": "my-trace",
    ///   "catalog": ["c4.2xlarge", "g2.2xlarge"],
    ///   "epochs": [
    ///     {"label": "normal", "duration_s": 5400,
    ///      "streams": [{"program": "zf", "fps": 0.2, "cameras": 3}]}
    ///   ]
    /// }
    /// ```
    pub fn to_json(&self) -> Json {
        let epochs: Vec<Json> = self
            .epochs
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("label".to_string(), Json::Str(e.label.clone())),
                    ("duration_s".to_string(), Json::Num(e.duration_s)),
                    (
                        "streams".to_string(),
                        Json::Arr(e.streams.iter().map(stream_to_json).collect()),
                    ),
                ];
                if !e.revocations.is_empty() {
                    fields.push((
                        "revocations".to_string(),
                        Json::Arr(
                            e.revocations
                                .iter()
                                .map(|r| {
                                    Json::obj(vec![
                                        ("at_s".to_string(), Json::Num(r.at_s)),
                                        ("fraction".to_string(), Json::Num(r.fraction)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                Json::obj(fields)
            })
            .collect();
        let mut fields = vec![
            ("name".to_string(), Json::Str(self.name.clone())),
            (
                "catalog".to_string(),
                Json::Arr(
                    self.catalog
                        .types
                        .iter()
                        .map(|t| Json::Str(t.name.clone()))
                        .collect(),
                ),
            ),
        ];
        if !self.catalog.pricing.is_flat() {
            fields.push(("pricing".to_string(), pricing_to_json(&self.catalog.pricing)));
        }
        fields.push(("epochs".to_string(), Json::Arr(epochs)));
        Json::obj(fields)
    }

    /// Parse the trace config shape (see [`WorkloadTrace::to_json`]).
    pub fn from_json(v: &Json) -> Result<WorkloadTrace> {
        let name = v.str_field("name")?.to_string();
        let catalog = catalog_from_json(v)?;
        let mut epochs = Vec::new();
        for (i, row) in v.arr_field("epochs")?.iter().enumerate() {
            let label = match row.get("label").and_then(Json::as_str) {
                Some(l) => l.to_string(),
                None => format!("epoch-{i}"),
            };
            let duration_s = row.f64_field("duration_s")?;
            if duration_s <= 0.0 {
                return Err(anyhow!("epoch {label:?}: duration_s must be positive"));
            }
            let streams = stream_rows_from_json(row.arr_field("streams")?)?;
            let mut revocations = Vec::new();
            if let Some(rows) = row.get("revocations").and_then(Json::as_arr) {
                for rr in rows {
                    let at_s = rr.f64_field("at_s")?;
                    let fraction = rr.f64_field("fraction")?;
                    if !(0.0..=duration_s).contains(&at_s) {
                        return Err(anyhow!("epoch {label:?}: revocation at_s out of range"));
                    }
                    if !(0.0..=1.0).contains(&fraction) {
                        return Err(anyhow!("epoch {label:?}: revocation fraction out of [0, 1]"));
                    }
                    revocations.push(Revocation { at_s, fraction });
                }
            }
            epochs.push(Epoch { label, duration_s, streams, revocations });
        }
        if epochs.is_empty() {
            return Err(anyhow!("trace has no epochs"));
        }
        Ok(WorkloadTrace { name, catalog, epochs })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<WorkloadTrace> {
        let text = std::fs::read_to_string(path)?;
        WorkloadTrace::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emergency_shape_is_stable_per_seed() {
        let a = WorkloadTrace::emergency_burst(7);
        let b = WorkloadTrace::emergency_burst(7);
        assert_eq!(a.epochs.len(), 3);
        assert_eq!(a.epochs[0].streams.len(), 3);
        assert_eq!(a.epochs[1].streams.len(), 10);
        assert_eq!(a.epochs[2].streams.len(), 3);
        assert_eq!(a.total_duration_s(), 14400.0);
        assert_eq!(a.start_of(1), 5400.0);
        for (x, y) in a.epochs.iter().zip(&b.epochs) {
            for (s, t) in x.streams.iter().zip(&y.streams) {
                assert_eq!(s.desired_fps, t.desired_fps);
            }
        }
        // Rates stay in the bands that pin the per-epoch plan shapes.
        assert!(a.epochs[0]
            .streams
            .iter()
            .all(|s| (0.15..0.25).contains(&s.desired_fps)));
        assert!(a.epochs[1]
            .streams
            .iter()
            .all(|s| (0.9..1.1).contains(&s.desired_fps)));
        let c = WorkloadTrace::emergency_burst(8);
        assert!(a.epochs[1]
            .streams
            .iter()
            .zip(&c.epochs[1].streams)
            .any(|(x, y)| x.desired_fps != y.desired_fps));
    }

    #[test]
    fn diurnal_scales_rates_within_baseline() {
        let t = WorkloadTrace::diurnal(12, 3);
        assert_eq!(t.epochs.len(), 24);
        let base = FleetSpec::new(12).seed(3).build();
        for e in &t.epochs {
            assert_eq!(e.streams.len(), 12);
            for (s, b) in e.streams.iter().zip(&base.streams) {
                assert!(s.desired_fps <= b.desired_fps + 1e-12);
                assert!(s.desired_fps >= 0.25 * b.desired_fps - 1e-12);
            }
        }
        // Peak hour (15:00) is the unscaled baseline.
        let peak = &t.epochs[15];
        for (s, b) in peak.streams.iter().zip(&base.streams) {
            assert!((s.desired_fps - b.desired_fps).abs() < 1e-12);
        }
        // Trough (03:00) is a quarter of it.
        let trough = &t.epochs[3];
        for (s, b) in trough.streams.iter().zip(&base.streams) {
            assert!((s.desired_fps - 0.25 * b.desired_fps).abs() < 1e-12);
        }
    }

    #[test]
    fn churn_walks_population_within_bounds() {
        let t = WorkloadTrace::camera_churn(40, 8, 11);
        assert_eq!(t.epochs.len(), 8);
        let counts: Vec<usize> = t.epochs.iter().map(|e| e.streams.len()).collect();
        assert!(counts.iter().all(|&n| (20..=80).contains(&n)), "{counts:?}");
        assert!(counts.windows(2).any(|w| w[0] != w[1]), "{counts:?}");
        // Stable identity: epoch populations are prefixes of one pool
        // (the quantized-rate pool the aggregated solver exploits).
        let pool = FleetSpec::new(80)
            .seed(11)
            .rate_levels(WorkloadTrace::CHURN_RATE_LEVELS)
            .build();
        for e in &t.epochs {
            for (s, p) in e.streams.iter().zip(&pool.streams) {
                assert_eq!(s.camera.id, p.camera.id);
                assert_eq!(s.desired_fps, p.desired_fps);
            }
        }
        // The pool collapses to few requirement classes: every epoch is
        // high-multiplicity once it has more streams than classes.
        let mut rates: Vec<(crate::types::Program, u64)> = pool
            .streams
            .iter()
            .map(|s| (s.program, s.desired_fps.to_bits()))
            .collect();
        rates.sort_unstable();
        rates.dedup();
        assert!(rates.len() <= 2 * WorkloadTrace::CHURN_RATE_LEVELS as usize);
    }

    #[test]
    fn builtin_names_resolve() {
        assert_eq!(WorkloadTrace::builtin("emergency", 1).unwrap().epochs.len(), 3);
        assert_eq!(WorkloadTrace::builtin("diurnal", 1).unwrap().epochs.len(), 24);
        assert_eq!(WorkloadTrace::builtin("churn", 1).unwrap().epochs.len(), 8);
        assert_eq!(WorkloadTrace::builtin("spot", 1).unwrap().epochs.len(), 6);
        assert!(WorkloadTrace::builtin("sinusoid", 1).is_err());
    }

    #[test]
    fn spot_trace_carries_tiers_and_seeded_revocations() {
        let a = WorkloadTrace::spot_market(7);
        let b = WorkloadTrace::spot_market(7);
        assert!(!a.catalog.pricing.is_flat());
        assert_eq!(a.catalog.pricing.tiers.len(), 2);
        assert!(a
            .catalog
            .pricing
            .tiers
            .iter()
            .any(|t| t.tier == PricingTier::Spot));
        let revoking: Vec<usize> = a
            .epochs
            .iter()
            .enumerate()
            .filter(|(_, e)| !e.revocations.is_empty())
            .map(|(i, _)| i)
            .collect();
        assert_eq!(revoking, vec![1, 3]);
        for (x, y) in a.epochs.iter().zip(&b.epochs) {
            assert_eq!(x.revocations, y.revocations);
            for r in &x.revocations {
                assert!((0.0..=x.duration_s).contains(&r.at_s));
                assert_eq!(r.fraction, 0.5);
            }
        }
        // Stable camera identities: warm repacks keep placements.
        for e in &a.epochs {
            assert_eq!(e.streams.len(), 8);
            assert_eq!(e.streams[0].camera.id, 0);
        }
    }

    #[test]
    fn spot_json_round_trip_preserves_pricing_and_revocations() {
        let t = WorkloadTrace::spot_market(3);
        let back =
            WorkloadTrace::from_json(&Json::parse(&t.to_json().to_pretty()).unwrap()).unwrap();
        assert!(!back.catalog.pricing.is_flat());
        assert_eq!(back.catalog.pricing.tiers.len(), 2);
        for (x, y) in t.epochs.iter().zip(&back.epochs) {
            assert_eq!(x.revocations.len(), y.revocations.len());
            for (r, s) in x.revocations.iter().zip(&y.revocations) {
                assert!((r.at_s - s.at_s).abs() < 1e-9);
                assert_eq!(r.fraction, s.fraction);
            }
        }
        // Out-of-range revocations are rejected on load.
        let bad = r#"{"name":"x","epochs":[
            {"duration_s":60,"streams":[{"program":"zf","fps":1}],
             "revocations":[{"at_s":90,"fraction":0.5}]}]}"#;
        assert!(WorkloadTrace::from_json(&Json::parse(bad).unwrap()).is_err());
        let bad2 = r#"{"name":"x","epochs":[
            {"duration_s":60,"streams":[{"program":"zf","fps":1}],
             "revocations":[{"at_s":30,"fraction":1.5}]}]}"#;
        assert!(WorkloadTrace::from_json(&Json::parse(bad2).unwrap()).is_err());
    }

    #[test]
    fn json_round_trip_preserves_trace() {
        let t = WorkloadTrace::emergency_burst(5);
        let back = WorkloadTrace::from_json(&Json::parse(&t.to_json().to_pretty()).unwrap())
            .unwrap();
        assert_eq!(back.name, t.name);
        assert_eq!(back.catalog.types.len(), t.catalog.types.len());
        assert_eq!(back.epochs.len(), t.epochs.len());
        for (x, y) in t.epochs.iter().zip(&back.epochs) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.duration_s, y.duration_s);
            assert_eq!(x.streams.len(), y.streams.len());
            for (s, r) in x.streams.iter().zip(&y.streams) {
                assert_eq!(s.program, r.program);
                assert_eq!(s.desired_fps, r.desired_fps);
            }
        }
    }

    #[test]
    fn from_json_validates() {
        let no_epochs = r#"{"name":"x","epochs":[]}"#;
        assert!(WorkloadTrace::from_json(&Json::parse(no_epochs).unwrap()).is_err());
        let bad_duration = r#"{"name":"x","epochs":[
            {"label":"a","duration_s":0,"streams":[{"program":"zf","fps":1}]}]}"#;
        assert!(WorkloadTrace::from_json(&Json::parse(bad_duration).unwrap()).is_err());
        let bad_fps = r#"{"name":"x","epochs":[
            {"label":"a","duration_s":60,"streams":[{"program":"zf","fps":-1}]}]}"#;
        assert!(WorkloadTrace::from_json(&Json::parse(bad_fps).unwrap()).is_err());
        // Default label and catalog apply.
        let minimal = r#"{"name":"x","epochs":[
            {"duration_s":60,"streams":[{"program":"zf","fps":1}]}]}"#;
        let t = WorkloadTrace::from_json(&Json::parse(minimal).unwrap()).unwrap();
        assert_eq!(t.epochs[0].label, "epoch-0");
        assert_eq!(t.catalog.types.len(), 4);
    }

    #[test]
    fn save_load_round_trip() {
        let mut path = std::env::temp_dir();
        path.push(format!("camcloud-trace-{}.json", std::process::id()));
        let t = WorkloadTrace::camera_churn(10, 4, 2);
        t.save(&path).unwrap();
        let back = WorkloadTrace::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.name, t.name);
        assert_eq!(back.epochs.len(), 4);
        assert!(WorkloadTrace::load(Path::new("/nonexistent/t.json")).is_err());
    }

    #[test]
    fn epoch_workload_view() {
        let t = WorkloadTrace::emergency_burst(9);
        let w = t.workload(1);
        assert_eq!(w.streams.len(), 10);
        assert!(w.name.ends_with("/emergency"));
        assert_eq!(w.catalog.types.len(), 2);
    }
}
