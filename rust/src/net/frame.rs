//! Length-prefixed message framing over a byte stream.
//!
//! Every message on the wire is one *frame*: a 4-byte big-endian
//! payload length followed by that many bytes of UTF-8 JSON
//! (`util::json` compact form).  Framing is the only byte-level layer
//! of the protocol — everything above it ([`crate::net::proto`]) works
//! on [`Json`] values, so a malformed peer can at worst produce a
//! parse error here, never a desynchronized stream interpretation.
//!
//! [`MAX_FRAME`] bounds the allocation a length prefix can demand, so
//! a corrupt or hostile peer cannot make the reader allocate
//! arbitrarily (the largest legitimate frames — serialized 100k-stream
//! simulation shards — are tens of megabytes).

use crate::util::error::{anyhow, ensure, Result};
use crate::util::json::Json;
use std::io::{Read, Write};

/// Upper bound on one frame's payload (256 MiB).
pub const MAX_FRAME: usize = 1 << 28;

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut impl Write, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME,
        "frame of {} bytes exceeds the {} byte cap",
        payload.len(),
        MAX_FRAME
    );
    stream.write_all(&(payload.len() as u32).to_be_bytes())?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame(stream: &mut impl Read) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_be_bytes(len_buf) as usize;
    ensure!(len <= MAX_FRAME, "peer announced a {len} byte frame (cap {MAX_FRAME})");
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    Ok(payload)
}

/// Serialize `msg` compactly and send it as one frame.
pub fn send_json(stream: &mut impl Write, msg: &Json) -> Result<()> {
    write_frame(stream, msg.to_compact().as_bytes())
}

/// Receive one frame and parse it as JSON.
pub fn recv_json(stream: &mut impl Read) -> Result<Json> {
    let payload = read_frame(stream)?;
    let text = std::str::from_utf8(&payload)
        .map_err(|e| anyhow!("frame payload is not UTF-8: {e}"))?;
    Ok(Json::parse(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip_over_a_buffer() {
        let msg = Json::obj(vec![
            ("type".to_string(), Json::Str("ping".to_string())),
            ("n".to_string(), Json::Num(42.0)),
        ]);
        let mut wire = Vec::new();
        send_json(&mut wire, &msg).unwrap();
        // 4-byte prefix + payload.
        assert_eq!(wire.len(), 4 + msg.to_compact().len());
        let back = recv_json(&mut wire.as_slice()).unwrap();
        assert_eq!(back.to_compact(), msg.to_compact());
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_be_bytes());
        assert!(read_frame(&mut wire.as_slice()).is_err());
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&8u32.to_be_bytes());
        wire.extend_from_slice(b"abc");
        assert!(read_frame(&mut wire.as_slice()).is_err());
    }

    #[test]
    fn non_json_payload_is_an_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"not json").unwrap();
        assert!(recv_json(&mut wire.as_slice()).is_err());
    }
}
