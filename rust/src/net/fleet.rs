//! The coordinator's view of the worker fleet, with a self-healing
//! failure model.
//!
//! A process-global registry (set once from the CLI via
//! [`set_workers`], queried by the dispatch seams in `packing::exact`
//! and `sched::shard` via [`active`]) holds one [`Fleet`] of worker
//! addresses.  Globality is deliberate: the fleet cuts *underneath*
//! the solver and simulation APIs, which stay byte-for-byte identical
//! — with no fleet registered (the default), every dispatch site takes
//! its pre-existing local path.
//!
//! **Failure model.**  Workers are raced against local threads and are
//! never load-bearing, so any failure can be survived by re-running
//! the affected work locally.  Failures are *classified*:
//!
//! * **transient** (connect refused, read/write timeout, disconnect) —
//!   the RPC retries up to [`FleetTuning::retries`] times with capped
//!   exponential backoff and deterministic seeded jitter; only
//!   exhausted retries trip the worker's circuit breaker open;
//! * **fatal** (the worker answered an explicit `error` reply) — the
//!   breaker trips open immediately;
//! * **protocol violation** (bad handshake, unparsable frame, a reply
//!   that fails the caller's structural validation) — the worker is
//!   quarantined for the rest of the run: a peer that *lies* is never
//!   trusted again, while a peer that merely *fails* may heal.
//!
//! **Circuit breaker.**  Each worker is `Closed` (in rotation), `Open`
//! (out of rotation, re-probed with a cheap `ping` once its cooldown
//! elapses — the half-open state — and re-admitted on success, with the
//! cooldown doubling per failed probe), or `Quarantined` (permanent).
//! [`Fleet::ready_workers`], called by every dispatch site before
//! fanning out, is the probe point: a worker that died and restarted
//! mid-trace rejoins the fleet there instead of being lost for the run.
//!
//! **Per-request-type deadlines.**  A `ping` gets seconds, a simulation
//! shard a minute, an exact subtree batch the full solve deadline
//! ([`RpcClass`]) — so liveness probing never waits on the worst-case
//! solve budget.
//!
//! Every terminal failure is visible: per-cause profiling counters
//! (`net:rpc:connect`, `net:rpc:timeout`, `net:rpc:disconnect`,
//! `net:rpc:garbage`, `net:rpc:retried`, `net:rpc:hedged`,
//! `net:fleet:readmitted`) plus the always-compiled [`FleetStats`]
//! snapshot.  Outcomes are unchanged by any of this, by construction:
//! workers only ever *race* work the coordinator can do itself, every
//! reply is re-validated, and the winner folds are order-strict.

use crate::net::chaos::{self, Fault};
use crate::net::frame::{recv_json, send_json};
use crate::net::proto::{check_hello, hello};
use crate::util::error::{anyhow, ensure, Result};
use crate::util::json::Json;
use crate::util::profiling::{bump, time_phase};
use std::cell::Cell;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Retry, backoff, re-probe, deadline, and hedging knobs.  The
/// defaults suit real fleets; tests shrink the clocks so soak runs
/// finish in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct FleetTuning {
    /// Extra attempts after the first for transient failures.
    pub retries: u32,
    /// First backoff step; doubles per retry.
    pub backoff_base_ms: u64,
    /// Backoff ceiling.
    pub backoff_cap_ms: u64,
    /// Seeds the deterministic backoff jitter.
    pub jitter_seed: u64,
    /// Cooldown before an `Open` worker is first re-probed; doubles
    /// per failed probe.
    pub probe_cooldown_ms: u64,
    /// Re-probe cooldown ceiling.
    pub probe_cooldown_cap_ms: u64,
    /// Master switch for straggler hedging on the claim loops.
    pub hedge: bool,
    /// Floor before any in-flight remote claim can be hedged.
    pub hedge_after_ms: u64,
    /// A claim is a straggler once it exceeds this multiple of the
    /// median completed-claim duration (with the floor above).
    pub hedge_factor: f64,
    /// Connect deadline for work-bearing RPCs.
    pub connect_timeout_ms: u64,
    /// Connect *and* I/O deadline for `ping` probes.
    pub ping_timeout_ms: u64,
    /// I/O deadline for `simulate` requests.
    pub sim_timeout_ms: u64,
    /// I/O deadline for `exact` requests (a reply can legitimately
    /// take a full subtree-batch solve).
    pub exact_timeout_ms: u64,
}

impl Default for FleetTuning {
    fn default() -> FleetTuning {
        FleetTuning {
            retries: 2,
            backoff_base_ms: 25,
            backoff_cap_ms: 1_000,
            jitter_seed: 0x5EED_CAFE,
            probe_cooldown_ms: 2_000,
            probe_cooldown_cap_ms: 30_000,
            hedge: true,
            hedge_after_ms: 500,
            hedge_factor: 4.0,
            connect_timeout_ms: 5_000,
            ping_timeout_ms: 2_000,
            sim_timeout_ms: 60_000,
            exact_timeout_ms: 120_000,
        }
    }
}

/// What kind of request an RPC carries, for deadline selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RpcClass {
    Ping,
    Simulate,
    Exact,
}

impl FleetTuning {
    /// `(connect, io)` deadlines for one request class.
    fn limits(&self, class: RpcClass) -> (Duration, Duration) {
        let ms = Duration::from_millis;
        match class {
            RpcClass::Ping => (
                ms(self.ping_timeout_ms.min(self.connect_timeout_ms)),
                ms(self.ping_timeout_ms),
            ),
            RpcClass::Simulate => (ms(self.connect_timeout_ms), ms(self.sim_timeout_ms)),
            RpcClass::Exact => (ms(self.connect_timeout_ms), ms(self.exact_timeout_ms)),
        }
    }
}

/// Circuit-breaker state of one worker.
#[derive(Clone, Copy, Debug)]
enum Breaker {
    /// In rotation.
    Closed,
    /// Out of rotation; re-probed once `next_probe` passes.
    Open { next_probe: Instant, failed_probes: u32 },
    /// Out of rotation forever (protocol violation).
    Quarantined,
}

struct Worker {
    addr: SocketAddr,
    /// The address as the user wrote it, for log lines.
    label: String,
    state: Mutex<Breaker>,
    /// Sequence number feeding the deterministic backoff jitter.
    jitter_seq: AtomicU64,
}

/// Monotonic failure/recovery counters, always compiled (unlike the
/// feature-gated profiling registry) so tests and benches can assert
/// on them.  Snapshot via [`Fleet::stats`].
#[derive(Default)]
struct Counters {
    connect: AtomicU64,
    timeout: AtomicU64,
    disconnect: AtomicU64,
    garbage: AtomicU64,
    retried: AtomicU64,
    hedged: AtomicU64,
    readmitted: AtomicU64,
}

/// A point-in-time snapshot of a fleet's [`Counters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetStats {
    /// Connect-refused RPC attempts (including injected ones).
    pub connect: u64,
    /// Read/write-timeout RPC attempts.
    pub timeout: u64,
    /// Mid-stream disconnects.
    pub disconnect: u64,
    /// Protocol violations (quarantines).
    pub garbage: u64,
    /// RPCs that succeeded only after at least one retry.
    pub retried: u64,
    /// Straggler claims speculatively re-dispatched locally.
    pub hedged: u64,
    /// `Open -> Closed` re-admissions via a successful probe.
    pub readmitted: u64,
}

/// An immutable set of worker addresses with per-worker breaker state.
pub struct Fleet {
    workers: Vec<Worker>,
    tuning: FleetTuning,
    counters: Counters,
}

static FLEET: Mutex<Option<Arc<Fleet>>> = Mutex::new(None);

/// Outcome of a cancellable RPC (see [`Fleet::rpc_cancellable`]).
pub(crate) enum RpcOutcome {
    /// The worker replied (the reply is *not* yet validated).
    Reply(Json),
    /// The worker failed terminally; its breaker is already updated.
    Lost,
    /// The caller's cancel predicate fired first; the in-flight
    /// attempt resolves (and updates breaker state) in the background.
    Abandoned,
}

/// How one round-trip attempt failed.
enum RpcError {
    /// Worth retrying: the worker may merely be restarting or slow.
    Transient(TransientKind, String),
    /// Not worth retrying, but the worker spoke the protocol
    /// correctly (an explicit `error` reply): trip open, re-probe.
    Fatal(String),
    /// The peer violated the protocol: quarantine it.
    Violation(String),
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum TransientKind {
    Connect,
    Timeout,
    Disconnect,
}

impl Fleet {
    /// Build a fleet: resolve every address and ping each worker once
    /// (with retries).  Workers that fail the registration ping start
    /// `Open` and will be re-probed; the build only fails if *no*
    /// worker is reachable (or an address does not resolve at all).
    /// Does not touch the process-global registry — see
    /// [`set_workers`] for that.
    pub fn connect(addrs: &[String], tuning: FleetTuning) -> Result<Arc<Fleet>> {
        ensure!(!addrs.is_empty(), "worker list is empty");
        let mut workers = Vec::with_capacity(addrs.len());
        for label in addrs {
            let addr = resolve(label)?;
            workers.push(Worker {
                addr,
                label: label.clone(),
                state: Mutex::new(Breaker::Closed),
                jitter_seq: AtomicU64::new(0),
            });
        }
        let fleet = Arc::new(Fleet { workers, tuning, counters: Counters::default() });
        let ping = ping_request();
        for i in 0..fleet.workers.len() {
            if let Some(reply) = fleet.rpc(i, &ping, RpcClass::Ping) {
                if let Err(e) = expect_pong(&reply) {
                    fleet.quarantine(i, &format!("registration ping: {e:#}"));
                }
            }
        }
        ensure!(
            fleet.live_count() > 0,
            "none of the {} workers are reachable",
            addrs.len()
        );
        Ok(fleet)
    }

    /// Workers currently `Closed` (in rotation).
    pub fn live_count(&self) -> usize {
        self.workers
            .iter()
            .filter(|w| matches!(*w.state.lock().expect("worker state"), Breaker::Closed))
            .count()
    }

    /// Workers not quarantined — `Closed` plus `Open` awaiting
    /// re-probe.  This is what keeps a fleet of temporarily-dead
    /// workers *registered* (so probing can heal it) while
    /// [`live_count`](Fleet::live_count) reports nobody in rotation.
    pub fn usable_count(&self) -> usize {
        self.workers
            .iter()
            .filter(|w| !matches!(*w.state.lock().expect("worker state"), Breaker::Quarantined))
            .count()
    }

    /// The dispatch-site entry point: re-probe every `Open` worker
    /// whose cooldown has elapsed (the half-open state — one cheap
    /// `ping` decides re-admission), then return the indices of
    /// `Closed` workers, one dispatcher thread each.
    pub fn ready_workers(&self) -> Vec<usize> {
        for i in 0..self.workers.len() {
            if self.claim_probe(i) {
                self.probe(i);
            }
        }
        (0..self.workers.len())
            .filter(|&i| {
                matches!(*self.workers[i].state.lock().expect("worker state"), Breaker::Closed)
            })
            .collect()
    }

    /// Atomically claim the right to probe worker `i` if it is `Open`
    /// and due, pushing `next_probe` forward so concurrent callers
    /// skip it while the probe is in flight.
    fn claim_probe(&self, i: usize) -> bool {
        let mut state = self.workers[i].state.lock().expect("worker state");
        match *state {
            Breaker::Open { next_probe, failed_probes } if Instant::now() >= next_probe => {
                *state = Breaker::Open {
                    next_probe: Instant::now() + self.probe_cooldown(failed_probes),
                    failed_probes,
                };
                true
            }
            _ => false,
        }
    }

    /// Half-open probe: one ping, no retries (probing is already
    /// periodic).  Success re-admits; garbage quarantines; failure
    /// doubles the cooldown.
    fn probe(&self, i: usize) {
        let fault = chaos::next_fault(i);
        let (connect, io) = self.tuning.limits(RpcClass::Ping);
        let outcome = round_trip(self.workers[i].addr, &ping_request(), connect, io, fault)
            .and_then(|reply| {
                expect_pong(&reply).map_err(|e| RpcError::Violation(format!("{e:#}")))
            });
        match outcome {
            Ok(()) => {
                *self.workers[i].state.lock().expect("worker state") = Breaker::Closed;
                self.counters.readmitted.fetch_add(1, Ordering::Relaxed);
                bump("net:fleet:readmitted");
                eprintln!("worker {} re-admitted to the fleet", self.workers[i].label);
            }
            Err(RpcError::Violation(reason)) => self.quarantine(i, &reason),
            Err(_) => {
                let mut state = self.workers[i].state.lock().expect("worker state");
                if let Breaker::Open { failed_probes, .. } = *state {
                    let failed = failed_probes.saturating_add(1);
                    *state = Breaker::Open {
                        next_probe: Instant::now() + self.probe_cooldown(failed),
                        failed_probes: failed,
                    };
                }
            }
        }
    }

    fn probe_cooldown(&self, failed_probes: u32) -> Duration {
        let base = self.tuning.probe_cooldown_ms.max(1);
        let ms = base
            .saturating_shl(failed_probes.min(16))
            .min(self.tuning.probe_cooldown_cap_ms.max(base));
        Duration::from_millis(ms)
    }

    /// One request/response exchange against worker `widx`, retrying
    /// transient failures with capped exponential backoff and seeded
    /// jitter.  `None` means the worker's breaker is now open (or it
    /// was already out of rotation) and the caller must run the
    /// shipped work locally.  The reply is transport-valid but not
    /// semantically validated — callers that find it structurally
    /// wrong must call [`report_violation`](Fleet::report_violation).
    pub fn rpc(&self, widx: usize, request: &Json, class: RpcClass) -> Option<Json> {
        let (connect, io) = self.tuning.limits(class);
        let mut attempt: u32 = 0;
        loop {
            if !matches!(
                *self.workers[widx].state.lock().expect("worker state"),
                Breaker::Closed
            ) {
                return None;
            }
            let fault = chaos::next_fault(widx);
            let outcome =
                time_phase("net:rpc", || round_trip(self.workers[widx].addr, request, connect, io, fault));
            match outcome {
                Ok(reply) => {
                    if attempt > 0 {
                        self.counters.retried.fetch_add(1, Ordering::Relaxed);
                        bump("net:rpc:retried");
                    }
                    return Some(reply);
                }
                Err(RpcError::Transient(kind, reason)) => {
                    match kind {
                        TransientKind::Connect => {
                            self.counters.connect.fetch_add(1, Ordering::Relaxed);
                            bump("net:rpc:connect");
                        }
                        TransientKind::Timeout => {
                            self.counters.timeout.fetch_add(1, Ordering::Relaxed);
                            bump("net:rpc:timeout");
                        }
                        TransientKind::Disconnect => {
                            self.counters.disconnect.fetch_add(1, Ordering::Relaxed);
                            bump("net:rpc:disconnect");
                        }
                    }
                    if attempt >= self.tuning.retries {
                        self.trip_open(widx, &reason);
                        return None;
                    }
                    attempt += 1;
                    std::thread::sleep(self.backoff(widx, attempt));
                }
                Err(RpcError::Fatal(reason)) => {
                    self.trip_open(widx, &reason);
                    return None;
                }
                Err(RpcError::Violation(reason)) => {
                    self.quarantine(widx, &reason);
                    return None;
                }
            }
        }
    }

    /// [`rpc`](Fleet::rpc) running on a detached thread while this
    /// thread polls `cancelled`.  When the predicate fires first the
    /// call returns [`RpcOutcome::Abandoned`] immediately — the claim
    /// loop's hedging uses this so a straggling worker cannot hold the
    /// epoch barrier hostage for a full I/O deadline — and the
    /// background attempt still settles breaker state when it
    /// resolves.  Its late reply, if any, is discarded unmerged.
    pub(crate) fn rpc_cancellable(
        self: &Arc<Self>,
        widx: usize,
        request: Json,
        class: RpcClass,
        cancelled: &(dyn Fn() -> bool),
    ) -> RpcOutcome {
        let (tx, rx) = mpsc::channel();
        let fleet = Arc::clone(self);
        std::thread::spawn(move || {
            let _ = tx.send(fleet.rpc(widx, &request, class));
        });
        loop {
            match rx.recv_timeout(Duration::from_millis(10)) {
                Ok(Some(reply)) => return RpcOutcome::Reply(reply),
                Ok(None) => return RpcOutcome::Lost,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if cancelled() {
                        return RpcOutcome::Abandoned;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return RpcOutcome::Lost,
            }
        }
    }

    /// Capped exponential backoff with deterministic seeded jitter:
    /// attempt `k` sleeps `base << (k-1)` (capped) plus a hash-derived
    /// jitter of up to half the step — reproducible for a given
    /// `(jitter_seed, worker, sequence)`, never synchronized across
    /// workers.
    fn backoff(&self, widx: usize, attempt: u32) -> Duration {
        let step = self
            .tuning
            .backoff_base_ms
            .max(1)
            .saturating_shl(attempt.saturating_sub(1).min(16))
            .min(self.tuning.backoff_cap_ms.max(1));
        let seq = self.workers[widx].jitter_seq.fetch_add(1, Ordering::Relaxed);
        let jitter = jitter_hash(self.tuning.jitter_seed, widx as u64, seq) % (step / 2 + 1);
        Duration::from_millis(step + jitter)
    }

    /// A reply that failed the caller's structural validation: the
    /// worker speaks the protocol but lies in it.  Quarantine — unlike
    /// a crash, garbage does not heal with a restart probe.
    pub(crate) fn report_violation(&self, widx: usize, reason: &str) {
        self.quarantine(widx, reason);
    }

    fn quarantine(&self, widx: usize, reason: &str) {
        let mut state = self.workers[widx].state.lock().expect("worker state");
        if !matches!(*state, Breaker::Quarantined) {
            *state = Breaker::Quarantined;
            drop(state);
            self.counters.garbage.fetch_add(1, Ordering::Relaxed);
            bump("net:rpc:garbage");
            eprintln!(
                "worker {} quarantined ({reason}); re-running its work locally",
                self.workers[widx].label
            );
        }
    }

    /// Trip the breaker open: out of rotation now, re-probed after the
    /// cooldown.  Idempotent; quarantine is never downgraded.
    fn trip_open(&self, widx: usize, reason: &str) {
        let mut state = self.workers[widx].state.lock().expect("worker state");
        if matches!(*state, Breaker::Closed) {
            *state = Breaker::Open {
                next_probe: Instant::now() + self.probe_cooldown(0),
                failed_probes: 0,
            };
            drop(state);
            eprintln!(
                "worker {} lost ({reason}); re-running its work locally, will re-probe",
                self.workers[widx].label
            );
        }
    }

    /// Count one hedged claim (called by the claim loops in
    /// `packing::solver` / `sched::shard`).
    pub(crate) fn note_hedged(&self) {
        self.counters.hedged.fetch_add(1, Ordering::Relaxed);
        bump("net:rpc:hedged");
    }

    /// Snapshot the failure/recovery counters.
    pub fn stats(&self) -> FleetStats {
        let c = &self.counters;
        FleetStats {
            connect: c.connect.load(Ordering::Relaxed),
            timeout: c.timeout.load(Ordering::Relaxed),
            disconnect: c.disconnect.load(Ordering::Relaxed),
            garbage: c.garbage.load(Ordering::Relaxed),
            retried: c.retried.load(Ordering::Relaxed),
            hedged: c.hedged.load(Ordering::Relaxed),
            readmitted: c.readmitted.load(Ordering::Relaxed),
        }
    }

    /// The tuning this fleet was built with.
    pub fn tuning(&self) -> &FleetTuning {
        &self.tuning
    }
}

/// Syntactic validation + order-preserving dedup for a `--workers`
/// list, applied at parse time so malformed addresses fail with a
/// clear error instead of surfacing as connect failures mid-run.
/// Duplicates are dropped with a warning (a doubled worker would just
/// race itself).
pub fn sanitize_workers(addrs: &[String]) -> Result<Vec<String>> {
    ensure!(!addrs.is_empty(), "worker list is empty");
    let mut seen = std::collections::BTreeSet::new();
    let mut out = Vec::with_capacity(addrs.len());
    for raw in addrs {
        let addr = raw.trim();
        ensure!(!addr.is_empty(), "worker list contains an empty address");
        let (host, port) = addr
            .rsplit_once(':')
            .ok_or_else(|| anyhow!("worker address {addr:?} is missing a :port suffix"))?;
        ensure!(!host.is_empty(), "worker address {addr:?} has an empty host");
        let port: u16 = port
            .parse()
            .map_err(|_| anyhow!("worker address {addr:?} has an invalid port {port:?}"))?;
        ensure!(port != 0, "worker address {addr:?} uses reserved port 0");
        if seen.insert(addr.to_string()) {
            out.push(addr.to_string());
        } else {
            eprintln!("warning: duplicate worker address {addr} ignored");
        }
    }
    Ok(out)
}

/// Register the fleet for this process with default tuning.  Returns
/// the live worker count.
pub fn set_workers(addrs: &[String]) -> Result<usize> {
    set_workers_tuned(addrs, FleetTuning::default())
}

/// [`set_workers`] with explicit tuning (tests shrink the backoff and
/// probe clocks; benches disable hedging for baselines).
pub fn set_workers_tuned(addrs: &[String], tuning: FleetTuning) -> Result<usize> {
    let fleet = Fleet::connect(addrs, tuning)?;
    let live = fleet.live_count();
    *FLEET.lock().expect("fleet registry") = Some(fleet);
    Ok(live)
}

/// Deregister the fleet; dispatch sites fall back to pure-local.
pub fn clear() {
    *FLEET.lock().expect("fleet registry") = None;
}

/// The registered fleet, if any worker in it could still serve —
/// `Closed` workers plus `Open` ones awaiting a re-probe.  (Dispatch
/// sites then call [`Fleet::ready_workers`], which is what actually
/// probes and re-admits.)
pub fn active() -> Option<Arc<Fleet>> {
    let fleet = FLEET.lock().expect("fleet registry").clone()?;
    (fleet.usable_count() > 0).then_some(fleet)
}

/// Global stats accessor for tests/benches: the registered fleet's
/// counter snapshot.
pub fn stats() -> Option<FleetStats> {
    FLEET.lock().expect("fleet registry").as_ref().map(|f| f.stats())
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .map_err(|e| anyhow!("worker address {addr:?} does not resolve: {e}"))?
        .next()
        .ok_or_else(|| anyhow!("worker address {addr:?} resolves to nothing"))
}

fn ping_request() -> Json {
    Json::obj(vec![("type".to_string(), Json::Str("ping".to_string()))])
}

fn expect_pong(reply: &Json) -> Result<()> {
    let kind = reply.str_field("type")?;
    ensure!(kind == "pong", "ping answered with {kind:?}");
    Ok(())
}

/// A `Read`/`Write` shim that remembers the `io::ErrorKind` of the
/// last failing operation, so frame-level errors (which surface as
/// opaque `util::error::Error`s) can still be classified as timeout
/// vs. disconnect vs. parse-garbage.
struct Recorded<'a> {
    stream: &'a TcpStream,
    kind: &'a Cell<Option<std::io::ErrorKind>>,
}

impl Read for Recorded<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let r = self.stream.read(buf);
        if let Err(e) = &r {
            self.kind.set(Some(e.kind()));
        }
        r
    }
}

impl Write for Recorded<'_> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let r = self.stream.write(buf);
        if let Err(e) = &r {
            self.kind.set(Some(e.kind()));
        }
        r
    }

    fn flush(&mut self) -> std::io::Result<()> {
        let r = self.stream.flush();
        if let Err(e) = &r {
            self.kind.set(Some(e.kind()));
        }
        r
    }
}

/// Classify a frame-layer failure via the recorded I/O error kind: a
/// fired socket deadline is a timeout, any other I/O error is a
/// disconnect, and *no* recorded I/O error means the bytes arrived but
/// did not parse — a protocol violation.
fn classify_io(e: crate::util::error::Error, kind: Option<std::io::ErrorKind>) -> RpcError {
    use std::io::ErrorKind::{TimedOut, WouldBlock};
    match kind {
        Some(TimedOut) | Some(WouldBlock) => RpcError::Transient(TransientKind::Timeout, format!("{e:#}")),
        Some(_) => RpcError::Transient(TransientKind::Disconnect, format!("{e:#}")),
        None => RpcError::Violation(format!("{e:#}")),
    }
}

/// One request/response round trip on a fresh connection, with chaos
/// injection (`fault`) woven through the frame layer.
fn round_trip(
    addr: SocketAddr,
    request: &Json,
    connect_timeout: Duration,
    io_timeout: Duration,
    fault: Option<Fault>,
) -> Result<Json, RpcError> {
    match fault {
        Some(Fault::Connect) => {
            return Err(RpcError::Transient(
                TransientKind::Connect,
                "chaos: connection refused".to_string(),
            ))
        }
        Some(Fault::WriteTimeout) => {
            return Err(RpcError::Transient(
                TransientKind::Timeout,
                "chaos: write timed out".to_string(),
            ))
        }
        Some(Fault::ReadTimeout) => {
            return Err(RpcError::Transient(
                TransientKind::Timeout,
                "chaos: read timed out".to_string(),
            ))
        }
        Some(Fault::Garbage) => return Ok(chaos::garbage_reply()),
        _ => {}
    }
    let stream = TcpStream::connect_timeout(&addr, connect_timeout)
        .map_err(|e| RpcError::Transient(TransientKind::Connect, e.to_string()))?;
    let setup = stream
        .set_read_timeout(Some(io_timeout))
        .and_then(|()| stream.set_write_timeout(Some(io_timeout)))
        .and_then(|()| stream.set_nodelay(true));
    if let Err(e) = setup {
        return Err(RpcError::Fatal(format!("socket setup failed: {e}")));
    }
    if let Some(Fault::Disconnect) = fault {
        // A real mid-frame disconnect: promise 64 payload bytes, send
        // 5, hang up.  The worker's read_exact fails exactly as it
        // would against a crashing coordinator.
        let mut s = &stream;
        let _ = s.write_all(&64u32.to_be_bytes());
        let _ = s.write_all(b"chaos");
        let _ = s.flush();
        return Err(RpcError::Transient(
            TransientKind::Disconnect,
            "chaos: disconnected mid-frame".to_string(),
        ));
    }
    let kind = Cell::new(None);
    let mut wire = Recorded { stream: &stream, kind: &kind };
    let mut exchange = || -> Result<Json> {
        send_json(&mut wire, &hello())?;
        check_hello(&recv_json(&mut wire)?)?;
        send_json(&mut wire, request)?;
        recv_json(&mut wire)
    };
    let response = exchange().map_err(|e| classify_io(e, kind.get()))?;
    let reply_type = response
        .str_field("type")
        .map_err(|e| RpcError::Violation(format!("reply has no type: {e:#}")))?;
    if reply_type == "error" {
        let message = response.str_field("message").unwrap_or("(no message)");
        return Err(RpcError::Fatal(format!("worker refused the request: {message}")));
    }
    if let Some(Fault::Slow(ms)) = fault {
        std::thread::sleep(Duration::from_millis(ms));
    }
    Ok(response)
}

/// splitmix64-style hash for backoff jitter.
fn jitter_hash(seed: u64, widx: u64, seq: u64) -> u64 {
    let mut z = seed
        .wrapping_add(widx.wrapping_mul(0x9e37_79b9_7f4a_7c15))
        .wrapping_add(seq.wrapping_mul(0xd134_2543_de82_ef95));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// `u64::checked_shl` with saturation to the cap-friendly maximum.
trait SaturatingShl {
    fn saturating_shl(self, shift: u32) -> u64;
}

impl SaturatingShl for u64 {
    fn saturating_shl(self, shift: u32) -> u64 {
        self.checked_shl(shift).unwrap_or(u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_unreachable_workers_is_an_error_and_registers_nothing() {
        // Port 1 on loopback refuses connections immediately; the
        // failed registration must leave the global fleet untouched.
        let tuning = FleetTuning { retries: 1, backoff_base_ms: 1, ..FleetTuning::default() };
        let result = set_workers_tuned(&["127.0.0.1:1".to_string()], tuning);
        assert!(result.is_err());
        assert!(active().is_none());
    }

    #[test]
    fn empty_worker_list_is_an_error() {
        assert!(set_workers(&[]).is_err());
    }

    #[test]
    fn unresolvable_address_is_a_clear_error() {
        let e = Fleet::connect(
            &["definitely-not-a-host.invalid:9001".to_string()],
            FleetTuning::default(),
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("does not resolve"), "{e:#}");
    }

    #[test]
    fn sanitize_accepts_dedupes_and_rejects() {
        // Valid list with one duplicate: deduped, order preserved.
        let addrs: Vec<String> = ["127.0.0.1:9001", "localhost:9002", "127.0.0.1:9001"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let clean = sanitize_workers(&addrs).unwrap();
        assert_eq!(clean, vec!["127.0.0.1:9001".to_string(), "localhost:9002".to_string()]);

        // Malformed addresses are rejected with a clear error.
        for bad in ["no-port", ":9001", "host:", "host:notaport", "host:0", "host:65536", ""] {
            let e = sanitize_workers(&[bad.to_string()]).unwrap_err();
            let msg = format!("{e:#}");
            assert!(
                msg.contains("worker")
                    && (msg.contains("port") || msg.contains("host") || msg.contains("empty")),
                "{bad:?}: {msg}"
            );
        }
        assert!(sanitize_workers(&[]).is_err());
    }

    #[test]
    fn backoff_is_capped_and_jitter_deterministic() {
        let fleet = Fleet {
            workers: vec![Worker {
                addr: SocketAddr::from(([127, 0, 0, 1], 1)),
                label: "test".to_string(),
                state: Mutex::new(Breaker::Closed),
                jitter_seq: AtomicU64::new(0),
            }],
            tuning: FleetTuning {
                backoff_base_ms: 10,
                backoff_cap_ms: 40,
                ..FleetTuning::default()
            },
            counters: Counters::default(),
        };
        // Steps double (10, 20, 40) then cap at 40; jitter adds at most
        // half a step.
        for (attempt, step) in [(1u32, 10u64), (2, 20), (3, 40), (4, 40), (10, 40)] {
            let d = fleet.backoff(0, attempt).as_millis() as u64;
            assert!(
                (step..=step + step / 2).contains(&d),
                "attempt {attempt}: {d}ms outside [{step}, {}]",
                step + step / 2
            );
        }
        // Same (seed, worker, seq) -> same jitter.
        assert_eq!(jitter_hash(1, 2, 3), jitter_hash(1, 2, 3));
        assert_ne!(jitter_hash(1, 2, 3), jitter_hash(1, 2, 4));
    }
}
