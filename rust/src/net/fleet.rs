//! The coordinator's view of the worker fleet.
//!
//! A process-global registry (set once from the CLI via
//! [`set_workers`], queried by the dispatch seams in `packing::exact`
//! and `sched::shard` via [`active`]) holds one [`Fleet`] of worker
//! addresses.  Globality is deliberate: the fleet cuts *underneath*
//! the solver and simulation APIs, which stay byte-for-byte identical
//! — with no fleet registered (the default), every dispatch site takes
//! its pre-existing local path.
//!
//! Failure model: workers are raced against local threads and are
//! never load-bearing.  Every RPC opens a fresh connection (workers
//! hold no per-coordinator state, so a crashed worker that restarts
//! simply starts winning tasks again — but a worker marked dead by
//! *this* coordinator stays dead for the run; re-pinging mid-search
//! would add latency on the failure path for a rare win).  Any
//! connect, I/O, timeout, protocol, or decode failure marks the worker
//! dead, bumps the `net:worker-lost` profiling counter, and the caller
//! re-runs the affected work locally — outcomes are unchanged by
//! construction because workers only ever *race* work the coordinator
//! can do itself.

use crate::net::frame::{recv_json, send_json};
use crate::net::proto::{check_hello, hello};
use crate::util::error::{anyhow, ensure, Result};
use crate::util::json::Json;
use crate::util::profiling::{bump, time_phase};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long a worker gets to accept a connection.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// How long a worker gets to read a request or produce a reply.  Long,
/// because a reply can legitimately take a full subtree-batch solve.
const IO_TIMEOUT: Duration = Duration::from_secs(120);

struct Worker {
    addr: SocketAddr,
    /// The address as the user wrote it, for log lines.
    label: String,
    dead: AtomicBool,
}

/// An immutable set of worker addresses with per-worker liveness.
pub struct Fleet {
    workers: Vec<Worker>,
}

static FLEET: Mutex<Option<Arc<Fleet>>> = Mutex::new(None);

impl Fleet {
    /// Workers not yet marked dead.
    pub fn live_count(&self) -> usize {
        self.workers.iter().filter(|w| !w.dead.load(Ordering::Relaxed)).count()
    }

    /// Indices of live workers, for spawning one dispatch thread each.
    pub(crate) fn live_indices(&self) -> Vec<usize> {
        (0..self.workers.len())
            .filter(|&i| !self.workers[i].dead.load(Ordering::Relaxed))
            .collect()
    }

    /// One request/response round trip against worker `widx` on a
    /// fresh connection.  `None` means the worker is (now) dead and
    /// the caller must run the shipped work locally.
    pub fn rpc(&self, widx: usize, request: &Json) -> Option<Json> {
        if self.workers[widx].dead.load(Ordering::Relaxed) {
            return None;
        }
        match time_phase("net:rpc", || round_trip(self.workers[widx].addr, request)) {
            Ok(reply) => Some(reply),
            Err(e) => {
                self.mark_dead(widx, &format!("{e:#}"));
                None
            }
        }
    }

    /// Retire a worker (RPC failure, or a reply the caller could not
    /// decode/validate).  Idempotent; logs and counts the first loss.
    pub(crate) fn mark_dead(&self, widx: usize, reason: &str) {
        if !self.workers[widx].dead.swap(true, Ordering::Relaxed) {
            bump("net:worker-lost");
            eprintln!(
                "worker {} lost ({reason}); re-running its work locally",
                self.workers[widx].label
            );
        }
    }
}

/// Register the fleet for this process: resolve and ping every
/// address, warn about (and retire) unreachable workers, and fail only
/// if *none* respond.  Returns the live worker count.
pub fn set_workers(addrs: &[String]) -> Result<usize> {
    ensure!(!addrs.is_empty(), "worker list is empty");
    let mut workers = Vec::with_capacity(addrs.len());
    for label in addrs {
        let (addr, dead) = match resolve(label) {
            Ok(addr) => (addr, false),
            Err(e) => {
                bump("net:worker-lost");
                eprintln!("worker {label} unresolvable ({e:#}); dropping it from the fleet");
                (SocketAddr::from(([127, 0, 0, 1], 0)), true)
            }
        };
        workers.push(Worker { addr, label: label.clone(), dead: AtomicBool::new(dead) });
    }
    let fleet = Arc::new(Fleet { workers });
    for i in 0..fleet.workers.len() {
        if fleet.workers[i].dead.load(Ordering::Relaxed) {
            continue;
        }
        if let Err(e) = ping(fleet.workers[i].addr) {
            fleet.mark_dead(i, &format!("handshake failed: {e:#}"));
        }
    }
    let live = fleet.live_count();
    ensure!(live > 0, "none of the {} workers are reachable", addrs.len());
    *FLEET.lock().expect("fleet registry") = Some(fleet);
    Ok(live)
}

/// Deregister the fleet; dispatch sites fall back to pure-local.
pub fn clear() {
    *FLEET.lock().expect("fleet registry") = None;
}

/// The registered fleet, if any worker in it is still live.
pub fn active() -> Option<Arc<Fleet>> {
    let fleet = FLEET.lock().expect("fleet registry").clone()?;
    (fleet.live_count() > 0).then_some(fleet)
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow!("address {addr} resolves to nothing"))
}

fn round_trip(addr: SocketAddr, request: &Json) -> Result<Json> {
    let mut stream = TcpStream::connect_timeout(&addr, CONNECT_TIMEOUT)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    stream.set_nodelay(true)?;
    send_json(&mut stream, &hello())?;
    check_hello(&recv_json(&mut stream)?)?;
    send_json(&mut stream, request)?;
    let response = recv_json(&mut stream)?;
    if response.str_field("type")? == "error" {
        let message = response.str_field("message").unwrap_or("(no message)");
        return Err(anyhow!("worker refused the request: {message}"));
    }
    Ok(response)
}

fn ping(addr: SocketAddr) -> Result<()> {
    let request = Json::obj(vec![("type".to_string(), Json::Str("ping".to_string()))]);
    let reply = round_trip(addr, &request)?;
    let kind = reply.str_field("type")?;
    ensure!(kind == "pong", "ping answered with {kind:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_unreachable_workers_is_an_error_and_registers_nothing() {
        // Port 1 on loopback refuses connections immediately; the
        // failed registration must leave the global fleet untouched.
        let result = set_workers(&["127.0.0.1:1".to_string()]);
        assert!(result.is_err());
        assert!(active().is_none());
    }

    #[test]
    fn empty_worker_list_is_an_error() {
        assert!(set_workers(&[]).is_err());
    }
}
