//! Deterministic, seeded fault injection for coordinator-side RPCs.
//!
//! Chaos is a test/diagnostic harness: when armed ([`arm`], via
//! `--chaos SPEC` or the `CAMCLOUD_CHAOS` env knob), every RPC attempt
//! the coordinator makes against a fleet worker first consults
//! [`next_fault`], which may order one of six failure modes injected at
//! the frame layer by `net::fleet::round_trip`:
//!
//! * **connect** — the connection is refused before any byte moves;
//! * **read-timeout** / **write-timeout** — the attempt fails as if the
//!   socket deadline fired (reported immediately rather than slept
//!   through, so chaos soak tests stay fast);
//! * **slow** — the real round trip completes, then the reply is
//!   delayed by `slow-ms` (this is what exercises straggler hedging);
//! * **disconnect** — a frame header promising more bytes than are ever
//!   sent goes over a real connection, then the socket closes: both
//!   peers observe a genuine mid-frame disconnect;
//! * **garbage** — the reply is replaced by a well-framed JSON value
//!   with a nonsense type, which must fail the caller's structural
//!   validation and quarantine the "lying" worker.
//!
//! **Determinism.**  The fault ordered for attempt *n* against worker
//! *w* is a pure function of `(seed, w, n)` — a splitmix64 hash mapped
//! to `[0, 1)` and compared against the configured cumulative rates —
//! so a given spec replays the identical per-worker fault sequence on
//! every run.  (Which *logical* request lands on attempt ordinal *n*
//! can shift with thread interleaving; the fleet's determinism
//! guarantee is stronger than replay anyway: outcomes are bit-identical
//! under *arbitrary* fault assignments, see `net::fleet`.)

use crate::util::error::{anyhow, ensure, Result};
use crate::util::json::Json;
use std::sync::{Arc, Mutex};

/// One injected failure mode for a single RPC attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Refuse the connection outright.
    Connect,
    /// Fail the attempt as if the read deadline fired.
    ReadTimeout,
    /// Fail the attempt as if the write deadline fired.
    WriteTimeout,
    /// Complete the round trip, then delay the reply by this many ms.
    Slow(u64),
    /// Open a real connection, send a truncated frame, and hang up.
    Disconnect,
    /// Replace the reply with well-framed garbage JSON.
    Garbage,
}

/// Per-fault-type injection rates plus the schedule seed.  Rates are
/// probabilities in `[0, 1]` and must sum to at most 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosConfig {
    /// Seeds the per-(worker, attempt) fault schedule.
    pub seed: u64,
    pub connect: f64,
    pub read_timeout: f64,
    pub write_timeout: f64,
    pub slow: f64,
    /// Reply delay for `slow` faults, in milliseconds.
    pub slow_ms: u64,
    pub disconnect: f64,
    pub garbage: f64,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            seed: 0,
            connect: 0.0,
            read_timeout: 0.0,
            write_timeout: 0.0,
            slow: 0.0,
            slow_ms: 150,
            disconnect: 0.0,
            garbage: 0.0,
        }
    }
}

impl ChaosConfig {
    /// Parse a `key=value,...` spec, e.g.
    /// `seed=42,connect=0.1,read-timeout=0.1,slow=0.2,slow-ms=300,disconnect=0.1,garbage=0.05`.
    /// Unknown keys, unparsable values, out-of-range rates, and rate
    /// sums above 1 are all hard errors — a typo must not silently arm
    /// a different schedule.
    pub fn parse(spec: &str) -> Result<ChaosConfig> {
        let mut config = ChaosConfig::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("chaos spec entry {part:?} is not key=value"))?;
            let rate = |slot: &mut f64| -> Result<()> {
                let v: f64 = value
                    .parse()
                    .map_err(|_| anyhow!("chaos rate {key}={value:?} is not a number"))?;
                ensure!((0.0..=1.0).contains(&v), "chaos rate {key}={value} outside [0, 1]");
                *slot = v;
                Ok(())
            };
            match key.trim() {
                "seed" => {
                    config.seed = value
                        .parse()
                        .map_err(|_| anyhow!("chaos seed {value:?} is not a u64"))?;
                }
                "slow-ms" => {
                    config.slow_ms = value
                        .parse()
                        .map_err(|_| anyhow!("chaos slow-ms {value:?} is not a u64"))?;
                }
                "connect" => rate(&mut config.connect)?,
                "read-timeout" => rate(&mut config.read_timeout)?,
                "write-timeout" => rate(&mut config.write_timeout)?,
                "slow" => rate(&mut config.slow)?,
                "disconnect" => rate(&mut config.disconnect)?,
                "garbage" => rate(&mut config.garbage)?,
                other => return Err(anyhow!("unknown chaos spec key {other:?}")),
            }
        }
        ensure!(
            config.total_rate() <= 1.0 + 1e-12,
            "chaos rates sum to {:.3} (> 1)",
            config.total_rate()
        );
        Ok(config)
    }

    fn total_rate(&self) -> f64 {
        self.connect + self.read_timeout + self.write_timeout + self.slow + self.disconnect
            + self.garbage
    }
}

struct State {
    config: ChaosConfig,
    /// Per-worker attempt ordinals (index = fleet worker index).
    attempts: Mutex<Vec<u64>>,
}

static CHAOS: Mutex<Option<Arc<State>>> = Mutex::new(None);

/// Arm fault injection process-wide.  Resets the attempt ordinals, so
/// re-arming the same config replays the same schedule from the top.
pub fn arm(config: ChaosConfig) {
    *CHAOS.lock().expect("chaos registry") =
        Some(Arc::new(State { config, attempts: Mutex::new(Vec::new()) }));
}

/// Disarm fault injection; subsequent RPCs run clean.
pub fn disarm() {
    *CHAOS.lock().expect("chaos registry") = None;
}

/// The armed config, if any.
pub fn armed() -> Option<ChaosConfig> {
    CHAOS.lock().expect("chaos registry").as_ref().map(|s| s.config)
}

/// The fault (if any) ordered for the next RPC attempt against fleet
/// worker `widx`.  Always `None` while disarmed.
pub fn next_fault(widx: usize) -> Option<Fault> {
    let state = CHAOS.lock().expect("chaos registry").clone()?;
    let attempt = {
        let mut attempts = state.attempts.lock().expect("chaos attempts");
        if attempts.len() <= widx {
            attempts.resize(widx + 1, 0);
        }
        let n = attempts[widx];
        attempts[widx] += 1;
        n
    };
    let c = &state.config;
    let u = unit(c.seed, widx as u64, attempt);
    let mut edge = c.connect;
    if u < edge {
        return Some(Fault::Connect);
    }
    edge += c.read_timeout;
    if u < edge {
        return Some(Fault::ReadTimeout);
    }
    edge += c.write_timeout;
    if u < edge {
        return Some(Fault::WriteTimeout);
    }
    edge += c.slow;
    if u < edge {
        return Some(Fault::Slow(c.slow_ms));
    }
    edge += c.disconnect;
    if u < edge {
        return Some(Fault::Disconnect);
    }
    edge += c.garbage;
    if u < edge {
        return Some(Fault::Garbage);
    }
    None
}

/// The well-framed nonsense a `garbage` fault substitutes for the real
/// reply: valid JSON with a type no dispatch site accepts, so every
/// caller's structural validation must reject it (and quarantine the
/// worker) rather than panic or mis-merge.
pub(crate) fn garbage_reply() -> Json {
    Json::obj(vec![
        ("type".to_string(), Json::Str("chaos-garbage".to_string())),
        ("payload".to_string(), Json::Str("not a valid reply".to_string())),
    ])
}

/// splitmix64 finalizer — the standard 64-bit avalanche mix.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Hash `(seed, widx, attempt)` to a uniform value in `[0, 1)`.
fn unit(seed: u64, widx: u64, attempt: u64) -> f64 {
    let h = mix64(seed ^ mix64(widx) ^ mix64(attempt).rotate_left(17));
    (h >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests in this module: chaos state is
    /// process-global, and the lib test harness runs tests in parallel.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn parse_round_trips_every_key() {
        let c = ChaosConfig::parse(
            "seed=42,connect=0.1,read-timeout=0.2,write-timeout=0.05,slow=0.15,slow-ms=300,\
             disconnect=0.1,garbage=0.05",
        )
        .unwrap();
        assert_eq!(c.seed, 42);
        assert_eq!(c.connect, 0.1);
        assert_eq!(c.read_timeout, 0.2);
        assert_eq!(c.write_timeout, 0.05);
        assert_eq!(c.slow, 0.15);
        assert_eq!(c.slow_ms, 300);
        assert_eq!(c.disconnect, 0.1);
        assert_eq!(c.garbage, 0.05);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(ChaosConfig::parse("bogus=1").is_err());
        assert!(ChaosConfig::parse("connect").is_err());
        assert!(ChaosConfig::parse("connect=nope").is_err());
        assert!(ChaosConfig::parse("connect=1.5").is_err());
        assert!(ChaosConfig::parse("connect=-0.1").is_err());
        assert!(ChaosConfig::parse("seed=abc").is_err());
        // Rates must sum to at most 1.
        assert!(ChaosConfig::parse("connect=0.6,garbage=0.6").is_err());
        // The empty spec arms a no-fault schedule (still a valid arm).
        assert_eq!(ChaosConfig::parse("").unwrap(), ChaosConfig::default());
    }

    #[test]
    fn schedule_is_deterministic_per_seed_and_worker() {
        let _guard = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let config = ChaosConfig::parse("seed=7,connect=0.3,slow=0.3,garbage=0.1").unwrap();
        let draw = |widx: usize, n: usize| -> Vec<Option<Fault>> {
            arm(config);
            let faults = (0..n).map(|_| next_fault(widx)).collect();
            disarm();
            faults
        };
        // Re-arming replays the identical per-worker sequence.
        assert_eq!(draw(0, 64), draw(0, 64));
        assert_eq!(draw(3, 64), draw(3, 64));
        // Distinct workers see distinct schedules (with these rates, 64
        // identical draws by coincidence is a ~2^-64 event).
        assert_ne!(draw(0, 64), draw(1, 64));
        // A different seed reshuffles the schedule.
        arm(ChaosConfig { seed: 8, ..config });
        let other: Vec<Option<Fault>> = (0..64).map(|_| next_fault(0)).collect();
        disarm();
        assert_ne!(draw(0, 64), other);
    }

    #[test]
    fn rates_are_roughly_honored_and_zero_rate_is_silent() {
        let _guard = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        arm(ChaosConfig::parse("seed=1,connect=0.5").unwrap());
        let n = 2000;
        let hits = (0..n).filter(|_| next_fault(0) == Some(Fault::Connect)).count();
        disarm();
        // Loose 3-sigma-ish band around 0.5.
        assert!((800..1200).contains(&hits), "got {hits}/{n} connect faults");

        arm(ChaosConfig::default());
        assert!((0..500).all(|_| next_fault(0).is_none()));
        disarm();
    }

    #[test]
    fn disarmed_is_faultless() {
        let _guard = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        disarm();
        assert_eq!(next_fault(0), None);
        assert_eq!(armed(), None);
    }
}
