//! The worker side of the protocol: a sequential serve loop.
//!
//! A worker is stateless between requests — each accepted connection
//! carries one hello exchange, one request, and one response, then
//! closes.  Statelessness is what makes coordinator-side failure
//! handling trivial: there is no session to resynchronize, so the
//! coordinator can retire a worker at any point and re-run the shipped
//! work locally with no cleanup protocol.
//!
//! The loop is deliberately sequential (one request at a time): a
//! worker's unit of work is a whole subtree batch or simulation shard,
//! which already saturates the machine, and the coordinator never has
//! more than one request in flight per worker.  A request that fails —
//! bad handshake, malformed payload, invalid task — is answered with
//! an `error` message (when the stream still works) and logged; the
//! loop itself never dies to a bad peer.

use crate::net::frame::{recv_json, send_json};
use crate::net::proto::{check_hello, hello, report_to_json, sim_config_from_json, sim_from_json};
use crate::util::error::{anyhow, Result};
use crate::util::json::Json;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// A dead client must not wedge the serve loop.
const IO_TIMEOUT: Duration = Duration::from_secs(120);

/// Knobs for [`serve`].
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerOptions {
    /// Exit the loop after this many accepted connections (every
    /// connection counts, pings and failed handshakes included).
    /// `None` serves forever.  Tests use small values to simulate a
    /// worker dying mid-trace.
    pub max_requests: Option<usize>,
}

/// Accept and answer requests until `options.max_requests` runs out
/// (or forever).  Returns only on listener failure or request
/// exhaustion — per-request errors are logged and survived.
pub fn serve(listener: TcpListener, options: WorkerOptions) -> Result<()> {
    let mut served = 0usize;
    loop {
        if options.max_requests.is_some_and(|max| served >= max) {
            return Ok(());
        }
        let (mut stream, peer) = listener.accept()?;
        served += 1;
        if let Err(e) = handle(&mut stream) {
            eprintln!("worker: request from {peer} failed: {e:#}");
        }
    }
}

/// Bind an ephemeral loopback port and serve it on a background
/// thread.  Returns the address to hand to
/// [`set_workers`](crate::net::fleet::set_workers) and the thread
/// handle (which only finishes if `max_requests` is set).
pub fn spawn_local(max_requests: Option<usize>) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback worker");
    let addr = listener.local_addr().expect("loopback worker address").to_string();
    (addr, spawn_serve(listener, max_requests))
}

/// [`spawn_local`] on a *specific* address — restart-on-the-same-port
/// tests use this to bring a dead worker back where the fleet expects
/// it.  Returns an error if the address is still bound.
pub fn spawn_on(addr: &str, max_requests: Option<usize>) -> Result<std::thread::JoinHandle<()>> {
    let listener = TcpListener::bind(addr)?;
    Ok(spawn_serve(listener, max_requests))
}

fn spawn_serve(
    listener: TcpListener,
    max_requests: Option<usize>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        if let Err(e) = serve(listener, WorkerOptions { max_requests }) {
            eprintln!("loopback worker exited: {e:#}");
        }
    })
}

fn error_response(e: &crate::util::error::Error) -> Json {
    Json::obj(vec![
        ("type".to_string(), Json::Str("error".to_string())),
        ("message".to_string(), Json::Str(format!("{e:#}"))),
    ])
}

fn handle(stream: &mut TcpStream) -> Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    stream.set_nodelay(true)?;
    if let Err(e) = check_hello(&recv_json(stream)?) {
        let _ = send_json(stream, &error_response(&e));
        return Err(e);
    }
    send_json(stream, &hello())?;
    let request = recv_json(stream)?;
    match dispatch(&request) {
        Ok(response) => {
            send_json(stream, &response)?;
            // Wait (briefly) for the peer's close so the worker ends up
            // on the passive side of the TCP teardown: TIME_WAIT then
            // lands on the coordinator's ephemeral port, not on the
            // worker's listen port, and a worker that dies can restart
            // on the same address immediately.  The coordinator drops
            // its stream as soon as it has the reply, so this returns
            // in microseconds on the normal path.
            use std::io::Read;
            let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
            let _ = stream.read(&mut [0u8; 1]);
            Ok(())
        }
        Err(e) => {
            send_json(stream, &error_response(&e))?;
            Err(e)
        }
    }
}

fn dispatch(request: &Json) -> Result<Json> {
    match request.str_field("type")? {
        "ping" => Ok(Json::obj(vec![("type".to_string(), Json::Str("pong".to_string()))])),
        "exact" => crate::packing::exact::run_remote_exact(request),
        "simulate" => {
            let config = sim_config_from_json(request.field("config")?)?;
            let mut sim = sim_from_json(request.field("sim")?)?;
            let report = sim.run_engine(config);
            Ok(Json::obj(vec![
                ("type".to_string(), Json::Str("sim_result".to_string())),
                ("report".to_string(), report_to_json(&report)),
            ]))
        }
        other => Err(anyhow!("unknown request type {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::UtilizationMeter;
    use crate::net::proto::{report_from_json, sim_config_to_json, sim_to_json};
    use crate::sched::sim::{Device, StreamExec};
    use crate::sched::{SimConfig, Simulation};
    use std::collections::BTreeMap;

    fn request(addr: &str, req: &Json) -> Result<Json> {
        let mut stream = TcpStream::connect(addr)?;
        send_json(&mut stream, &hello())?;
        check_hello(&recv_json(&mut stream)?)?;
        send_json(&mut stream, req)?;
        recv_json(&mut stream)
    }

    fn tiny_sim() -> Simulation {
        let mut device_index = BTreeMap::new();
        device_index.insert((0, 0), 0);
        Simulation {
            devices: vec![Device { capacity: 4.0, meter: UtilizationMeter::new() }],
            device_index,
            device_names: vec![(0, "cpu".to_string())],
            streams: vec![StreamExec {
                instance: 0,
                gpu_index: None,
                desired_fps: 10.0,
                cpu_work: 0.05,
                gpu_work: 0.0,
                cpu_parallelism: 1.0,
                gpu_parallelism: 1.0,
                id: "s0".to_string(),
            }],
        }
    }

    #[test]
    fn loopback_worker_answers_ping_simulate_and_unknown() {
        let (addr, _handle) = spawn_local(Some(4));

        let ping = Json::obj(vec![("type".to_string(), Json::Str("ping".to_string()))]);
        let pong = request(&addr, &ping).unwrap();
        assert_eq!(pong.str_field("type").unwrap(), "pong");

        // A remote simulate must produce exactly what run_engine does
        // locally on the same shard.
        let config = SimConfig::for_duration(2.0);
        let mut local = tiny_sim();
        let expected = local.run_engine(config);
        let req = Json::obj(vec![
            ("type".to_string(), Json::Str("simulate".to_string())),
            ("config".to_string(), sim_config_to_json(&config)),
            ("sim".to_string(), sim_to_json(&tiny_sim())),
        ]);
        let reply = request(&addr, &req).unwrap();
        assert_eq!(reply.str_field("type").unwrap(), "sim_result");
        let report = report_from_json(reply.field("report").unwrap()).unwrap();
        assert_eq!(report.streams.len(), 1);
        assert_eq!(report.streams[0].achieved_fps, expected.streams[0].achieved_fps);
        assert_eq!(report.frames_completed, expected.frames_completed);
        assert_eq!(report.frames_dropped, expected.frames_dropped);

        // Unknown request types are answered with an error, and the
        // loop survives to answer the next connection.
        let bogus = Json::obj(vec![("type".to_string(), Json::Str("nonsense".to_string()))]);
        let reply = request(&addr, &bogus).unwrap();
        assert_eq!(reply.str_field("type").unwrap(), "error");
        let pong = request(&addr, &ping).unwrap();
        assert_eq!(pong.str_field("type").unwrap(), "pong");
    }

    #[test]
    fn worker_dies_after_max_requests() {
        let (addr, handle) = spawn_local(Some(1));
        let ping = Json::obj(vec![("type".to_string(), Json::Str("ping".to_string()))]);
        request(&addr, &ping).unwrap();
        // The serve loop has exhausted its budget; the thread joins
        // and the port stops answering.
        handle.join().unwrap();
        assert!(request(&addr, &ping).is_err());
    }
}
