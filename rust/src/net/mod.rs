//! Coordinator/worker distribution over plain TCP, with a
//! self-healing fleet.
//!
//! Threads ran out as a scaling axis (sharded simulation in PR 4,
//! parallel branch-and-bound in PR 8 both saturate one machine); this
//! module is the next rung: a zero-dependency wire protocol
//! (`std::net` + length-prefixed `util::json` frames) that ships work
//! to a fleet of `camcloud worker --listen ADDR` processes along the
//! two axes the codebase already made shardable —
//!
//! * **exact-search root subtree tasks** (`packing::exact`'s frontier
//!   unit): workers race batches of subtrees under the coordinator's
//!   incumbent and the results fold through the same strict
//!   `(cost, root index)` winner composition, so completed proofs are
//!   bit-identical to in-process search;
//! * **contiguous instance partitions for simulation**
//!   (`sched::shard`'s unit): per-shard `SimReport`s merge in
//!   instance-id order, which is partition-invariant, so fleet-sharded
//!   runs are bit-identical to local ones.
//!
//! Layering: [`frame`] moves length-prefixed JSON over a byte stream;
//! [`proto`] defines the handshake and the type encodings; [`fleet`]
//! is the coordinator's process-global worker registry and failure
//! model; [`chaos`] is the deterministic seeded fault injector that
//! exercises it; [`worker`] is the serve loop.
//!
//! **The failure lifecycle** (see [`fleet`] for detail): each RPC
//! classifies its failures — *transient* faults (connect refusal,
//! timeout, disconnect) retry with capped exponential backoff and
//! seeded jitter before tripping the worker's circuit breaker open;
//! *fatal* errors trip it immediately; *protocol violations* (garbage
//! replies) quarantine the worker for the run.  Open workers are
//! periodically re-probed with `ping` (half-open) and re-admitted on
//! success, so a worker that restarts mid-trace rejoins the fleet.
//! Straggling remote claims are hedged: past a multiple of the median
//! claim duration the coordinator re-runs the claim locally and takes
//! whichever result lands first.
//!
//! With no fleet registered (the default — no `--workers` flag) every
//! dispatch site runs its pre-existing local code path untouched, and
//! any worker failure mid-run degrades to exactly that path for the
//! affected work: workers *race*, they are never load-bearing.  That
//! is also why none of the above can change an outcome: every reply is
//! re-validated, winner folds are order-strict, and hedged duplicates
//! are resolved first-wins per already-deterministic slot.

pub mod chaos;
pub mod fleet;
pub mod frame;
pub mod proto;
pub mod worker;
