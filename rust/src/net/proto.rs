//! Wire protocol: handshake and JSON encodings of the shipped types.
//!
//! Every connection starts with a hello exchange carrying
//! [`PROTOCOL_VERSION`]; a version mismatch fails the handshake before
//! any work is shipped, so a stale worker binary degrades to "worker
//! lost" instead of silently mis-decoding requests.
//!
//! The encodings here cover what the two distribution axes ship:
//! MVBP problems and solutions (exact-search subtree batches — the
//! per-task search states themselves are encoded next to their private
//! types in `packing::exact`), and simulation shards with their
//! [`SimReport`]s.  Numbers ride as JSON numbers: `util::json` prints
//! `f64`s in shortest-round-trip form and parses them back with
//! correctly-rounded conversion, so every finite float survives the
//! wire bit-exactly — the foundation of the distributed determinism
//! guarantee.  [`Dollars`] travel as whole micro-dollar counts (always
//! far below 2^53); the `i64::MAX` "no incumbent" sentinel travels as
//! `null` because it is *not* representable in an `f64`.

use crate::packing::{BinType, Item, MvbpProblem, PackedBin, Solution};
use crate::metrics::{StreamPerf, UtilizationMeter};
use crate::sched::sim::{Device, SimConfig, StreamExec};
use crate::sched::{Parallelism, SimEngine, SimReport, Simulation};
use crate::types::{Dollars, ResourceVec};
use crate::util::error::{anyhow, ensure, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Version of the coordinator/worker wire protocol.  Bumped on any
/// encoding change; the handshake rejects mismatched peers.
pub const PROTOCOL_VERSION: u64 = 1;

/// The handshake message either peer sends.
pub fn hello() -> Json {
    Json::obj(vec![
        ("type".to_string(), Json::Str("hello".to_string())),
        ("version".to_string(), Json::Num(PROTOCOL_VERSION as f64)),
    ])
}

/// Validate a peer's handshake message.
pub fn check_hello(msg: &Json) -> Result<()> {
    let kind = msg.str_field("type")?;
    ensure!(kind == "hello", "expected hello, got {kind:?}");
    let version = msg.u64_field("version")?;
    ensure!(
        version == PROTOCOL_VERSION,
        "protocol version mismatch: peer speaks v{version}, this binary v{PROTOCOL_VERSION}"
    );
    Ok(())
}

/// Micro-dollar encoding; the `i64::MAX` no-incumbent sentinel is
/// `null` (it does not survive an `f64` round trip).
pub(crate) fn dollars_to_json(d: Dollars) -> Json {
    if d.0 == i64::MAX {
        Json::Null
    } else {
        Json::Num(d.0 as f64)
    }
}

pub(crate) fn dollars_from_json(j: &Json) -> Result<Dollars> {
    match j {
        Json::Null => Ok(Dollars(i64::MAX)),
        _ => {
            let micros = j.as_f64().ok_or_else(|| anyhow!("expected a micro-dollar number"))?;
            ensure!(
                micros.fract() == 0.0 && micros.abs() < 9e15,
                "micro-dollar count {micros} is not a whole in-range integer"
            );
            Ok(Dollars(micros as i64))
        }
    }
}

pub(crate) fn resources_to_json(v: &ResourceVec) -> Json {
    Json::arr(v.0.iter().map(|&x| Json::Num(x)))
}

pub(crate) fn resources_from_json(j: &Json, dims: usize) -> Result<ResourceVec> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("expected a resource vector array"))?;
    ensure!(arr.len() == dims, "resource vector has {} dims, expected {dims}", arr.len());
    let mut out = Vec::with_capacity(dims);
    for x in arr {
        out.push(x.as_f64().ok_or_else(|| anyhow!("resource vector entry is not a number"))?);
    }
    Ok(ResourceVec::from_slice(&out))
}

fn index_field(j: &Json, key: &str) -> Result<usize> {
    Ok(j.u64_field(key)? as usize)
}

// ---------------------------------------------------------------- MVBP

/// Encode a full MVBP problem (bin types, items with per-choice
/// requirement vectors, optional per-choice costs).
pub fn problem_to_json(problem: &MvbpProblem) -> Json {
    Json::obj(vec![
        ("dims".to_string(), Json::Num(problem.dims as f64)),
        (
            "bin_types".to_string(),
            Json::arr(problem.bin_types.iter().map(|bt| {
                Json::obj(vec![
                    ("name".to_string(), Json::Str(bt.name.clone())),
                    ("cost".to_string(), dollars_to_json(bt.cost)),
                    ("capacity".to_string(), resources_to_json(&bt.capacity)),
                ])
            })),
        ),
        (
            "items".to_string(),
            Json::arr(problem.items.iter().map(|item| {
                Json::obj(vec![
                    ("id".to_string(), Json::Str(item.id.clone())),
                    (
                        "choices".to_string(),
                        Json::arr(item.choices.iter().map(resources_to_json)),
                    ),
                ])
            })),
        ),
        (
            "choice_costs".to_string(),
            Json::arr(
                problem
                    .choice_costs
                    .iter()
                    .map(|costs| Json::arr(costs.iter().map(|&c| dollars_to_json(c)))),
            ),
        ),
    ])
}

/// Decode and validate an MVBP problem.
pub fn problem_from_json(j: &Json) -> Result<MvbpProblem> {
    let dims = index_field(j, "dims")?;
    let mut bin_types = Vec::new();
    for bt in j.arr_field("bin_types")? {
        bin_types.push(BinType {
            name: bt.str_field("name")?.to_string(),
            cost: dollars_from_json(bt.field("cost")?)?,
            capacity: resources_from_json(bt.field("capacity")?, dims)?,
        });
    }
    let mut items = Vec::new();
    for item in j.arr_field("items")? {
        let mut choices = Vec::new();
        for c in item.arr_field("choices")? {
            choices.push(resources_from_json(c, dims)?);
        }
        items.push(Item { id: item.str_field("id")?.to_string(), choices });
    }
    let mut choice_costs = Vec::new();
    for costs in j.arr_field("choice_costs")? {
        let arr = costs.as_arr().ok_or_else(|| anyhow!("choice_costs row is not an array"))?;
        let mut row = Vec::with_capacity(arr.len());
        for c in arr {
            row.push(dollars_from_json(c)?);
        }
        choice_costs.push(row);
    }
    let problem = MvbpProblem { dims, bin_types, items, choice_costs };
    problem.validate().map_err(|e| anyhow!("decoded problem is invalid: {e:#}"))?;
    Ok(problem)
}

/// Encode a packing solution (bin type + `(item, choice)` assignments
/// per bin).
pub fn solution_to_json(solution: &Solution) -> Json {
    Json::arr(solution.bins.iter().map(|bin| {
        Json::obj(vec![
            ("bin_type".to_string(), Json::Num(bin.bin_type as f64)),
            (
                "assignments".to_string(),
                Json::arr(bin.assignments.iter().map(|&(item, choice)| {
                    Json::arr(vec![Json::Num(item as f64), Json::Num(choice as f64)])
                })),
            ),
        ])
    }))
}

/// Decode a packing solution (structural only — callers validate
/// against their problem before trusting it).
pub fn solution_from_json(j: &Json) -> Result<Solution> {
    let mut bins = Vec::new();
    for bin in j.as_arr().ok_or_else(|| anyhow!("expected a solution array"))? {
        let mut assignments = Vec::new();
        for pair in bin.arr_field("assignments")? {
            let pair = pair.as_arr().ok_or_else(|| anyhow!("assignment is not a pair"))?;
            ensure!(pair.len() == 2, "assignment pair has {} entries", pair.len());
            let item = pair[0].as_u64().ok_or_else(|| anyhow!("assignment item index"))?;
            let choice = pair[1].as_u64().ok_or_else(|| anyhow!("assignment choice index"))?;
            assignments.push((item as usize, choice as usize));
        }
        bins.push(PackedBin { bin_type: index_field(bin, "bin_type")?, assignments });
    }
    Ok(Solution { bins })
}

// ---------------------------------------------------------- simulation

/// Encode a (sub-)simulation: device capacities and their
/// `(instance, slot)` index, plus the per-stream execution parameters.
/// Utilization meters are *not* shipped — the receiver starts fresh
/// ones, exactly like `sched::shard::extract` does for local shards.
pub(crate) fn sim_to_json(sim: &Simulation) -> Json {
    Json::obj(vec![
        (
            "devices".to_string(),
            Json::arr(sim.devices.iter().map(|d| Json::Num(d.capacity))),
        ),
        (
            "device_index".to_string(),
            Json::arr(sim.device_index.iter().map(|(&(inst, slot), &dev)| {
                Json::arr(vec![
                    Json::Num(inst as f64),
                    Json::Num(slot as f64),
                    Json::Num(dev as f64),
                ])
            })),
        ),
        (
            "device_names".to_string(),
            Json::arr(sim.device_names.iter().map(|(inst, name)| {
                Json::arr(vec![Json::Num(*inst as f64), Json::Str(name.clone())])
            })),
        ),
        (
            "streams".to_string(),
            Json::arr(sim.streams.iter().map(|s| {
                Json::obj(vec![
                    ("instance".to_string(), Json::Num(s.instance as f64)),
                    (
                        "gpu_index".to_string(),
                        s.gpu_index.map_or(Json::Null, |g| Json::Num(g as f64)),
                    ),
                    ("desired_fps".to_string(), Json::Num(s.desired_fps)),
                    ("cpu_work".to_string(), Json::Num(s.cpu_work)),
                    ("gpu_work".to_string(), Json::Num(s.gpu_work)),
                    ("cpu_parallelism".to_string(), Json::Num(s.cpu_parallelism)),
                    ("gpu_parallelism".to_string(), Json::Num(s.gpu_parallelism)),
                    ("id".to_string(), Json::Str(s.id.clone())),
                ])
            })),
        ),
    ])
}

/// Decode a (sub-)simulation, starting fresh utilization meters.
pub(crate) fn sim_from_json(j: &Json) -> Result<Simulation> {
    let devices: Vec<Device> = j
        .arr_field("devices")?
        .iter()
        .map(|d| {
            d.as_f64()
                .map(|capacity| Device { capacity, meter: UtilizationMeter::new() })
                .ok_or_else(|| anyhow!("device capacity is not a number"))
        })
        .collect::<Result<_>>()?;
    let mut device_index = BTreeMap::new();
    for row in j.arr_field("device_index")? {
        let row = row.as_arr().ok_or_else(|| anyhow!("device_index row is not an array"))?;
        ensure!(row.len() == 3, "device_index row has {} entries", row.len());
        let triple: Vec<usize> = row
            .iter()
            .map(|x| x.as_u64().map(|v| v as usize))
            .collect::<Option<_>>()
            .ok_or_else(|| anyhow!("device_index entry is not an index"))?;
        ensure!(triple[2] < devices.len(), "device_index points past the device table");
        device_index.insert((triple[0], triple[1]), triple[2]);
    }
    let mut device_names = Vec::new();
    for row in j.arr_field("device_names")? {
        let row = row.as_arr().ok_or_else(|| anyhow!("device_names row is not an array"))?;
        ensure!(row.len() == 2, "device_names row has {} entries", row.len());
        let inst = row[0].as_u64().ok_or_else(|| anyhow!("device_names instance index"))?;
        let name = row[1].as_str().ok_or_else(|| anyhow!("device name is not a string"))?;
        device_names.push((inst as usize, name.to_string()));
    }
    let mut streams = Vec::new();
    for s in j.arr_field("streams")? {
        streams.push(StreamExec {
            instance: index_field(s, "instance")?,
            gpu_index: match s.field("gpu_index")? {
                Json::Null => None,
                g => Some(g.as_u64().ok_or_else(|| anyhow!("gpu_index is not an index"))? as usize),
            },
            desired_fps: s.f64_field("desired_fps")?,
            cpu_work: s.f64_field("cpu_work")?,
            gpu_work: s.f64_field("gpu_work")?,
            cpu_parallelism: s.f64_field("cpu_parallelism")?,
            gpu_parallelism: s.f64_field("gpu_parallelism")?,
            id: s.str_field("id")?.to_string(),
        });
    }
    Ok(Simulation { devices, device_index, device_names, streams })
}

/// Encode the simulation config a shard runs under.  Parallelism knobs
/// are not shipped: the worker runs its shard unsharded
/// (`run_engine`), exactly like a local shard thread.
pub fn sim_config_to_json(config: &SimConfig) -> Json {
    Json::obj(vec![
        ("duration_s".to_string(), Json::Num(config.duration_s)),
        ("dt".to_string(), Json::Num(config.dt)),
        ("queue_cap".to_string(), Json::Num(config.queue_cap as f64)),
        ("engine".to_string(), Json::Str(config.engine.to_string())),
    ])
}

pub fn sim_config_from_json(j: &Json) -> Result<SimConfig> {
    let engine: SimEngine = j
        .str_field("engine")?
        .parse()
        .map_err(|e: String| anyhow!("{e}"))?;
    Ok(SimConfig {
        duration_s: j.f64_field("duration_s")?,
        dt: j.f64_field("dt")?,
        queue_cap: index_field(j, "queue_cap")?,
        engine,
        parallelism: Parallelism { sim_threads: 1, pipeline: false },
    })
}

/// Encode a shard's simulation report.
pub fn report_to_json(report: &SimReport) -> Json {
    Json::obj(vec![
        (
            "streams".to_string(),
            Json::arr(report.streams.iter().map(|p| {
                Json::obj(vec![
                    ("stream_id".to_string(), Json::Str(p.stream_id.clone())),
                    ("desired_fps".to_string(), Json::Num(p.desired_fps)),
                    ("achieved_fps".to_string(), Json::Num(p.achieved_fps)),
                ])
            })),
        ),
        (
            "device_utilization".to_string(),
            Json::arr(report.device_utilization.iter().map(|((inst, name), (mean, peak))| {
                Json::arr(vec![
                    Json::Num(*inst as f64),
                    Json::Str(name.clone()),
                    Json::Num(*mean),
                    Json::Num(*peak),
                ])
            })),
        ),
        ("frames_completed".to_string(), Json::Num(report.frames_completed as f64)),
        ("frames_dropped".to_string(), Json::Num(report.frames_dropped as f64)),
        ("duration_s".to_string(), Json::Num(report.duration_s)),
    ])
}

pub fn report_from_json(j: &Json) -> Result<SimReport> {
    let mut streams = Vec::new();
    for p in j.arr_field("streams")? {
        streams.push(StreamPerf {
            stream_id: p.str_field("stream_id")?.to_string(),
            desired_fps: p.f64_field("desired_fps")?,
            achieved_fps: p.f64_field("achieved_fps")?,
        });
    }
    let mut device_utilization = BTreeMap::new();
    for row in j.arr_field("device_utilization")? {
        let row = row.as_arr().ok_or_else(|| anyhow!("utilization row is not an array"))?;
        ensure!(row.len() == 4, "utilization row has {} entries", row.len());
        let inst = row[0].as_u64().ok_or_else(|| anyhow!("utilization instance index"))?;
        let name = row[1].as_str().ok_or_else(|| anyhow!("utilization device name"))?;
        let mean = row[2].as_f64().ok_or_else(|| anyhow!("utilization mean"))?;
        let peak = row[3].as_f64().ok_or_else(|| anyhow!("utilization peak"))?;
        device_utilization.insert((inst as usize, name.to_string()), (mean, peak));
    }
    Ok(SimReport {
        streams,
        device_utilization,
        frames_completed: j.u64_field("frames_completed")?,
        frames_dropped: j.u64_field("frames_dropped")?,
        duration_s: j.f64_field("duration_s")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_problem() -> MvbpProblem {
        MvbpProblem {
            dims: 2,
            bin_types: vec![BinType {
                name: "big".into(),
                cost: Dollars::from_f64(1.8),
                capacity: ResourceVec::from_slice(&[8.0, 4.5]),
            }],
            items: vec![Item {
                id: "s0".into(),
                choices: vec![
                    ResourceVec::from_slice(&[1.25, 0.0]),
                    ResourceVec::from_slice(&[0.4, 2.0]),
                ],
            }],
            choice_costs: vec![vec![Dollars::ZERO, Dollars::from_f64(0.01)]],
        }
    }

    #[test]
    fn hello_round_trips_and_rejects_other_versions() {
        check_hello(&hello()).unwrap();
        let stale = Json::obj(vec![
            ("type".to_string(), Json::Str("hello".to_string())),
            ("version".to_string(), Json::Num(999.0)),
        ]);
        assert!(check_hello(&stale).is_err());
    }

    #[test]
    fn dollars_round_trip_including_the_sentinel() {
        for d in [Dollars::ZERO, Dollars(123_456), Dollars(-42), Dollars(i64::MAX)] {
            let j = dollars_to_json(d);
            let text = j.to_compact();
            let back = dollars_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, d);
        }
        assert_eq!(dollars_to_json(Dollars(i64::MAX)), Json::Null);
    }

    #[test]
    fn problem_round_trips_bit_exactly() {
        let problem = sample_problem();
        let text = problem_to_json(&problem).to_compact();
        let back = problem_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.dims, problem.dims);
        assert_eq!(back.bin_types.len(), problem.bin_types.len());
        assert_eq!(back.bin_types[0].cost, problem.bin_types[0].cost);
        assert_eq!(back.bin_types[0].capacity.0, problem.bin_types[0].capacity.0);
        assert_eq!(back.items[0].choices[1].0, problem.items[0].choices[1].0);
        assert_eq!(back.choice_costs, problem.choice_costs);
    }

    #[test]
    fn solution_round_trips() {
        let solution = Solution {
            bins: vec![PackedBin { bin_type: 0, assignments: vec![(0, 1), (2, 0)] }],
        };
        let text = solution_to_json(&solution).to_compact();
        let back = solution_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, solution);
    }

    #[test]
    fn invalid_decoded_problem_is_rejected() {
        let mut j = problem_to_json(&sample_problem());
        if let Json::Obj(map) = &mut j {
            map.insert("dims".to_string(), Json::Num(7.0)); // capacity dims no longer match
        }
        assert!(problem_from_json(&j).is_err());
    }
}
