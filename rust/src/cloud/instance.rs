//! Provisioned-instance lifecycle.

use super::catalog::{InstanceType, PricingTier};
use crate::types::{DimLayout, ResourceVec};

/// Opaque instance identifier, unique per provisioning session.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct InstanceId(pub u32);

impl std::fmt::Display for InstanceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "i-{:04}", self.0)
    }
}

/// Lifecycle state of a simulated instance.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum InstanceState {
    /// Provision requested, booting (cloud boot latency).
    Provisioning,
    /// Serving assigned streams.
    Running,
    /// Terminated; no longer billed after the current hour.
    Terminated,
}

/// One provisioned cloud instance.
#[derive(Clone, Debug)]
pub struct SimInstance {
    pub id: InstanceId,
    pub itype: InstanceType,
    /// Lease tier the instance was purchased under (plain catalog
    /// names provision as on-demand; see [`crate::cloud::Offering`]).
    pub tier: PricingTier,
    pub state: InstanceState,
    /// Simulation time (seconds) at which the instance started billing.
    pub started_at: f64,
    /// Simulation time at which it terminated (if it did).
    pub terminated_at: Option<f64>,
}

impl SimInstance {
    pub fn new(id: InstanceId, itype: InstanceType, now: f64) -> Self {
        SimInstance {
            id,
            itype,
            tier: PricingTier::OnDemand,
            state: InstanceState::Provisioning,
            started_at: now,
            terminated_at: None,
        }
    }

    pub fn mark_running(&mut self) {
        assert_eq!(self.state, InstanceState::Provisioning);
        self.state = InstanceState::Running;
    }

    pub fn terminate(&mut self, now: f64) {
        if self.state != InstanceState::Terminated {
            self.state = InstanceState::Terminated;
            self.terminated_at = Some(now);
        }
    }

    /// Usable capacity after the paper's 90% headroom rule.
    pub fn usable_capacity(&self, layout: DimLayout, headroom: f64) -> ResourceVec {
        self.itype.capability(layout).scale(headroom)
    }

    /// Billable seconds in `[self.started_at, now]`.
    pub fn billable_seconds(&self, now: f64) -> f64 {
        let end = self.terminated_at.unwrap_or(now);
        (end - self.started_at).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::catalog::Catalog;

    fn inst() -> SimInstance {
        let t = Catalog::aws_table1().get("c4.2xlarge").unwrap().clone();
        SimInstance::new(InstanceId(1), t, 100.0)
    }

    #[test]
    fn lifecycle() {
        let mut i = inst();
        assert_eq!(i.state, InstanceState::Provisioning);
        i.mark_running();
        assert_eq!(i.state, InstanceState::Running);
        i.terminate(200.0);
        assert_eq!(i.state, InstanceState::Terminated);
        assert_eq!(i.terminated_at, Some(200.0));
        // Idempotent terminate.
        i.terminate(300.0);
        assert_eq!(i.terminated_at, Some(200.0));
    }

    #[test]
    fn billable_seconds() {
        let mut i = inst();
        assert_eq!(i.billable_seconds(160.0), 60.0);
        i.terminate(130.0);
        assert_eq!(i.billable_seconds(1000.0), 30.0);
    }

    #[test]
    fn usable_capacity_headroom() {
        let i = inst();
        let cap = i.usable_capacity(crate::types::DimLayout::new(0), 0.9);
        assert!((cap[0] - 7.2).abs() < 1e-12);
        assert!((cap[1] - 13.5).abs() < 1e-12);
    }

    #[test]
    fn display_id() {
        assert_eq!(InstanceId(7).to_string(), "i-0007");
    }
}
