//! Hourly billing meter over the simulation clock, with per-tier
//! lease semantics.
//!
//! Implements the pay-as-you-go model the paper relies on (§1): each
//! instance bills its hourly cost for every *started* hour between
//! provisioning and termination (classic EC2 semantics).
//!
//! # Started-hour semantics across reallocation epochs
//!
//! The meter is the reason churn has a real price in the autoscaling
//! subsystem (`workload::trace` + `coordinator::autoscale`):
//!
//! * provisioning an instance immediately bills its first hour, even if
//!   it is terminated seconds later — flapping between fleets is never
//!   free;
//! * an instance *kept* across consecutive epochs accumulates one
//!   continuous span, so `ceil` rounding is paid once at termination
//!   rather than once per epoch — keeping a fleet for two half-hour
//!   epochs costs one hour, while terminating and re-provisioning at
//!   the epoch boundary costs two;
//! * terminating mid-hour wastes the remainder of the started hour,
//!   which is exactly the waste the
//!   [`worth_reallocating`](crate::manager::realloc::worth_reallocating)
//!   hysteresis gate weighs against horizon savings.
//!
//! # Per-tier lease semantics
//!
//! Each record carries the [`PricingTier`] its instance was purchased
//! under (see [`crate::cloud::Offering`]); the tier changes *when*
//! hours are charged, never the effective hourly rate (which is baked
//! into the offering's `hourly_cost`):
//!
//! * **OnDemand** — the paper's model: `ceil` started hours from
//!   provision to termination, minimum one hour.
//! * **Reserved** — a commitment: billed from provision to the
//!   settlement horizon `now` *regardless of termination*.  Churning a
//!   reserved instance away early saves nothing; the discount is paid
//!   for with inflexibility.
//! * **Spot** — billed like on-demand while it runs, but when the
//!   vendor revokes it ([`BillingMeter::on_revoke`]) the interrupted
//!   partial hour is *not* charged: `floor` full hours only, possibly
//!   zero.  Voluntary termination of a spot instance still pays the
//!   started hour.
//!
//! Cross-region data-transfer charges are metered separately via
//! [`BillingMeter::add_transfer`] and folded into the settlement
//! total.
//!
//! One meter therefore spans a whole trace run: records open at each
//! provision, close at each terminate or revoke, and
//! [`BillingMeter::total_cost`] prices the union at settlement.
//! [`BillingMeter::hourly_rate`] is the *run-rate* view — the combined
//! hourly cost of instances running at an instant — and is
//! well-defined mid-simulation even for records whose termination has
//! already been written with a later timestamp.

use super::catalog::{InstanceType, PricingTier};
use super::instance::{InstanceId, SimInstance};
use crate::types::Dollars;
use std::collections::BTreeMap;

/// One instance's usage span and the lease it was purchased under.
#[derive(Clone, Debug)]
struct BillingRecord {
    itype: InstanceType,
    tier: PricingTier,
    start: f64,
    end: Option<f64>,
    revoked: bool,
}

impl BillingRecord {
    /// Billed hours for this record at settlement time `now`.
    fn hours(&self, now: f64) -> u32 {
        match self.tier {
            PricingTier::Reserved => {
                // Commitment: start -> settlement horizon, regardless
                // of early termination.
                BillingMeter::billed_hours(now - self.start)
            }
            PricingTier::OnDemand => {
                BillingMeter::billed_hours(self.end.unwrap_or(now) - self.start)
            }
            PricingTier::Spot => {
                let span = self.end.unwrap_or(now) - self.start;
                if self.revoked {
                    // Vendor interruption: only completed hours are
                    // charged; a revocation inside the first hour is
                    // free.
                    (span.max(0.0) / 3600.0).floor() as u32
                } else {
                    BillingMeter::billed_hours(span)
                }
            }
        }
    }

    fn cost(&self, now: f64) -> Dollars {
        self.itype.hourly_cost * self.hours(now)
    }
}

/// Accumulates per-instance usage and prices it.
#[derive(Default, Debug)]
pub struct BillingMeter {
    records: BTreeMap<InstanceId, BillingRecord>,
    /// Accumulated cross-region data-transfer charges.
    transfer: Dollars,
}

impl BillingMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_provision(&mut self, inst: &SimInstance) {
        self.records.insert(
            inst.id,
            BillingRecord {
                itype: inst.itype.clone(),
                tier: inst.tier,
                start: inst.started_at,
                end: None,
                revoked: false,
            },
        );
    }

    /// Close a record at `now`.  Idempotent: once a span has ended —
    /// by termination or revocation — later calls never move it, so
    /// an instance can never be double-charged for one span.
    pub fn on_terminate(&mut self, id: InstanceId, now: f64) {
        if let Some(rec) = self.records.get_mut(&id) {
            if rec.end.is_none() {
                rec.end = Some(now.max(rec.start));
            }
        }
    }

    /// Vendor revocation of a spot instance at `now`: closes the span
    /// and marks it interrupted, which forgives the partial hour.  A
    /// record that already ended is left untouched.
    pub fn on_revoke(&mut self, id: InstanceId, now: f64) {
        if let Some(rec) = self.records.get_mut(&id) {
            if rec.end.is_none() {
                rec.end = Some(now.max(rec.start));
                rec.revoked = true;
            }
        }
    }

    /// Accrue a cross-region data-transfer charge.
    pub fn add_transfer(&mut self, amount: Dollars) {
        debug_assert!(amount >= Dollars::ZERO, "transfer charges are non-negative");
        self.transfer = self.transfer + amount;
    }

    /// Accumulated transfer charges so far.
    pub fn transfer_cost(&self) -> Dollars {
        self.transfer
    }

    /// Billed started-hours for a usage span.
    fn billed_hours(seconds: f64) -> u32 {
        if seconds <= 0.0 {
            // Provisioned at all -> first hour billed.
            1
        } else {
            (seconds / 3600.0).ceil().max(1.0) as u32
        }
    }

    /// Total cost of all usage up to `now`, including transfer fees.
    pub fn total_cost(&self, now: f64) -> Dollars {
        self.records.values().map(|rec| rec.cost(now)).sum::<Dollars>() + self.transfer
    }

    /// `(instance, billed hours, cost)` per record up to `now` — the
    /// per-instance breakdown of [`BillingMeter::total_cost`] (minus
    /// transfer fees, which are not attributable to one instance).
    pub fn per_instance(&self, now: f64) -> Vec<(InstanceId, u32, Dollars)> {
        self.records
            .iter()
            .map(|(id, rec)| (*id, rec.hours(now), rec.cost(now)))
            .collect()
    }

    /// Combined hourly run-rate of instances running at `now`: started
    /// at or before `now` and not terminated until strictly after it.
    /// A record whose `end` is already written with a *later* timestamp
    /// still counts — mid-simulation queries must see it running.
    /// Reserved commitments keep billing after termination, so they
    /// count whenever they have started.
    pub fn hourly_rate(&self, now: f64) -> Dollars {
        self.records
            .values()
            .filter(|rec| {
                rec.start <= now
                    && (rec.tier == PricingTier::Reserved
                        || rec.end.map_or(true, |e| e > now))
            })
            .map(|rec| rec.itype.hourly_cost)
            .sum()
    }

    pub fn instance_count(&self) -> usize {
        self.records.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::catalog::Catalog;

    fn meter_with(id: u32, type_name: &str, start: f64) -> (BillingMeter, SimInstance) {
        let t = Catalog::aws_table1().get(type_name).unwrap().clone();
        let inst = SimInstance::new(InstanceId(id), t, start);
        let mut m = BillingMeter::new();
        m.on_provision(&inst);
        (m, inst)
    }

    fn tiered(id: u32, tier: PricingTier, start: f64) -> SimInstance {
        let t = Catalog::aws_table1().get("c4.2xlarge").unwrap().clone();
        let mut inst = SimInstance::new(InstanceId(id), t, start);
        inst.tier = tier;
        inst
    }

    #[test]
    fn first_hour_billed_immediately() {
        let (m, _) = meter_with(1, "c4.2xlarge", 0.0);
        assert_eq!(m.total_cost(1.0), Dollars::from_f64(0.419));
    }

    #[test]
    fn started_hours_round_up() {
        let (mut m, _) = meter_with(1, "g2.2xlarge", 0.0);
        m.on_terminate(InstanceId(1), 3601.0); // 1h + 1s -> 2 hours
        assert_eq!(m.total_cost(10_000.0), Dollars::from_f64(1.300));
    }

    #[test]
    fn per_instance_breakdown_sums_to_total() {
        let (mut m, _) = meter_with(1, "c4.2xlarge", 0.0);
        let t2 = Catalog::aws_table1().get("g2.2xlarge").unwrap().clone();
        m.on_provision(&SimInstance::new(InstanceId(2), t2, 0.0));
        m.on_terminate(InstanceId(2), 3601.0); // 2 started hours
        let per = m.per_instance(100.0);
        assert_eq!(per.len(), 2);
        assert_eq!(per[0], (InstanceId(1), 1, Dollars::from_f64(0.419)));
        assert_eq!(per[1], (InstanceId(2), 2, Dollars::from_f64(1.300)));
        let total: Dollars = per.iter().map(|(_, _, c)| *c).sum();
        assert_eq!(total, m.total_cost(100.0));
    }

    #[test]
    fn hourly_rate_counts_only_running() {
        let (mut m, _) = meter_with(1, "c4.2xlarge", 0.0);
        let t2 = Catalog::aws_table1().get("g2.2xlarge").unwrap().clone();
        let i2 = SimInstance::new(InstanceId(2), t2, 0.0);
        m.on_provision(&i2);
        assert_eq!(m.hourly_rate(10.0), Dollars::from_f64(1.069));
        m.on_terminate(InstanceId(1), 20.0);
        assert_eq!(m.hourly_rate(30.0), Dollars::from_f64(0.650));
        assert_eq!(m.instance_count(), 2);
    }

    #[test]
    fn hourly_rate_counts_instances_terminating_later() {
        // Regression: a record whose end is already written must still
        // count toward the run-rate at times *before* that end.  The
        // pre-fix filter (`end.is_none()`) excluded it, under-reporting
        // mid-simulation run-rate queries.
        let (mut m, _) = meter_with(1, "c4.2xlarge", 0.0);
        m.on_terminate(InstanceId(1), 20.0);
        assert_eq!(m.hourly_rate(10.0), Dollars::from_f64(0.419));
        // At the termination instant and after it, the instance is gone.
        assert_eq!(m.hourly_rate(20.0), Dollars::ZERO);
        assert_eq!(m.hourly_rate(25.0), Dollars::ZERO);
        // Not-yet-started instances never count.
        let (m2, _) = meter_with(2, "g2.2xlarge", 50.0);
        assert_eq!(m2.hourly_rate(10.0), Dollars::ZERO);
    }

    #[test]
    fn terminate_is_idempotent() {
        let (mut m, _) = meter_with(1, "c4.2xlarge", 0.0);
        m.on_terminate(InstanceId(1), 1800.0); // 1 started hour
        m.on_terminate(InstanceId(1), 7200.0); // must not extend the span
        assert_eq!(m.total_cost(10_000.0), Dollars::from_f64(0.419));
        // A late revoke of an already-closed record changes nothing.
        m.on_revoke(InstanceId(1), 9000.0);
        assert_eq!(m.total_cost(10_000.0), Dollars::from_f64(0.419));
    }

    #[test]
    fn reserved_commitment_billed_regardless_of_churn() {
        let mut m = BillingMeter::new();
        m.on_provision(&tiered(1, PricingTier::Reserved, 0.0));
        // Terminated after 30 minutes, but the commitment runs to the
        // settlement horizon: 2 started hours at t = 2h - 1s.
        m.on_terminate(InstanceId(1), 1800.0);
        assert_eq!(m.total_cost(7199.0), Dollars::from_f64(0.838));
        // Still on the books for run-rate purposes.
        assert_eq!(m.hourly_rate(3600.0), Dollars::from_f64(0.419));
    }

    #[test]
    fn spot_revocation_forgives_partial_hour() {
        let mut m = BillingMeter::new();
        m.on_provision(&tiered(1, PricingTier::Spot, 0.0));
        m.on_provision(&tiered(2, PricingTier::Spot, 0.0));
        // Revoked inside the first hour: free.
        m.on_revoke(InstanceId(1), 1800.0);
        // Revoked after 1h30: only the completed hour is charged.
        m.on_revoke(InstanceId(2), 5400.0);
        let per = m.per_instance(10_000.0);
        assert_eq!(per[0], (InstanceId(1), 0, Dollars::ZERO));
        assert_eq!(per[1], (InstanceId(2), 1, Dollars::from_f64(0.419)));
        // Voluntary termination of spot still pays the started hour.
        let mut m2 = BillingMeter::new();
        m2.on_provision(&tiered(3, PricingTier::Spot, 0.0));
        m2.on_terminate(InstanceId(3), 1800.0);
        assert_eq!(m2.total_cost(10_000.0), Dollars::from_f64(0.419));
    }

    #[test]
    fn transfer_charges_fold_into_total() {
        let (mut m, _) = meter_with(1, "c4.2xlarge", 0.0);
        m.add_transfer(Dollars::from_f64(0.010));
        m.add_transfer(Dollars::from_f64(0.005));
        assert_eq!(m.transfer_cost(), Dollars::from_f64(0.015));
        assert_eq!(m.total_cost(1.0), Dollars::from_f64(0.434));
    }
}
