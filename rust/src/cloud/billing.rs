//! Hourly billing meter over the simulation clock.
//!
//! Implements the pay-as-you-go model the paper relies on (§1): each
//! instance bills its hourly cost for every *started* hour between
//! provisioning and termination (classic EC2 semantics).
//!
//! # Started-hour semantics across reallocation epochs
//!
//! The meter is the reason churn has a real price in the autoscaling
//! subsystem (`workload::trace` + `coordinator::autoscale`):
//!
//! * provisioning an instance immediately bills its first hour, even if
//!   it is terminated seconds later — flapping between fleets is never
//!   free;
//! * an instance *kept* across consecutive epochs accumulates one
//!   continuous span, so `ceil` rounding is paid once at termination
//!   rather than once per epoch — keeping a fleet for two half-hour
//!   epochs costs one hour, while terminating and re-provisioning at
//!   the epoch boundary costs two;
//! * terminating mid-hour wastes the remainder of the started hour,
//!   which is exactly the waste the
//!   [`worth_reallocating`](crate::manager::realloc::worth_reallocating)
//!   hysteresis gate weighs against horizon savings.
//!
//! One meter therefore spans a whole trace run: records open at each
//! provision, close at each terminate, and [`BillingMeter::total_cost`]
//! prices the union at settlement.  [`BillingMeter::hourly_rate`] is the
//! *run-rate* view — the combined hourly cost of instances running at an
//! instant — and is well-defined mid-simulation even for records whose
//! termination has already been written with a later timestamp.

use super::catalog::InstanceType;
use super::instance::{InstanceId, SimInstance};
use crate::types::Dollars;
use std::collections::BTreeMap;

/// Accumulates per-instance usage and prices it.
#[derive(Default, Debug)]
pub struct BillingMeter {
    records: BTreeMap<InstanceId, (InstanceType, f64, Option<f64>)>,
}

impl BillingMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_provision(&mut self, inst: &SimInstance) {
        self.records
            .insert(inst.id, (inst.itype.clone(), inst.started_at, None));
    }

    pub fn on_terminate(&mut self, id: InstanceId, now: f64) {
        if let Some((_, start, end)) = self.records.get_mut(&id) {
            *end = Some(now.max(*start));
        }
    }

    /// Billed started-hours for a usage span.
    fn billed_hours(seconds: f64) -> u32 {
        if seconds <= 0.0 {
            // Provisioned at all -> first hour billed.
            1
        } else {
            (seconds / 3600.0).ceil().max(1.0) as u32
        }
    }

    /// Total cost of all usage up to `now`.
    pub fn total_cost(&self, now: f64) -> Dollars {
        self.records
            .values()
            .map(|(itype, start, end)| {
                let span = end.unwrap_or(now) - start;
                itype.hourly_cost * Self::billed_hours(span)
            })
            .sum()
    }

    /// `(instance, billed hours, cost)` per record up to `now` — the
    /// per-instance breakdown of [`BillingMeter::total_cost`].
    pub fn per_instance(&self, now: f64) -> Vec<(InstanceId, u32, Dollars)> {
        self.records
            .iter()
            .map(|(id, (itype, start, end))| {
                let span = end.unwrap_or(now) - start;
                let hours = Self::billed_hours(span);
                (*id, hours, itype.hourly_cost * hours)
            })
            .collect()
    }

    /// Combined hourly run-rate of instances running at `now`: started
    /// at or before `now` and not terminated until strictly after it.
    /// A record whose `end` is already written with a *later* timestamp
    /// still counts — mid-simulation queries must see it running.
    pub fn hourly_rate(&self, now: f64) -> Dollars {
        self.records
            .values()
            .filter(|(_, start, end)| *start <= now && end.map_or(true, |e| e > now))
            .map(|(itype, _, _)| itype.hourly_cost)
            .sum()
    }

    pub fn instance_count(&self) -> usize {
        self.records.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::catalog::Catalog;

    fn meter_with(id: u32, type_name: &str, start: f64) -> (BillingMeter, SimInstance) {
        let t = Catalog::aws_table1().get(type_name).unwrap().clone();
        let inst = SimInstance::new(InstanceId(id), t, start);
        let mut m = BillingMeter::new();
        m.on_provision(&inst);
        (m, inst)
    }

    #[test]
    fn first_hour_billed_immediately() {
        let (m, _) = meter_with(1, "c4.2xlarge", 0.0);
        assert_eq!(m.total_cost(1.0), Dollars::from_f64(0.419));
    }

    #[test]
    fn started_hours_round_up() {
        let (mut m, _) = meter_with(1, "g2.2xlarge", 0.0);
        m.on_terminate(InstanceId(1), 3601.0); // 1h + 1s -> 2 hours
        assert_eq!(m.total_cost(10_000.0), Dollars::from_f64(1.300));
    }

    #[test]
    fn per_instance_breakdown_sums_to_total() {
        let (mut m, _) = meter_with(1, "c4.2xlarge", 0.0);
        let t2 = Catalog::aws_table1().get("g2.2xlarge").unwrap().clone();
        m.on_provision(&SimInstance::new(InstanceId(2), t2, 0.0));
        m.on_terminate(InstanceId(2), 3601.0); // 2 started hours
        let per = m.per_instance(100.0);
        assert_eq!(per.len(), 2);
        assert_eq!(per[0], (InstanceId(1), 1, Dollars::from_f64(0.419)));
        assert_eq!(per[1], (InstanceId(2), 2, Dollars::from_f64(1.300)));
        let total: Dollars = per.iter().map(|(_, _, c)| *c).sum();
        assert_eq!(total, m.total_cost(100.0));
    }

    #[test]
    fn hourly_rate_counts_only_running() {
        let (mut m, _) = meter_with(1, "c4.2xlarge", 0.0);
        let t2 = Catalog::aws_table1().get("g2.2xlarge").unwrap().clone();
        let i2 = SimInstance::new(InstanceId(2), t2, 0.0);
        m.on_provision(&i2);
        assert_eq!(m.hourly_rate(10.0), Dollars::from_f64(1.069));
        m.on_terminate(InstanceId(1), 20.0);
        assert_eq!(m.hourly_rate(30.0), Dollars::from_f64(0.650));
        assert_eq!(m.instance_count(), 2);
    }

    #[test]
    fn hourly_rate_counts_instances_terminating_later() {
        // Regression: a record whose end is already written must still
        // count toward the run-rate at times *before* that end.  The
        // pre-fix filter (`end.is_none()`) excluded it, under-reporting
        // mid-simulation run-rate queries.
        let (mut m, _) = meter_with(1, "c4.2xlarge", 0.0);
        m.on_terminate(InstanceId(1), 20.0);
        assert_eq!(m.hourly_rate(10.0), Dollars::from_f64(0.419));
        // At the termination instant and after it, the instance is gone.
        assert_eq!(m.hourly_rate(20.0), Dollars::ZERO);
        assert_eq!(m.hourly_rate(25.0), Dollars::ZERO);
        // Not-yet-started instances never count.
        let (m2, _) = meter_with(2, "g2.2xlarge", 50.0);
        assert_eq!(m2.hourly_rate(10.0), Dollars::ZERO);
    }
}
