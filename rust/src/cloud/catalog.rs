//! Instance-type catalog (the paper's Table 1) and the pricing model
//! layered on top of it.
//!
//! The paper prices a single-region, on-demand catalog: one
//! started-hour rate per instance type.  Real cloud vendors sell the
//! same hardware under several **lease tiers** and in several
//! **regions**, and the spread between those prices is the largest
//! cost lever a provisioner has.  This module keeps [`InstanceType`]
//! as the hardware description (capability vector + *base* on-demand
//! rate) and adds a [`PricingModel`] describing how that base rate is
//! modulated:
//!
//! * [`PricingTier`] — `Reserved` (discounted commitment, billed for
//!   the whole settlement window regardless of churn), `OnDemand`
//!   (the paper's started-hour semantics), `Spot` (deep discount, but
//!   the vendor may revoke the instance mid-trace; see
//!   `workload::trace` revocation events and `cloud::billing` for how
//!   interrupted hours are priced).
//! * [`RegionSpec`] — a named region with a price factor and an
//!   hourly **data-transfer charge** applied when a stream is served
//!   from an instance outside its home region (cross-region
//!   assignment, as in geo-distributed lease optimization).
//!
//! A (type, tier, region) combination is an [`Offering`]: a synthetic
//! `InstanceType` whose name is `base:tier@region` (for example
//! `c4.2xlarge:spot@r1`) and whose `hourly_cost` is the *effective*
//! rate `base × tier factor × region factor`.  [`Catalog::offerings`]
//! enumerates them and [`Catalog::resolve`] maps any plan type name —
//! plain or offering-qualified — back to its offering, so the fleet
//! simulator and billing meter price provisioned instances correctly.
//!
//! The default [`PricingModel`] is **flat** (one on-demand tier, one
//! local region, zero transfer): under it `offerings()` reproduces the
//! plain catalog byte for byte and every downstream path — problem
//! construction, billing, reports — is bit-identical to the
//! single-price model the paper describes.

use crate::types::{DimLayout, Dollars, ResourceVec};

/// One GPU inside an instance type.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    /// CUDA-core count in the paper's unit convention (g2: 1536).
    pub cores: f64,
    /// GPU memory in GB.
    pub mem_gb: f64,
}

/// A cloud instance type: capabilities and hourly cost.
#[derive(Clone, Debug, PartialEq)]
pub struct InstanceType {
    pub name: String,
    pub cpu_cores: f64,
    pub mem_gb: f64,
    pub gpus: Vec<GpuSpec>,
    pub hourly_cost: Dollars,
}

impl InstanceType {
    /// Capability vector under `layout` (absolute units, no headroom).
    ///
    /// Panics if the type has more GPUs than the layout admits — the
    /// manager always sizes the layout from the catalog it uses.
    pub fn capability(&self, layout: DimLayout) -> ResourceVec {
        assert!(
            self.gpus.len() <= layout.max_gpus,
            "{} has {} GPUs but layout admits {}",
            self.name,
            self.gpus.len(),
            layout.max_gpus
        );
        let mut v = ResourceVec::zeros(layout.dims());
        v[DimLayout::CPU] = self.cpu_cores;
        v[DimLayout::MEM] = self.mem_gb;
        for (g, gpu) in self.gpus.iter().enumerate() {
            v[layout.gpu_cores(g)] = gpu.cores;
            v[layout.gpu_mem(g)] = gpu.mem_gb;
        }
        v
    }

    pub fn has_gpu(&self) -> bool {
        !self.gpus.is_empty()
    }
}

/// A cloud lease tier: how an instance is paid for, and what the
/// vendor may do to it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PricingTier {
    /// Committed capacity at a discount: billed from provision until
    /// the settlement horizon regardless of early termination.
    Reserved,
    /// The paper's model: started-hour billing, never revoked.
    OnDemand,
    /// Deep discount; the vendor may revoke the instance mid-trace
    /// (the interrupted partial hour is not charged).
    Spot,
}

impl PricingTier {
    /// Conventional price factor relative to the on-demand base rate.
    pub fn default_factor(self) -> f64 {
        match self {
            PricingTier::Reserved => 0.6,
            PricingTier::OnDemand => 1.0,
            PricingTier::Spot => 0.35,
        }
    }
}

impl std::fmt::Display for PricingTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PricingTier::Reserved => "reserved",
            PricingTier::OnDemand => "ondemand",
            PricingTier::Spot => "spot",
        })
    }
}

impl std::str::FromStr for PricingTier {
    type Err = String;
    fn from_str(s: &str) -> Result<PricingTier, String> {
        match s {
            "reserved" => Ok(PricingTier::Reserved),
            "ondemand" | "on-demand" => Ok(PricingTier::OnDemand),
            "spot" => Ok(PricingTier::Spot),
            other => Err(format!(
                "unknown pricing tier {other:?} (expected reserved, ondemand, or spot)"
            )),
        }
    }
}

/// One lease tier on offer, with its price factor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierSpec {
    pub tier: PricingTier,
    /// Multiplier applied to the base on-demand rate.
    pub factor: f64,
}

impl TierSpec {
    pub fn new(tier: PricingTier) -> TierSpec {
        TierSpec { tier, factor: tier.default_factor() }
    }
}

/// One region on offer: price factor plus the hourly data-transfer
/// charge for serving a stream homed elsewhere from this region.
#[derive(Clone, Debug, PartialEq)]
pub struct RegionSpec {
    pub name: String,
    /// Multiplier applied to the (tier-adjusted) rate in this region.
    pub factor: f64,
    /// Hourly cross-region transfer cost per stream assigned here
    /// from another home region.
    pub transfer_hourly: Dollars,
}

/// The tier × region grid modulating a catalog's base rates.
#[derive(Clone, Debug, PartialEq)]
pub struct PricingModel {
    pub tiers: Vec<TierSpec>,
    pub regions: Vec<RegionSpec>,
}

impl Default for PricingModel {
    /// The paper's model: one on-demand tier, one local region, no
    /// transfer charges.  Everything downstream treats this as "no
    /// pricing layer at all".
    fn default() -> PricingModel {
        PricingModel {
            tiers: vec![TierSpec { tier: PricingTier::OnDemand, factor: 1.0 }],
            regions: vec![RegionSpec {
                name: "local".into(),
                factor: 1.0,
                transfer_hourly: Dollars::ZERO,
            }],
        }
    }
}

impl PricingModel {
    /// Tiered pricing in the default single local region.
    pub fn with_tiers(tiers: Vec<TierSpec>) -> PricingModel {
        let mut m = PricingModel::default();
        if !tiers.is_empty() {
            m.tiers = tiers;
        }
        m
    }

    /// True when this model changes nothing relative to the paper's
    /// single-price catalog: one on-demand tier at factor 1 and at
    /// most one region at factor 1 with zero transfer cost.
    pub fn is_flat(&self) -> bool {
        let flat_tiers = self.tiers.len() == 1
            && self.tiers[0].tier == PricingTier::OnDemand
            && self.tiers[0].factor == 1.0;
        let flat_regions = match self.regions.as_slice() {
            [] => true,
            [r] => r.factor == 1.0 && r.transfer_hourly == Dollars::ZERO,
            _ => false,
        };
        flat_tiers && flat_regions
    }

    pub fn n_regions(&self) -> usize {
        self.regions.len().max(1)
    }
}

/// One purchasable (type, tier, region) combination.
///
/// `itype.name` is the offering-qualified name (`base:tier@region`,
/// or the plain base name under a flat model) and `itype.hourly_cost`
/// the effective rate after tier and region factors.
#[derive(Clone, Debug, PartialEq)]
pub struct Offering {
    pub itype: InstanceType,
    pub tier: PricingTier,
    /// Index into [`PricingModel::regions`].
    pub region: usize,
}

/// A set of instance types offered by the (simulated) cloud vendor.
#[derive(Clone, Debug, Default)]
pub struct Catalog {
    pub types: Vec<InstanceType>,
    pub pricing: PricingModel,
}

impl Catalog {
    /// The paper's Table 1 (Amazon EC2, Oregon).
    pub fn aws_table1() -> Catalog {
        let g2_gpu = GpuSpec { cores: 1536.0, mem_gb: 4.0 };
        Catalog {
            types: vec![
                InstanceType {
                    name: "c4.2xlarge".into(),
                    cpu_cores: 8.0,
                    mem_gb: 15.0,
                    gpus: vec![],
                    hourly_cost: Dollars::from_f64(0.419),
                },
                InstanceType {
                    name: "c4.8xlarge".into(),
                    cpu_cores: 36.0,
                    mem_gb: 60.0,
                    gpus: vec![],
                    hourly_cost: Dollars::from_f64(1.675),
                },
                InstanceType {
                    name: "g2.2xlarge".into(),
                    cpu_cores: 8.0,
                    mem_gb: 15.0,
                    gpus: vec![g2_gpu],
                    hourly_cost: Dollars::from_f64(0.650),
                },
                InstanceType {
                    name: "g2.8xlarge".into(),
                    cpu_cores: 32.0,
                    mem_gb: 60.0,
                    gpus: vec![g2_gpu; 4],
                    hourly_cost: Dollars::from_f64(2.600),
                },
            ],
            pricing: PricingModel::default(),
        }
    }

    /// The two-type catalog the paper's experiments actually price
    /// against ("the same pricing of the c4.2xlarge and g2.2xlarge
    /// instances is used", §4.1).
    pub fn paper_experiments() -> Catalog {
        Catalog::aws_table1().subset(&["c4.2xlarge", "g2.2xlarge"])
    }

    /// Replace the pricing model (builder style).
    pub fn with_pricing(mut self, pricing: PricingModel) -> Catalog {
        self.pricing = pricing;
        self
    }

    /// Restrict to the named types (preserving catalog order).
    ///
    /// Offering-qualified names (`base:tier@region`) select their base
    /// type, so a fleet provisioned from `offerings()` can restrict a
    /// catalog for repacking.
    pub fn subset(&self, names: &[&str]) -> Catalog {
        Catalog {
            types: self
                .types
                .iter()
                .filter(|t| {
                    names
                        .iter()
                        .any(|n| n.split(':').next().unwrap_or(n) == t.name)
                })
                .cloned()
                .collect(),
            pricing: self.pricing.clone(),
        }
    }

    /// Only non-GPU types (strategy ST1).
    pub fn non_gpu_only(&self) -> Catalog {
        Catalog {
            types: self.types.iter().filter(|t| !t.has_gpu()).cloned().collect(),
            pricing: self.pricing.clone(),
        }
    }

    /// Only GPU types (strategy ST2).
    pub fn gpu_only(&self) -> Catalog {
        Catalog {
            types: self.types.iter().filter(|t| t.has_gpu()).cloned().collect(),
            pricing: self.pricing.clone(),
        }
    }

    pub fn get(&self, name: &str) -> Option<&InstanceType> {
        self.types.iter().find(|t| t.name == name)
    }

    /// Enumerate every purchasable (type, tier, region) offering.
    ///
    /// Under a flat pricing model this is exactly the plain catalog
    /// (same names, same rates); otherwise the type list is expanded
    /// across the tier × region grid with effective rates.
    pub fn offerings(&self) -> Vec<Offering> {
        if self.pricing.is_flat() {
            return self
                .types
                .iter()
                .map(|t| Offering {
                    itype: t.clone(),
                    tier: PricingTier::OnDemand,
                    region: 0,
                })
                .collect();
        }
        let mut out = Vec::new();
        for t in &self.types {
            for ts in &self.pricing.tiers {
                for (r, rs) in self.pricing.regions.iter().enumerate() {
                    let mut itype = t.clone();
                    itype.name = format!("{}:{}@{}", t.name, ts.tier, rs.name);
                    itype.hourly_cost = t.hourly_cost.scale(ts.factor * rs.factor);
                    out.push(Offering { itype, tier: ts.tier, region: r });
                }
            }
        }
        out
    }

    /// Resolve a plan type name — plain (`c4.2xlarge`) or
    /// offering-qualified (`c4.2xlarge:spot@r1`) — to its offering.
    ///
    /// Plain names resolve to the base type at on-demand rates in
    /// region 0, which keeps pre-pricing plans valid unchanged.
    pub fn resolve(&self, name: &str) -> Option<Offering> {
        if let Some(t) = self.get(name) {
            return Some(Offering {
                itype: t.clone(),
                tier: PricingTier::OnDemand,
                region: 0,
            });
        }
        let (base, rest) = name.split_once(':')?;
        let (tier_s, region_s) = rest.split_once('@')?;
        let tier: PricingTier = tier_s.parse().ok()?;
        let tier_factor = self
            .pricing
            .tiers
            .iter()
            .find(|ts| ts.tier == tier)
            .map(|ts| ts.factor)?;
        let region = self
            .pricing
            .regions
            .iter()
            .position(|r| r.name == region_s)?;
        let t = self.get(base)?;
        let mut itype = t.clone();
        itype.name = name.to_string();
        itype.hourly_cost = t
            .hourly_cost
            .scale(tier_factor * self.pricing.regions[region].factor);
        Some(Offering { itype, tier, region })
    }

    /// Dimension layout wide enough for every type in this catalog.
    pub fn layout(&self) -> DimLayout {
        DimLayout::new(self.types.iter().map(|t| t.gpus.len()).max().unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let cat = Catalog::aws_table1();
        assert_eq!(cat.types.len(), 4);
        let c4 = cat.get("c4.2xlarge").unwrap();
        assert_eq!(c4.cpu_cores, 8.0);
        assert_eq!(c4.mem_gb, 15.0);
        assert!(!c4.has_gpu());
        assert_eq!(c4.hourly_cost, Dollars::from_f64(0.419));

        let g28 = cat.get("g2.8xlarge").unwrap();
        assert_eq!(g28.gpus.len(), 4);
        assert_eq!(g28.cpu_cores, 32.0);
        assert_eq!(g28.hourly_cost, Dollars::from_f64(2.600));
    }

    #[test]
    fn capability_vectors_match_paper_section_3_2() {
        let cat = Catalog::aws_table1();
        // "[8, 15, 0, 0] represents a non-GPU instance" (N = 1 layout).
        let layout = DimLayout::new(1);
        let c4 = cat.get("c4.2xlarge").unwrap().capability(layout);
        assert_eq!(c4.0, vec![8.0, 15.0, 0.0, 0.0]);
        // "[8, 15, 1536, 4] represents a GPU instance".
        let g2 = cat.get("g2.2xlarge").unwrap().capability(layout);
        assert_eq!(g2.0, vec![8.0, 15.0, 1536.0, 4.0]);
        // g2.8xlarge under N = 4: [32, 60, (1536, 4) x4].
        let l4 = DimLayout::new(4);
        let g28 = cat.get("g2.8xlarge").unwrap().capability(l4);
        assert_eq!(
            g28.0,
            vec![32.0, 60.0, 1536.0, 4.0, 1536.0, 4.0, 1536.0, 4.0, 1536.0, 4.0]
        );
    }

    #[test]
    #[should_panic(expected = "layout admits")]
    fn capability_panics_on_narrow_layout() {
        let cat = Catalog::aws_table1();
        cat.get("g2.8xlarge").unwrap().capability(DimLayout::new(1));
    }

    #[test]
    fn strategy_subsets() {
        let cat = Catalog::aws_table1();
        assert_eq!(
            cat.non_gpu_only()
                .types
                .iter()
                .map(|t| t.name.as_str())
                .collect::<Vec<_>>(),
            vec!["c4.2xlarge", "c4.8xlarge"]
        );
        assert_eq!(
            cat.gpu_only()
                .types
                .iter()
                .map(|t| t.name.as_str())
                .collect::<Vec<_>>(),
            vec!["g2.2xlarge", "g2.8xlarge"]
        );
        assert_eq!(Catalog::paper_experiments().types.len(), 2);
    }

    #[test]
    fn layout_sized_from_catalog() {
        assert_eq!(Catalog::aws_table1().layout(), DimLayout::new(4));
        assert_eq!(Catalog::paper_experiments().layout(), DimLayout::new(1));
        assert_eq!(
            Catalog::aws_table1().non_gpu_only().layout(),
            DimLayout::new(0)
        );
    }

    #[test]
    fn tier_names_round_trip() {
        for tier in [PricingTier::Reserved, PricingTier::OnDemand, PricingTier::Spot] {
            let s = tier.to_string();
            assert_eq!(s.parse::<PricingTier>().unwrap(), tier);
        }
        assert_eq!("on-demand".parse::<PricingTier>().unwrap(), PricingTier::OnDemand);
        assert!("preemptible".parse::<PricingTier>().is_err());
        assert_eq!(PricingTier::Spot.default_factor(), 0.35);
        assert_eq!(PricingTier::Reserved.default_factor(), 0.6);
    }

    #[test]
    fn flat_model_offerings_reproduce_plain_catalog() {
        let cat = Catalog::aws_table1();
        assert!(cat.pricing.is_flat());
        let offs = cat.offerings();
        assert_eq!(offs.len(), cat.types.len());
        for (o, t) in offs.iter().zip(&cat.types) {
            assert_eq!(o.itype, *t);
            assert_eq!(o.tier, PricingTier::OnDemand);
            assert_eq!(o.region, 0);
        }
        // Plain names resolve to themselves at base rates.
        let r = cat.resolve("g2.2xlarge").unwrap();
        assert_eq!(r.itype.hourly_cost, Dollars::from_f64(0.650));
        assert!(cat.resolve("m5.large").is_none());
    }

    #[test]
    fn tiered_offerings_expand_and_resolve() {
        let pricing = PricingModel {
            tiers: vec![
                TierSpec { tier: PricingTier::OnDemand, factor: 1.0 },
                TierSpec { tier: PricingTier::Spot, factor: 0.35 },
            ],
            regions: vec![
                RegionSpec { name: "r0".into(), factor: 1.0, transfer_hourly: Dollars::ZERO },
                RegionSpec {
                    name: "r1".into(),
                    factor: 1.05,
                    transfer_hourly: Dollars::from_f64(0.01),
                },
            ],
        };
        assert!(!pricing.is_flat());
        let cat = Catalog::paper_experiments().with_pricing(pricing);
        let offs = cat.offerings();
        // 2 types x 2 tiers x 2 regions.
        assert_eq!(offs.len(), 8);
        let spot = offs
            .iter()
            .find(|o| o.itype.name == "c4.2xlarge:spot@r1")
            .unwrap();
        assert_eq!(spot.tier, PricingTier::Spot);
        assert_eq!(spot.region, 1);
        assert_eq!(
            spot.itype.hourly_cost,
            Dollars::from_f64(0.419).scale(0.35 * 1.05)
        );
        // Every offering name resolves back to an identical offering.
        for o in &offs {
            let r = cat.resolve(&o.itype.name).unwrap();
            assert_eq!(r.itype, o.itype);
            assert_eq!(r.tier, o.tier);
            assert_eq!(r.region, o.region);
        }
        // Plain base names still resolve (on-demand, region 0).
        let plain = cat.resolve("c4.2xlarge").unwrap();
        assert_eq!(plain.itype.hourly_cost, Dollars::from_f64(0.419));
        // subset() accepts offering-qualified names.
        let sub = cat.subset(&["c4.2xlarge:spot@r1"]);
        assert_eq!(sub.types.len(), 1);
        assert_eq!(sub.types[0].name, "c4.2xlarge");
        assert!(!sub.pricing.is_flat());
    }
}
