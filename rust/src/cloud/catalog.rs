//! Instance-type catalog (the paper's Table 1).

use crate::types::{DimLayout, Dollars, ResourceVec};

/// One GPU inside an instance type.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    /// CUDA-core count in the paper's unit convention (g2: 1536).
    pub cores: f64,
    /// GPU memory in GB.
    pub mem_gb: f64,
}

/// A cloud instance type: capabilities and hourly cost.
#[derive(Clone, Debug, PartialEq)]
pub struct InstanceType {
    pub name: String,
    pub cpu_cores: f64,
    pub mem_gb: f64,
    pub gpus: Vec<GpuSpec>,
    pub hourly_cost: Dollars,
}

impl InstanceType {
    /// Capability vector under `layout` (absolute units, no headroom).
    ///
    /// Panics if the type has more GPUs than the layout admits — the
    /// manager always sizes the layout from the catalog it uses.
    pub fn capability(&self, layout: DimLayout) -> ResourceVec {
        assert!(
            self.gpus.len() <= layout.max_gpus,
            "{} has {} GPUs but layout admits {}",
            self.name,
            self.gpus.len(),
            layout.max_gpus
        );
        let mut v = ResourceVec::zeros(layout.dims());
        v[DimLayout::CPU] = self.cpu_cores;
        v[DimLayout::MEM] = self.mem_gb;
        for (g, gpu) in self.gpus.iter().enumerate() {
            v[layout.gpu_cores(g)] = gpu.cores;
            v[layout.gpu_mem(g)] = gpu.mem_gb;
        }
        v
    }

    pub fn has_gpu(&self) -> bool {
        !self.gpus.is_empty()
    }
}

/// A set of instance types offered by the (simulated) cloud vendor.
#[derive(Clone, Debug, Default)]
pub struct Catalog {
    pub types: Vec<InstanceType>,
}

impl Catalog {
    /// The paper's Table 1 (Amazon EC2, Oregon).
    pub fn aws_table1() -> Catalog {
        let g2_gpu = GpuSpec { cores: 1536.0, mem_gb: 4.0 };
        Catalog {
            types: vec![
                InstanceType {
                    name: "c4.2xlarge".into(),
                    cpu_cores: 8.0,
                    mem_gb: 15.0,
                    gpus: vec![],
                    hourly_cost: Dollars::from_f64(0.419),
                },
                InstanceType {
                    name: "c4.8xlarge".into(),
                    cpu_cores: 36.0,
                    mem_gb: 60.0,
                    gpus: vec![],
                    hourly_cost: Dollars::from_f64(1.675),
                },
                InstanceType {
                    name: "g2.2xlarge".into(),
                    cpu_cores: 8.0,
                    mem_gb: 15.0,
                    gpus: vec![g2_gpu],
                    hourly_cost: Dollars::from_f64(0.650),
                },
                InstanceType {
                    name: "g2.8xlarge".into(),
                    cpu_cores: 32.0,
                    mem_gb: 60.0,
                    gpus: vec![g2_gpu; 4],
                    hourly_cost: Dollars::from_f64(2.600),
                },
            ],
        }
    }

    /// The two-type catalog the paper's experiments actually price
    /// against ("the same pricing of the c4.2xlarge and g2.2xlarge
    /// instances is used", §4.1).
    pub fn paper_experiments() -> Catalog {
        Catalog::aws_table1().subset(&["c4.2xlarge", "g2.2xlarge"])
    }

    /// Restrict to the named types (preserving catalog order).
    pub fn subset(&self, names: &[&str]) -> Catalog {
        Catalog {
            types: self
                .types
                .iter()
                .filter(|t| names.contains(&t.name.as_str()))
                .cloned()
                .collect(),
        }
    }

    /// Only non-GPU types (strategy ST1).
    pub fn non_gpu_only(&self) -> Catalog {
        Catalog {
            types: self.types.iter().filter(|t| !t.has_gpu()).cloned().collect(),
        }
    }

    /// Only GPU types (strategy ST2).
    pub fn gpu_only(&self) -> Catalog {
        Catalog {
            types: self.types.iter().filter(|t| t.has_gpu()).cloned().collect(),
        }
    }

    pub fn get(&self, name: &str) -> Option<&InstanceType> {
        self.types.iter().find(|t| t.name == name)
    }

    /// Dimension layout wide enough for every type in this catalog.
    pub fn layout(&self) -> DimLayout {
        DimLayout::new(self.types.iter().map(|t| t.gpus.len()).max().unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let cat = Catalog::aws_table1();
        assert_eq!(cat.types.len(), 4);
        let c4 = cat.get("c4.2xlarge").unwrap();
        assert_eq!(c4.cpu_cores, 8.0);
        assert_eq!(c4.mem_gb, 15.0);
        assert!(!c4.has_gpu());
        assert_eq!(c4.hourly_cost, Dollars::from_f64(0.419));

        let g28 = cat.get("g2.8xlarge").unwrap();
        assert_eq!(g28.gpus.len(), 4);
        assert_eq!(g28.cpu_cores, 32.0);
        assert_eq!(g28.hourly_cost, Dollars::from_f64(2.600));
    }

    #[test]
    fn capability_vectors_match_paper_section_3_2() {
        let cat = Catalog::aws_table1();
        // "[8, 15, 0, 0] represents a non-GPU instance" (N = 1 layout).
        let layout = DimLayout::new(1);
        let c4 = cat.get("c4.2xlarge").unwrap().capability(layout);
        assert_eq!(c4.0, vec![8.0, 15.0, 0.0, 0.0]);
        // "[8, 15, 1536, 4] represents a GPU instance".
        let g2 = cat.get("g2.2xlarge").unwrap().capability(layout);
        assert_eq!(g2.0, vec![8.0, 15.0, 1536.0, 4.0]);
        // g2.8xlarge under N = 4: [32, 60, (1536, 4) x4].
        let l4 = DimLayout::new(4);
        let g28 = cat.get("g2.8xlarge").unwrap().capability(l4);
        assert_eq!(
            g28.0,
            vec![32.0, 60.0, 1536.0, 4.0, 1536.0, 4.0, 1536.0, 4.0, 1536.0, 4.0]
        );
    }

    #[test]
    #[should_panic(expected = "layout admits")]
    fn capability_panics_on_narrow_layout() {
        let cat = Catalog::aws_table1();
        cat.get("g2.8xlarge").unwrap().capability(DimLayout::new(1));
    }

    #[test]
    fn strategy_subsets() {
        let cat = Catalog::aws_table1();
        assert_eq!(
            cat.non_gpu_only()
                .types
                .iter()
                .map(|t| t.name.as_str())
                .collect::<Vec<_>>(),
            vec!["c4.2xlarge", "c4.8xlarge"]
        );
        assert_eq!(
            cat.gpu_only()
                .types
                .iter()
                .map(|t| t.name.as_str())
                .collect::<Vec<_>>(),
            vec!["g2.2xlarge", "g2.8xlarge"]
        );
        assert_eq!(Catalog::paper_experiments().types.len(), 2);
    }

    #[test]
    fn layout_sized_from_catalog() {
        assert_eq!(Catalog::aws_table1().layout(), DimLayout::new(4));
        assert_eq!(Catalog::paper_experiments().layout(), DimLayout::new(1));
        assert_eq!(
            Catalog::aws_table1().non_gpu_only().layout(),
            DimLayout::new(0)
        );
    }
}
