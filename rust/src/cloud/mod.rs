//! Simulated cloud substrate: instance catalog, lifecycle, and billing.
//!
//! The paper evaluates on Amazon EC2 (Table 1).  This module implements
//! the equivalent substrate: the instance-type catalog with capability
//! vectors and hourly costs, provisioned-instance lifecycle, and a
//! billing meter over the simulation clock.  The GPU *device model* —
//! how fast a simulated GPU executes an analysis program — lives in
//! [`crate::profiler::calibration`]; this module only knows capacities.

pub mod billing;
pub mod catalog;
pub mod instance;

pub use billing::BillingMeter;
pub use catalog::{Catalog, GpuSpec, InstanceType};
pub use instance::{InstanceId, InstanceState, SimInstance};
