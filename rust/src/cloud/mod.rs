//! Simulated cloud substrate: instance catalog, pricing tiers,
//! lifecycle, and billing.
//!
//! The paper evaluates on Amazon EC2 (Table 1).  This module implements
//! the equivalent substrate: the instance-type catalog with capability
//! vectors and hourly costs, a pluggable [`PricingModel`] (reserved /
//! on-demand / spot lease tiers and multi-region catalogs with
//! cross-region transfer charges — see [`catalog`]), provisioned-
//! instance lifecycle including vendor spot revocations, and a billing
//! meter over the simulation clock with per-tier started-hour
//! semantics (see [`billing`]).  The GPU *device model* — how fast a
//! simulated GPU executes an analysis program — lives in
//! [`crate::profiler::calibration`]; this module only knows capacities
//! and prices.

pub mod billing;
pub mod catalog;
pub mod instance;

pub use billing::BillingMeter;
pub use catalog::{
    Catalog, GpuSpec, InstanceType, Offering, PricingModel, PricingTier, RegionSpec, TierSpec,
};
pub use instance::{InstanceId, InstanceState, SimInstance};
