//! Workload / scenario configuration (the paper's Table 5) and JSON
//! config files for user-defined workloads.

use crate::cloud::{Catalog, PricingModel, PricingTier, RegionSpec, TierSpec};
use crate::streams::StreamSpec;
use crate::types::{Dollars, FrameSize, Program, VGA};
use crate::util::error::{anyhow, Result};
use crate::util::json::Json;
use std::path::Path;

/// A named workload plus the catalog it prices against.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub streams: Vec<StreamSpec>,
    pub catalog: Catalog,
}

/// The paper's three evaluation scenarios (Table 5).  All use VGA
/// streams and the two-type catalog of §4.1.
pub fn paper_scenario(number: u32) -> Result<Scenario> {
    let catalog = Catalog::paper_experiments();
    let mut streams = Vec::new();
    match number {
        1 => {
            streams.extend(StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.25));
            streams.extend(StreamSpec::replicate(100, 3, VGA, Program::Zf, 0.55));
        }
        2 => {
            streams.extend(StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.20));
            streams.extend(StreamSpec::replicate(100, 1, VGA, Program::Zf, 0.50));
        }
        3 => {
            streams.extend(StreamSpec::replicate(0, 2, VGA, Program::Vgg16, 0.20));
            streams.extend(StreamSpec::replicate(100, 10, VGA, Program::Zf, 8.00));
        }
        other => return Err(anyhow!("paper scenarios are 1-3, got {other}")),
    }
    Ok(Scenario {
        name: format!("scenario-{number}"),
        streams,
        catalog,
    })
}

/// Parse a `"catalog": ["c4.2xlarge", ...]` field (the full Table 1
/// catalog when absent), plus an optional sibling `"pricing"` object
/// (see [`pricing_from_json`]).  Shared by scenario and trace configs.
pub(crate) fn catalog_from_json(v: &Json) -> Result<Catalog> {
    let cat = match v.get("catalog") {
        None => Catalog::aws_table1(),
        Some(c) => {
            let names: Vec<&str> = c
                .as_arr()
                .ok_or_else(|| anyhow!("catalog must be an array of type names"))?
                .iter()
                .map(|x| x.as_str().ok_or_else(|| anyhow!("catalog entries are strings")))
                .collect::<Result<Vec<_>>>()?;
            let cat = Catalog::aws_table1().subset(&names);
            if cat.types.len() != names.len() {
                return Err(anyhow!("unknown instance type in catalog {names:?}"));
            }
            cat
        }
    };
    match v.get("pricing") {
        None => Ok(cat),
        Some(p) => Ok(cat.with_pricing(pricing_from_json(p)?)),
    }
}

/// Parse a `"pricing"` config object:
///
/// ```json
/// {
///   "tiers": [{"tier": "ondemand"}, {"tier": "spot", "factor": 0.35}],
///   "regions": [
///     {"name": "r0"},
///     {"name": "r1", "factor": 1.05, "transfer_hourly": 0.014}
///   ]
/// }
/// ```
///
/// Omitted `factor`s fall back to the tier's default discount (region
/// factors to 1.0); omitted keys leave the flat default in place.
pub(crate) fn pricing_from_json(v: &Json) -> Result<PricingModel> {
    let mut pricing = PricingModel::default();
    if let Some(rows) = v.get("tiers").and_then(Json::as_arr) {
        let mut tiers = Vec::new();
        for row in rows {
            let tier: PricingTier = row
                .str_field("tier")?
                .parse()
                .map_err(crate::util::error::Error::msg)?;
            let factor = row
                .get("factor")
                .and_then(Json::as_f64)
                .unwrap_or_else(|| tier.default_factor());
            if factor <= 0.0 {
                return Err(anyhow!("tier {tier} factor must be positive"));
            }
            tiers.push(TierSpec { tier, factor });
        }
        if tiers.is_empty() {
            return Err(anyhow!("pricing.tiers must not be empty"));
        }
        pricing.tiers = tiers;
    }
    if let Some(rows) = v.get("regions").and_then(Json::as_arr) {
        let mut regions = Vec::new();
        for row in rows {
            let name = row.str_field("name")?.to_string();
            let factor = row.get("factor").and_then(Json::as_f64).unwrap_or(1.0);
            let transfer = row.get("transfer_hourly").and_then(Json::as_f64).unwrap_or(0.0);
            if factor <= 0.0 || transfer < 0.0 {
                return Err(anyhow!("bad pricing for region {name:?}"));
            }
            regions.push(RegionSpec { name, factor, transfer_hourly: Dollars::from_f64(transfer) });
        }
        if regions.is_empty() {
            return Err(anyhow!("pricing.regions must not be empty"));
        }
        pricing.regions = regions;
    }
    Ok(pricing)
}

/// Serialize a pricing model back to the config shape
/// ([`pricing_from_json`] inverts it).
pub(crate) fn pricing_to_json(p: &PricingModel) -> Json {
    Json::obj(vec![
        (
            "tiers".to_string(),
            Json::Arr(
                p.tiers
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("tier".to_string(), Json::Str(t.tier.to_string())),
                            ("factor".to_string(), Json::Num(t.factor)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "regions".to_string(),
            Json::Arr(
                p.regions
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("name".to_string(), Json::Str(r.name.clone())),
                            ("factor".to_string(), Json::Num(r.factor)),
                            (
                                "transfer_hourly".to_string(),
                                Json::Num(r.transfer_hourly.as_f64()),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Parse config stream rows (`{"program", "fps", "cameras", "frame_h",
/// "frame_w"}`) into expanded stream specs.  Shared by scenario and
/// trace-epoch configs.
pub(crate) fn stream_rows_from_json(rows: &[Json]) -> Result<Vec<StreamSpec>> {
    let mut streams = Vec::new();
    let mut next_camera = 0u32;
    for row in rows {
        let program: Program = row
            .str_field("program")?
            .parse()
            .map_err(crate::util::error::Error::msg)?;
        let fps = row.f64_field("fps")?;
        if fps <= 0.0 {
            return Err(anyhow!("fps must be positive"));
        }
        let cameras = row.get("cameras").and_then(Json::as_u64).unwrap_or(1) as u32;
        let h = row.get("frame_h").and_then(Json::as_u64).unwrap_or(VGA.h as u64) as u32;
        let w = row.get("frame_w").and_then(Json::as_u64).unwrap_or(VGA.w as u64) as u32;
        streams.extend(StreamSpec::replicate(
            next_camera,
            cameras,
            FrameSize::new(h, w),
            program,
            fps,
        ));
        next_camera += cameras.max(1) * 100;
    }
    Ok(streams)
}

/// Serialize one stream spec back to the config row shape.
pub(crate) fn stream_to_json(s: &StreamSpec) -> Json {
    Json::obj(vec![
        ("program".to_string(), Json::Str(s.program.name().to_string())),
        ("fps".to_string(), Json::Num(s.desired_fps)),
        ("cameras".to_string(), Json::Num(1.0)),
        ("frame_h".to_string(), Json::Num(s.camera.frame_size.h as f64)),
        ("frame_w".to_string(), Json::Num(s.camera.frame_size.w as f64)),
    ])
}

impl Scenario {
    /// Parse a scenario from a JSON config:
    ///
    /// ```json
    /// {
    ///   "name": "my-workload",
    ///   "catalog": ["c4.2xlarge", "g2.2xlarge"],
    ///   "streams": [
    ///     {"program": "vgg16", "fps": 0.25, "cameras": 2,
    ///      "frame_h": 480, "frame_w": 640}
    ///   ]
    /// }
    /// ```
    pub fn from_json(v: &Json) -> Result<Scenario> {
        let name = v.str_field("name")?.to_string();
        let catalog = catalog_from_json(v)?;
        let streams = stream_rows_from_json(v.arr_field("streams")?)?;
        if streams.is_empty() {
            return Err(anyhow!("scenario has no streams"));
        }
        Ok(Scenario { name, streams, catalog })
    }

    pub fn load(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)?;
        Scenario::from_json(&Json::parse(&text)?)
    }

    /// Serialize back to the config JSON shape (one row per stream).
    pub fn to_json(&self) -> Json {
        let streams: Vec<Json> = self.streams.iter().map(stream_to_json).collect();
        Json::obj(vec![
            ("name".to_string(), Json::Str(self.name.clone())),
            (
                "catalog".to_string(),
                Json::Arr(
                    self.catalog
                        .types
                        .iter()
                        .map(|t| Json::Str(t.name.clone()))
                        .collect(),
                ),
            ),
            ("streams".to_string(), Json::Arr(streams)),
        ])
    }

    /// A randomized workload for ablation benchmarks: `n` streams with
    /// mixed programs, rates, and frame sizes.  Thin wrapper over the
    /// [`FleetSpec`](crate::workload::FleetSpec) generator with mixed
    /// frame sizes, so rates are drawn such that the CPU choice is
    /// sometimes feasible, sometimes not (mirrors the paper's mixed
    /// scenarios) and some draws are infeasible outright.
    pub fn random(seed: u64, n: u32, catalog: Catalog) -> Scenario {
        let fleet = crate::workload::FleetSpec::new(n)
            .seed(seed)
            .frame_sizes(&crate::types::FRAME_SIZES)
            .catalog(catalog)
            .build();
        Scenario {
            name: format!("random-{seed}-{n}"),
            streams: fleet.streams,
            catalog: fleet.catalog,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scenarios_match_table5() {
        let s1 = paper_scenario(1).unwrap();
        assert_eq!(s1.streams.len(), 4);
        assert_eq!(s1.catalog.types.len(), 2);
        let s2 = paper_scenario(2).unwrap();
        assert_eq!(s2.streams.len(), 2);
        let s3 = paper_scenario(3).unwrap();
        assert_eq!(s3.streams.len(), 12);
        assert_eq!(
            s3.streams.iter().filter(|s| s.program == Program::Zf).count(),
            10
        );
        assert!(paper_scenario(4).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let s = paper_scenario(1).unwrap();
        let back = Scenario::from_json(&s.to_json()).unwrap();
        assert_eq!(back.streams.len(), s.streams.len());
        assert_eq!(back.catalog.types.len(), 2);
        assert_eq!(back.name, "scenario-1");
    }

    #[test]
    fn from_json_validates() {
        let empty = r#"{"name":"x","streams":[]}"#;
        assert!(Scenario::from_json(&Json::parse(empty).unwrap()).is_err());
        let bad_fps = r#"{"name":"x","streams":[{"program":"zf","fps":-1}]}"#;
        assert!(Scenario::from_json(&Json::parse(bad_fps).unwrap()).is_err());
        let bad_type = r#"{"name":"x","catalog":["h100.mega"],"streams":[{"program":"zf","fps":1}]}"#;
        assert!(Scenario::from_json(&Json::parse(bad_type).unwrap()).is_err());
        let bad_program = r#"{"name":"x","streams":[{"program":"resnet","fps":1}]}"#;
        assert!(Scenario::from_json(&Json::parse(bad_program).unwrap()).is_err());
    }

    #[test]
    fn pricing_round_trip() {
        let p = PricingModel {
            tiers: vec![TierSpec::new(PricingTier::OnDemand), TierSpec::new(PricingTier::Spot)],
            regions: vec![
                RegionSpec { name: "r0".into(), factor: 1.0, transfer_hourly: Dollars::ZERO },
                RegionSpec {
                    name: "r1".into(),
                    factor: 1.05,
                    transfer_hourly: Dollars::from_f64(0.014),
                },
            ],
        };
        let back =
            pricing_from_json(&Json::parse(&pricing_to_json(&p).to_pretty()).unwrap()).unwrap();
        assert_eq!(back.tiers.len(), 2);
        assert_eq!(back.tiers[1].tier, PricingTier::Spot);
        assert!((back.tiers[1].factor - 0.35).abs() < 1e-12);
        assert_eq!(back.regions[1].name, "r1");
        assert_eq!(back.regions[1].transfer_hourly, Dollars::from_f64(0.014));
        // A catalog carrying this pricing round-trips through the
        // scenario/trace config shape.
        let cat = Catalog::paper_experiments().with_pricing(p);
        let cfg = Json::obj(vec![
            ("catalog".to_string(), Json::Arr(vec![Json::Str("c4.2xlarge".into())])),
            ("pricing".to_string(), pricing_to_json(&cat.pricing)),
        ]);
        let parsed = catalog_from_json(&cfg).unwrap();
        assert!(!parsed.pricing.is_flat());
        assert_eq!(parsed.pricing.tiers.len(), 2);
        // Unknown tier names and empty lists are rejected.
        let bad = r#"{"tiers":[{"tier":"preemptible"}]}"#;
        assert!(pricing_from_json(&Json::parse(bad).unwrap()).is_err());
        assert!(pricing_from_json(&Json::parse(r#"{"regions":[]}"#).unwrap()).is_err());
    }

    #[test]
    fn random_workloads_are_deterministic_and_varied() {
        let a = Scenario::random(7, 20, Catalog::paper_experiments());
        let b = Scenario::random(7, 20, Catalog::paper_experiments());
        assert_eq!(a.streams.len(), 20);
        for (x, y) in a.streams.iter().zip(&b.streams) {
            assert_eq!(x.desired_fps, y.desired_fps);
            assert_eq!(x.program, y.program);
        }
        let programs: std::collections::BTreeSet<_> =
            a.streams.iter().map(|s| s.program.name()).collect();
        assert_eq!(programs.len(), 2);
    }
}
