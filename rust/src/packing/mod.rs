//! Multiple-choice vector bin packing (MVBP).
//!
//! The paper (§3.2) formulates resource allocation as MVBP: each *bin
//! type* is a cloud instance type with an hourly cost and a capacity
//! vector; each *item* is a camera stream with one candidate requirement
//! vector per execution choice (CPU, or one of the N GPUs).  The goal is
//! to pack every item — selecting exactly one choice — into bins so the
//! total cost of opened bins is minimal and no bin is over capacity in
//! any dimension.
//!
//! The paper solves this with the exact arc-flow method of Brandão &
//! Pedroso (VPSolver).  This crate provides:
//!
//! * [`exact`] — an exact branch-and-bound solver (the default; proven
//!   optimal at paper scale and validated against brute force),
//! * [`arcflow`] — the arc-flow graph construction with the compression
//!   step, used as an exact 1-D solver and as a lower bound,
//! * [`heuristics`] — first-fit-decreasing / best-fit-decreasing
//!   baselines (ablation A, and the fallback above the exact-size cutoff).

pub mod arcflow;
pub mod exact;
pub mod heuristics;
pub mod problem;

pub use exact::{solve_exact, BranchAndBound};
pub use heuristics::{solve_best_fit, solve_first_fit, Decreasing};
pub use problem::{BinType, Item, MvbpProblem, PackedBin, Solution};

/// Which solver produced a solution (reports / ablations).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SolverKind {
    Exact,
    FirstFit,
    BestFit,
    ArcFlow1D,
}

impl std::fmt::Display for SolverKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            SolverKind::Exact => "exact-bb",
            SolverKind::FirstFit => "ffd",
            SolverKind::BestFit => "bfd",
            SolverKind::ArcFlow1D => "arcflow-1d",
        };
        f.write_str(s)
    }
}

/// Solve with the exact solver, falling back to best-fit-decreasing when
/// the instance exceeds `exact_cutoff` items (the manager's default path).
pub fn solve_auto(problem: &MvbpProblem, exact_cutoff: usize) -> Option<(Solution, SolverKind)> {
    if problem.items.len() <= exact_cutoff {
        // Exact search seeded with the BFD incumbent.
        solve_exact(problem).map(|s| (s, SolverKind::Exact))
    } else {
        solve_best_fit(problem).map(|s| (s, SolverKind::BestFit))
    }
}
