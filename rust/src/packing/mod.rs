//! Multiple-choice vector bin packing (MVBP).
//!
//! The paper (§3.2) formulates resource allocation as MVBP: each *bin
//! type* is a cloud instance type with an hourly cost and a capacity
//! vector; each *item* is a camera stream with one candidate requirement
//! vector per execution choice (CPU, or one of the N GPUs).  The goal is
//! to pack every item — selecting exactly one choice — into bins so the
//! total cost of opened bins is minimal and no bin is over capacity in
//! any dimension.
//!
//! The solving stack is organized around the [`Solver`] trait
//! (`packing::solver`): every strategy takes an [`MvbpProblem`] and a
//! [`SolveBudget`] and returns a [`SolveOutcome`] that carries the
//! solution **plus** a certified cost lower bound and the resulting
//! optimality gap, so allocations self-certify instead of handing back
//! blind answers.  The layers, bottom up:
//!
//! * [`problem`] — the instance/solution types with full validation;
//! * [`index`] — the residual index: a segment tree over open bins
//!   (element-wise max residual per node) giving near-O(log bins)
//!   first-fit descent and best-fit candidate enumeration with *exactly*
//!   the linear scan's fit decisions, so indexing never changes a
//!   heuristic's answer;
//! * [`heuristics`] — first-fit / best-fit under pluggable item
//!   orderings ([`ItemOrder`]), built on the index-driven placement
//!   engine that also powers sharded portfolio arms and warm-start
//!   delta repacking;
//! * [`aggregate`] — the class-aggregation layer: items with identical
//!   choice lists merge into multiplicity classes
//!   ([`group_classes`]), the greedy heuristics place whole *runs* of
//!   copies per bin via `floor(residual/req)` arithmetic, and the
//!   class-level packing expands back to per-item assignments — so a
//!   million-stream fleet with a handful of requirement classes packs
//!   in near-linear time while plans, certificates, and the warm-start
//!   repacker stay unchanged downstream.  Aggregation is bypassed when
//!   items are (mostly) distinct ([`aggregation_pays`]): below two
//!   items per class on average the per-item sharded path runs instead;
//! * [`exact`] — branch-and-bound, node- and deadline-bounded, seedable
//!   with any incumbent ([`BranchAndBound::solve_seeded`]).  On
//!   high-multiplicity instances it branches over *class
//!   multiplicities* ("place k copies of class c into bin b") instead
//!   of individual items, with symmetry breaking — classes are placed
//!   in a fixed (hardest-first) order, copy counts are tried
//!   non-increasing, equal-residual bins of one type are branched only
//!   once, and fresh bins open in non-increasing `(type, choice,
//!   count)` order — so the `k!` permutations of identical items
//!   collapse to a single search path;
//! * [`arcflow`] — the arc-flow machinery (Brandão & Pedroso): graph
//!   construction with compression (Ablation B), the Martello-Toth L2
//!   bound the certified gap is built from, and a 1-D exact oracle;
//! * [`bounds`] — dual-feasible-function (DFF) lower bounds: a family
//!   of superadditive roundings evaluated over weighted dimension
//!   projections (per-dimension units plus a combined
//!   `1/roomiest`-normalized weighting), maxed into
//!   [`certified_lower_bound`].  The combined projection is what
//!   tightens certificates on mixed CPU+GPU catalogs, where
//!   per-dimension relaxations let every stream dodge each dimension
//!   via its other execution choice;
//! * [`solver`] — the trait, the per-strategy implementations
//!   ([`FfdSolver`], [`BfdSolver`], [`ExactSolver`]), the
//!   [`PortfolioSolver`] that races orderings on `std::thread::scope`
//!   threads (aggregated arms when multiplicity pays, sharded per-item
//!   arms otherwise) and polishes with a seeded exact arm, and
//!   [`SolverChoice`] — the budget-based routing that replaced the old
//!   `solve_auto` item-count cliff.

pub mod aggregate;
pub mod arcflow;
pub mod bounds;
pub mod exact;
pub mod heuristics;
pub mod index;
pub mod problem;
pub mod solver;

pub use aggregate::{
    aggregation_pays, group_classes, group_classes_capped, problem_fingerprint,
    solve_greedy_aggregated, ItemClass,
};
pub use bounds::{dff_disabled, dff_lower_bound, set_dff_disabled};
pub use exact::{solve_exact, BranchAndBound, ExactResult};
pub use heuristics::{solve_best_fit, solve_first_fit, solve_greedy, Decreasing, Greedy, ItemOrder};
pub use problem::{BinType, Item, MvbpProblem, PackedBin, Solution};
pub use solver::{
    certified_gap, certified_lower_bound, BfdSolver, ExactSolver, FfdSolver, PortfolioSolver,
    SolveBudget, SolveOutcome, Solver, SolverChoice,
};

/// Which solver produced a solution (reports / ablations).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SolverKind {
    Exact,
    FirstFit,
    BestFit,
    ArcFlow1D,
    /// The racing portfolio (whichever arm won).
    Portfolio,
    /// Warm-start incremental repack seeded from a previous plan.
    WarmStart,
}

impl std::fmt::Display for SolverKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            SolverKind::Exact => "exact-bb",
            SolverKind::FirstFit => "ffd",
            SolverKind::BestFit => "bfd",
            SolverKind::ArcFlow1D => "arcflow-1d",
            SolverKind::Portfolio => "portfolio",
            SolverKind::WarmStart => "warm-start",
        };
        f.write_str(s)
    }
}

impl std::str::FromStr for SolverKind {
    type Err = String;

    /// Inverse of `Display` — plans round-trip through JSON (solve
    /// cache persistence, wire protocol) by these exact names.
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "exact-bb" => Ok(SolverKind::Exact),
            "ffd" => Ok(SolverKind::FirstFit),
            "bfd" => Ok(SolverKind::BestFit),
            "arcflow-1d" => Ok(SolverKind::ArcFlow1D),
            "portfolio" => Ok(SolverKind::Portfolio),
            "warm-start" => Ok(SolverKind::WarmStart),
            other => Err(format!("unknown solver kind {other:?}")),
        }
    }
}
