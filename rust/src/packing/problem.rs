//! MVBP problem and solution types, with full validation.

use crate::types::{Dollars, ResourceVec};

/// Capacity written into a synthetic *region-gate* dimension.
///
/// Multi-region problems append one extra dimension per region: a bin
/// in region `r` gets `GATE_DIM_CAP` capacity in gate dimension `r`
/// and zero in the others, while every expanded item choice carries
/// `1.0` in the gate dimension of the region it targets — so a choice
/// only fits bins of its region, with ordinary capacity arithmetic and
/// no solver changes.  The cap is large enough that gate dimensions
/// never bind (or meaningfully perturb utilization ratios) for any
/// realistic bin population.
pub(crate) const GATE_DIM_CAP: f64 = 1e6;

/// A bin type: an instance type's cost and capacity vector.
#[derive(Clone, Debug)]
pub struct BinType {
    /// Human-readable name (e.g. `g2.2xlarge`).
    pub name: String,
    /// Cost of opening one bin of this type (hourly cost).
    pub cost: Dollars,
    /// Usable capacity per dimension (already scaled by the 90% headroom
    /// rule when built by the manager).
    pub capacity: ResourceVec,
}

/// An item: one camera stream with one requirement vector per choice.
#[derive(Clone, Debug)]
pub struct Item {
    /// Stream identifier (opaque to the solver).
    pub id: String,
    /// Candidate requirement vectors; index = choice.  For the paper's
    /// problem, choice 0 is "analyze on CPU" and choice `1 + g` is
    /// "analyze on GPU g".
    pub choices: Vec<ResourceVec>,
}

/// A fully-specified MVBP instance.
#[derive(Clone, Debug)]
pub struct MvbpProblem {
    pub dims: usize,
    pub bin_types: Vec<BinType>,
    pub items: Vec<Item>,
    /// Optional per-(item, choice) assignment cost added to the bin-
    /// opening objective — `choice_costs[i][c]` is charged whenever
    /// item `i` is packed under choice `c` (cross-region data-transfer
    /// cost in the tiered cloud model).  Empty means all-zero, which
    /// is the classic MVBP objective.
    pub choice_costs: Vec<Vec<Dollars>>,
}

/// One opened bin with its item assignments.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedBin {
    pub bin_type: usize,
    /// `(item_index, choice_index)` pairs.
    pub assignments: Vec<(usize, usize)>,
}

/// A complete packing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Solution {
    pub bins: Vec<PackedBin>,
}

impl MvbpProblem {
    /// Structural sanity of the instance itself.
    pub fn validate(&self) -> Result<(), String> {
        if self.bin_types.is_empty() {
            return Err("no bin types".into());
        }
        for bt in &self.bin_types {
            if bt.capacity.dims() != self.dims {
                return Err(format!(
                    "bin type {} has {} dims, problem has {}",
                    bt.name,
                    bt.capacity.dims(),
                    self.dims
                ));
            }
            if bt.capacity.0.iter().any(|c| !c.is_finite()) {
                return Err(format!("bin type {} has non-finite capacity", bt.name));
            }
            if bt.capacity.0.iter().any(|c| *c < 0.0) {
                return Err(format!("bin type {} has negative capacity", bt.name));
            }
        }
        for item in &self.items {
            if item.choices.is_empty() {
                return Err(format!("item {} has no choices", item.id));
            }
            for (c, choice) in item.choices.iter().enumerate() {
                if choice.dims() != self.dims {
                    return Err(format!(
                        "item {} choice {} has {} dims, problem has {}",
                        item.id,
                        c,
                        choice.dims(),
                        self.dims
                    ));
                }
                if choice.0.iter().any(|v| !v.is_finite()) {
                    return Err(format!(
                        "item {} choice {} has a non-finite requirement",
                        item.id, c
                    ));
                }
                if choice.0.iter().any(|v| *v < 0.0) {
                    return Err(format!("item {} choice {} is negative", item.id, c));
                }
            }
        }
        if !self.choice_costs.is_empty() {
            if self.choice_costs.len() != self.items.len() {
                return Err(format!(
                    "choice_costs covers {} items, problem has {}",
                    self.choice_costs.len(),
                    self.items.len()
                ));
            }
            for (i, (item, costs)) in self.items.iter().zip(&self.choice_costs).enumerate() {
                if costs.len() != item.choices.len() {
                    return Err(format!(
                        "item {} has {} choices but {} choice costs",
                        item.id,
                        item.choices.len(),
                        costs.len()
                    ));
                }
                if costs.iter().any(|c| *c < Dollars::ZERO) {
                    return Err(format!("item {i} has a negative choice cost"));
                }
            }
        }
        Ok(())
    }

    /// Assignment cost of packing item `i` under choice `c` (zero when
    /// no choice costs are attached).
    pub fn choice_cost(&self, i: usize, c: usize) -> Dollars {
        self.choice_costs
            .get(i)
            .and_then(|cs| cs.get(c))
            .copied()
            .unwrap_or(Dollars::ZERO)
    }

    /// Whether item `i` under choice `c` fits into an *empty* bin of some type.
    pub fn choice_feasible(&self, i: usize, c: usize) -> bool {
        let need = &self.items[i].choices[c];
        self.bin_types.iter().any(|bt| need.fits(&bt.capacity))
    }

    /// An item is packable iff at least one of its choices is feasible.
    /// (ST1 in scenario 3 fails exactly here: ZF at 8 FPS does not fit the
    /// CPU of any non-GPU instance.)
    pub fn infeasible_items(&self) -> Vec<usize> {
        (0..self.items.len())
            .filter(|&i| {
                (0..self.items[i].choices.len()).all(|c| !self.choice_feasible(i, c))
            })
            .collect()
    }
}

impl Solution {
    /// Total cost: opened bins plus per-assignment choice costs.
    pub fn cost(&self, problem: &MvbpProblem) -> Dollars {
        self.bins
            .iter()
            .map(|b| {
                problem.bin_types[b.bin_type].cost
                    + b.assignments
                        .iter()
                        .map(|&(i, c)| problem.choice_cost(i, c))
                        .sum::<Dollars>()
            })
            .sum()
    }

    /// Count of opened bins per bin type, indexed like `problem.bin_types`.
    pub fn bins_per_type(&self, problem: &MvbpProblem) -> Vec<u32> {
        let mut counts = vec![0u32; problem.bin_types.len()];
        for b in &self.bins {
            counts[b.bin_type] += 1;
        }
        counts
    }

    /// Full feasibility check: every item packed exactly once with a valid
    /// choice, and every bin within capacity in every dimension.
    pub fn validate(&self, problem: &MvbpProblem) -> Result<(), String> {
        let mut seen = vec![false; problem.items.len()];
        for (b_idx, bin) in self.bins.iter().enumerate() {
            let bt = problem
                .bin_types
                .get(bin.bin_type)
                .ok_or_else(|| format!("bin {b_idx}: unknown bin type {}", bin.bin_type))?;
            if bin.assignments.is_empty() {
                return Err(format!("bin {b_idx}: opened but empty"));
            }
            let mut load = ResourceVec::zeros(problem.dims);
            for &(item, choice) in &bin.assignments {
                let it = problem
                    .items
                    .get(item)
                    .ok_or_else(|| format!("bin {b_idx}: unknown item {item}"))?;
                let req = it
                    .choices
                    .get(choice)
                    .ok_or_else(|| format!("item {}: unknown choice {choice}", it.id))?;
                if seen[item] {
                    return Err(format!("item {} packed twice", it.id));
                }
                seen[item] = true;
                load.add_assign(req);
            }
            if !load.fits(&bt.capacity) {
                return Err(format!(
                    "bin {b_idx} ({}) over capacity: load {:?} vs cap {:?}",
                    bt.name, load.0, bt.capacity.0
                ));
            }
        }
        if let Some(missing) = seen.iter().position(|s| !s) {
            return Err(format!("item {} not packed", problem.items[missing].id));
        }
        Ok(())
    }

    /// Per-bin utilization (load / capacity) in each dimension.
    pub fn utilizations(&self, problem: &MvbpProblem) -> Vec<ResourceVec> {
        self.bins
            .iter()
            .map(|bin| {
                let mut load = ResourceVec::zeros(problem.dims);
                for &(item, choice) in &bin.assignments {
                    load.add_assign(&problem.items[item].choices[choice]);
                }
                let cap = &problem.bin_types[bin.bin_type].capacity;
                ResourceVec(
                    load.0
                        .iter()
                        .zip(&cap.0)
                        .map(|(l, c)| if *c > 0.0 { l / c } else { 0.0 })
                        .collect(),
                )
            })
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod test_fixtures {
    use super::*;

    /// Two bin types (cheap small / expensive big), 2-D.
    pub fn small_problem() -> MvbpProblem {
        MvbpProblem {
            dims: 2,
            bin_types: vec![
                BinType {
                    name: "small".into(),
                    cost: Dollars::from_f64(1.0),
                    capacity: ResourceVec::from_slice(&[4.0, 4.0]),
                },
                BinType {
                    name: "big".into(),
                    cost: Dollars::from_f64(1.8),
                    capacity: ResourceVec::from_slice(&[10.0, 10.0]),
                },
            ],
            items: vec![
                Item {
                    id: "a".into(),
                    choices: vec![ResourceVec::from_slice(&[3.0, 1.0])],
                },
                Item {
                    id: "b".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[3.0, 1.0]),
                        ResourceVec::from_slice(&[1.0, 3.0]),
                    ],
                },
                Item {
                    id: "c".into(),
                    choices: vec![ResourceVec::from_slice(&[2.0, 2.0])],
                },
            ],
            choice_costs: vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_fixtures::small_problem;
    use super::*;

    #[test]
    fn validate_ok() {
        assert!(small_problem().validate().is_ok());
    }

    #[test]
    fn validate_catches_dim_mismatch() {
        let mut p = small_problem();
        p.items[0].choices[0] = ResourceVec::from_slice(&[1.0]);
        assert!(p.validate().unwrap_err().contains("dims"));
    }

    #[test]
    fn validate_catches_negative() {
        let mut p = small_problem();
        p.items[0].choices[0] = ResourceVec::from_slice(&[-1.0, 0.0]);
        assert!(p.validate().unwrap_err().contains("negative"));
    }

    #[test]
    fn validate_catches_non_finite() {
        // Regression: NaN requirements used to flow through validation
        // (NaN < 0.0 is false) and into the solvers' float sorts.
        let mut p = small_problem();
        p.items[1].choices[0] = ResourceVec::from_slice(&[f64::NAN, 1.0]);
        assert!(p.validate().unwrap_err().contains("non-finite"));

        let mut q = small_problem();
        q.items[0].choices[0] = ResourceVec::from_slice(&[f64::INFINITY, 1.0]);
        assert!(q.validate().unwrap_err().contains("non-finite"));

        let mut r = small_problem();
        r.bin_types[0].capacity = ResourceVec::from_slice(&[f64::NAN, 4.0]);
        assert!(r.validate().unwrap_err().contains("non-finite capacity"));
    }

    #[test]
    fn infeasible_item_detected() {
        let mut p = small_problem();
        p.items.push(Item {
            id: "huge".into(),
            choices: vec![ResourceVec::from_slice(&[11.0, 0.0])],
        });
        assert_eq!(p.infeasible_items(), vec![3]);
    }

    #[test]
    fn solution_cost_and_validation() {
        let p = small_problem();
        // a+b(choice1)+c in the big bin: load (3+1+2, 1+3+2) = (6,6) <= 10.
        let sol = Solution {
            bins: vec![PackedBin {
                bin_type: 1,
                assignments: vec![(0, 0), (1, 1), (2, 0)],
            }],
        };
        sol.validate(&p).unwrap();
        assert_eq!(sol.cost(&p), Dollars::from_f64(1.8));
        assert_eq!(sol.bins_per_type(&p), vec![0, 1]);
        let u = &sol.utilizations(&p)[0];
        assert!((u[0] - 0.6).abs() < 1e-12 && (u[1] - 0.6).abs() < 1e-12);
    }

    #[test]
    fn solution_rejects_overload() {
        let p = small_problem();
        let sol = Solution {
            bins: vec![PackedBin {
                bin_type: 0,
                assignments: vec![(0, 0), (1, 0)], // cpu 6 > 4
            }],
        };
        assert!(sol.validate(&p).unwrap_err().contains("over capacity"));
    }

    #[test]
    fn solution_rejects_missing_and_duplicate_items() {
        let p = small_problem();
        let missing = Solution {
            bins: vec![PackedBin {
                bin_type: 1,
                assignments: vec![(0, 0), (1, 0)],
            }],
        };
        assert!(missing.validate(&p).unwrap_err().contains("not packed"));

        let dup = Solution {
            bins: vec![
                PackedBin {
                    bin_type: 1,
                    assignments: vec![(0, 0), (1, 0), (2, 0)],
                },
                PackedBin {
                    bin_type: 0,
                    assignments: vec![(0, 0)],
                },
            ],
        };
        assert!(dup.validate(&p).unwrap_err().contains("twice"));
    }

    #[test]
    fn choice_costs_priced_and_validated() {
        let mut p = small_problem();
        let sol = Solution {
            bins: vec![PackedBin {
                bin_type: 1,
                assignments: vec![(0, 0), (1, 1), (2, 0)],
            }],
        };
        // No choice costs attached: classic objective.
        assert_eq!(sol.cost(&p), Dollars::from_f64(1.8));
        // Item b's second choice carries a transfer cost.
        p.choice_costs = vec![
            vec![Dollars::ZERO],
            vec![Dollars::ZERO, Dollars::from_f64(0.2)],
            vec![Dollars::ZERO],
        ];
        p.validate().unwrap();
        assert_eq!(p.choice_cost(1, 1), Dollars::from_f64(0.2));
        assert_eq!(p.choice_cost(2, 0), Dollars::ZERO);
        assert_eq!(sol.cost(&p), Dollars::from_f64(2.0));
        // Shape mismatches and negative costs are rejected.
        let mut bad = small_problem();
        bad.choice_costs = vec![vec![Dollars::ZERO]];
        assert!(bad.validate().unwrap_err().contains("choice_costs"));
        let mut neg = small_problem();
        neg.choice_costs = vec![
            vec![Dollars(-1)],
            vec![Dollars::ZERO, Dollars::ZERO],
            vec![Dollars::ZERO],
        ];
        assert!(neg.validate().unwrap_err().contains("negative choice cost"));
    }

    #[test]
    fn empty_bin_rejected() {
        let p = small_problem();
        let sol = Solution {
            bins: vec![PackedBin {
                bin_type: 0,
                assignments: vec![],
            }],
        };
        assert!(sol.validate(&p).unwrap_err().contains("empty"));
    }
}
