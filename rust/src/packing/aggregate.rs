//! Item-class aggregation: pack multiplicity *classes*, not items.
//!
//! The paper's fleets are highly degenerate — thousands of camera
//! streams collapse into a handful of distinct (program, frame-rate,
//! device-choice) requirement classes.  Packing every stream as an
//! individual item costs O(items × bins × choices) scans even with the
//! residual index; exploiting multiplicity is the standard large-scale
//! move (cf. the arc-flow formulation in [`super::arcflow`], which also
//! reasons over patterns rather than items).
//!
//! The layer has three steps:
//!
//! 1. **Group** ([`group_classes`]): items with bit-identical choice
//!    lists (same vectors, same order — choice order is semantic: index
//!    0 is the CPU path) merge into an [`ItemClass`] carrying its
//!    member item indices.  Canonicalization is exact-bit equality of
//!    the requirement vectors, which is what identical profile lookups
//!    produce for identical streams.
//! 2. **Pack classes with counts** ([`solve_greedy_aggregated`]): the
//!    greedy heuristics run once per class instead of once per item.  A
//!    whole *run* of copies is placed into a bin in one step — the run
//!    length comes from `floor(residual / req)` arithmetic
//!    ([`copy_bound`]) with the boundary verified against
//!    [`ResourceVec::fits`] so the count agrees exactly with per-item
//!    placement — and the open-bin lookup per run goes through the
//!    [`ResidualIndex`].  The result matches the per-item heuristic's
//!    packing (same bins, same choices) whenever distinct classes have
//!    distinct ordering measures; exact measure ties may interleave
//!    classes differently per-item (cost can then differ either way).
//! 3. **Expand** ([`expand`]): class-level placements map back to
//!    per-item assignments (members dealt out in bin order), so
//!    `Solution`, `AllocationPlan`, certificates, and the warm-start
//!    repacker are unchanged downstream.
//!
//! Aggregation is *bypassed* when it cannot pay: [`aggregation_pays`]
//! requires at least two items per class on average — an all-distinct
//! fleet goes through the per-item (sharded) path untouched.

use super::heuristics::{self, Greedy, ItemOrder};
use super::index::ResidualIndex;
use super::problem::{MvbpProblem, PackedBin, Solution};
use crate::types::ResourceVec;

/// One multiplicity class: items whose choice lists are bit-identical.
#[derive(Clone, Debug)]
pub struct ItemClass {
    /// Lowest member item index — carries the class's measures.
    pub rep: usize,
    /// All member item indices, ascending.
    pub members: Vec<u32>,
}

impl ItemClass {
    pub fn count(&self) -> usize {
        self.members.len()
    }
}

/// Group items into multiplicity classes by exact-bit equality of their
/// choice lists.  Classes come back in first-occurrence order, so the
/// grouping is deterministic for a given problem (the hash map is only
/// a membership index — iteration order never matters).
pub fn group_classes(problem: &MvbpProblem) -> Vec<ItemClass> {
    group_classes_capped(problem, usize::MAX).expect("uncapped grouping cannot abort")
}

/// Like [`group_classes`], but abort with `None` as soon as the class
/// count exceeds `max_classes`.  The class count is monotone over the
/// scan, so the portfolio's routing gate uses this to stop grouping an
/// all-distinct million-item fleet after ~`max_classes` items instead
/// of building (and discarding) a million-entry map.
pub fn group_classes_capped(
    problem: &MvbpProblem,
    max_classes: usize,
) -> Option<Vec<ItemClass>> {
    use std::collections::HashMap;
    let mut by_key: HashMap<Vec<u64>, usize> = HashMap::new();
    let mut classes: Vec<ItemClass> = Vec::new();
    for (i, item) in problem.items.iter().enumerate() {
        let mut key = Vec::with_capacity(1 + item.choices.len() * (problem.dims + 1));
        key.push(item.choices.len() as u64);
        for (c, choice) in item.choices.iter().enumerate() {
            for v in &choice.0 {
                key.push(v.to_bits());
            }
            // Choice costs are part of class identity: members must be
            // interchangeable in the objective, not just in capacity.
            key.push(problem.choice_cost(i, c).0 as u64);
        }
        match by_key.get(&key) {
            Some(&ci) => classes[ci].members.push(i as u32),
            None => {
                if classes.len() == max_classes {
                    return None;
                }
                by_key.insert(key, classes.len());
                classes.push(ItemClass { rep: i, members: vec![i as u32] });
            }
        }
    }
    Some(classes)
}

/// Aggregation pays only when classes actually carry multiplicity: at
/// least two items per class on average.  Below that the grouping
/// overhead buys nothing and callers should take the per-item path.
pub fn aggregation_pays(n_classes: usize, n_items: usize) -> bool {
    n_items > 0 && n_classes * 2 <= n_items
}

/// Order-independent fingerprint of an MVBP instance, for the
/// epoch-level solve cache: two independent 64-bit digests (different
/// FNV bases — colliding both at once is far harder than either alone)
/// over the priced bin catalog (ordered — bin-type indices are
/// semantic, they appear in solutions) and the *multiset* of item
/// requirement classes (each item hashed by the same
/// choices + choice-costs recipe [`group_classes_capped`] keys on,
/// folded commutatively, so item order never matters — two epochs with
/// the same class histogram fingerprint identically no matter how the
/// fleet enumerates its streams).  Item ids are deliberately excluded:
/// they don't constrain the packing, and the cache revalidates ids
/// structurally before replaying a hit.
pub fn problem_fingerprint(problem: &MvbpProblem) -> (u64, u64) {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    const FNV_OFFSET_A: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_OFFSET_B: u64 = 0x6c62_272e_07bb_0142;
    fn fnv_u64(mut h: u64, v: u64) -> u64 {
        for byte in v.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        h
    }
    fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
        for &byte in bytes {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        h
    }
    // Ordered catalog digest: dims, then every bin type's name, cost,
    // and capacity.
    let catalog = |seed: u64| -> u64 {
        let mut h = fnv_u64(seed, problem.dims as u64);
        h = fnv_u64(h, problem.bin_types.len() as u64);
        for bt in &problem.bin_types {
            h = fnv_u64(h, bt.name.len() as u64);
            h = fnv_bytes(h, bt.name.as_bytes());
            h = fnv_u64(h, bt.cost.0 as u64);
            for v in &bt.capacity.0 {
                h = fnv_u64(h, v.to_bits());
            }
        }
        h
    };
    // Commutative item fold: each item's class digest (the
    // `group_classes_capped` key recipe) summed with wrapping adds.
    let items = |seed: u64| -> u64 {
        let mut sum: u64 = 0;
        for (i, item) in problem.items.iter().enumerate() {
            let mut h = fnv_u64(seed, item.choices.len() as u64);
            for (c, choice) in item.choices.iter().enumerate() {
                for v in &choice.0 {
                    h = fnv_u64(h, v.to_bits());
                }
                h = fnv_u64(h, problem.choice_cost(i, c).0 as u64);
            }
            sum = sum.wrapping_add(h);
        }
        sum
    };
    let a = fnv_u64(catalog(FNV_OFFSET_A), items(FNV_OFFSET_A));
    let b = fnv_u64(catalog(FNV_OFFSET_B), items(FNV_OFFSET_B));
    (a, b)
}

/// `floor((residual + eps) / req)` per dimension — an estimate of how
/// many copies of `req` fit into `residual` in one step, under the
/// shared [`ResourceVec::fits`] tolerance.  Dimensions with zero
/// requirement impose no bound.
fn copy_bound(residual: &ResourceVec, req: &ResourceVec) -> u64 {
    let mut bound = u64::MAX;
    for (r, q) in residual.0.iter().zip(&req.0) {
        if *q > 0.0 {
            let fit = (r + crate::types::FIT_EPS) / q;
            let fit = if fit >= 0.0 { fit.floor() as u64 } else { 0 };
            bound = bound.min(fit);
        }
    }
    bound
}

/// One open bin holding class-level placements.
struct AggBin {
    bin_type: usize,
    residual: ResourceVec,
    /// `(class, choice, count)` runs in placement order.
    entries: Vec<(usize, usize, u32)>,
}

impl AggBin {
    fn record(&mut self, class: usize, choice: usize, count: u64) {
        if count == 0 {
            return;
        }
        if let Some(last) = self.entries.last_mut() {
            if last.0 == class && last.1 == choice {
                last.2 += count as u32;
                return;
            }
        }
        self.entries.push((class, choice, count as u32));
    }
}

/// Place up to `limit` copies of `req` into `residual`, bulk-subtracting
/// the provably-safe `floor(residual/req) - 1` copies without per-copy
/// checks and verifying the boundary copies with [`ResourceVec::fits`]
/// — so the placed count agrees exactly with per-item placement.
fn place_run(residual: &mut ResourceVec, req: &ResourceVec, limit: u64) -> u64 {
    let bulk = copy_bound(residual, req).saturating_sub(1).min(limit);
    for _ in 0..bulk {
        residual.sub_assign(req);
    }
    let mut placed = bulk;
    while placed < limit && req.fits(residual) {
        residual.sub_assign(req);
        placed += 1;
    }
    placed
}

/// Fill `bin` with copies of class `ci` under first-fit choice order:
/// walk choices in index order (CPU first), placing the maximal run of
/// each — exactly what consecutive per-item first-fit placements do,
/// since a choice that stops fitting never fits again as the residual
/// shrinks.
fn fill_first_fit(
    problem: &MvbpProblem,
    bin: &mut AggBin,
    ci: usize,
    rep: usize,
    remaining: &mut u64,
) {
    for (c, req) in problem.items[rep].choices.iter().enumerate() {
        if *remaining == 0 {
            return;
        }
        let placed = place_run(&mut bin.residual, req, *remaining);
        bin.record(ci, c, placed);
        *remaining -= placed;
    }
}

/// Fill `bin` with copies of class `ci` under best-fit scoring: each
/// copy takes the choice minimizing post-placement headroom *within
/// this bin*.  Staying inside the bin is sound because placing a copy
/// only lowers this bin's best slack below every untouched bin's (see
/// the argument in `solve_classes`), but the winning choice can switch
/// as the bin fills, so best-fit places copy-by-copy rather than in
/// floor-arithmetic runs.
fn fill_best_fit(
    problem: &MvbpProblem,
    bin: &mut AggBin,
    ci: usize,
    rep: usize,
    remaining: &mut u64,
) {
    let cap = &problem.bin_types[bin.bin_type].capacity;
    while *remaining > 0 {
        let mut best: Option<(usize, f64)> = None;
        for (c, req) in problem.items[rep].choices.iter().enumerate() {
            if let Some(slack) = heuristics::slack_after(&bin.residual, req, cap) {
                if best.map_or(true, |(_, bs)| slack < bs) {
                    best = Some((c, slack));
                }
            }
        }
        let Some((c, _)) = best else { return };
        bin.residual.sub_assign(&problem.items[rep].choices[c]);
        bin.record(ci, c, 1);
        *remaining -= 1;
    }
}

/// Pack `classes` of `problem` under `greedy`/`order` and expand back
/// to a per-item [`Solution`].  Returns `None` when some class fits no
/// bin type (the instance is unpackable).
///
/// Per-item equivalence: within one class, consecutive per-item
/// placements always target the same bin until it stops fitting —
/// already-rejected bins never re-fit (residuals only shrink), and for
/// best-fit, placing a copy strictly lowers the chosen bin's slack
/// below every untouched bin's, so the argmin stays inside the bin.
/// Aggregation turns that run structure into explicit batches.
pub(crate) fn solve_classes(
    problem: &MvbpProblem,
    classes: &[ItemClass],
    greedy: Greedy,
    order: ItemOrder,
) -> Option<Solution> {
    let mut class_order: Vec<usize> = (0..classes.len()).collect();
    order.sort_keys(problem, &mut class_order, |&ci| classes[ci].rep);

    let mut open: Vec<AggBin> = Vec::new();
    let mut index = ResidualIndex::new(problem.dims, &[]);
    let mut candidates: Vec<usize> = Vec::new();
    for &ci in &class_order {
        let rep = classes[ci].rep;
        let choices = &problem.items[rep].choices;
        let mut remaining = classes[ci].count() as u64;
        while remaining > 0 {
            // Pick the open bin the per-item heuristic would pick.
            let target = match greedy {
                Greedy::FirstFit => index.first_fit_any(choices).map(|(b, _)| b),
                Greedy::BestFit => {
                    index.may_fit(choices, &mut candidates);
                    let mut best: Option<(usize, f64)> = None;
                    for &b in &candidates {
                        let cap = &problem.bin_types[open[b].bin_type].capacity;
                        for req in choices.iter() {
                            if let Some(slack) =
                                heuristics::slack_after(&open[b].residual, req, cap)
                            {
                                if best.map_or(true, |(_, bs)| slack < bs) {
                                    best = Some((b, slack));
                                }
                            }
                        }
                    }
                    best.map(|(b, _)| b)
                }
            };
            let b = match target {
                Some(b) => b,
                None => {
                    // Open the cheapest feasible new bin (same selector
                    // as the per-item engine) seeded with one copy.
                    let (t, c) = heuristics::best_new_bin(problem, rep)?;
                    let mut residual = problem.bin_types[t].capacity.clone();
                    residual.sub_assign(&choices[c]);
                    let mut bin = AggBin { bin_type: t, residual, entries: Vec::new() };
                    bin.record(ci, c, 1);
                    remaining -= 1;
                    open.push(bin);
                    index.push(&open.last().expect("bin just opened").residual);
                    open.len() - 1
                }
            };
            let before = remaining;
            match greedy {
                Greedy::FirstFit => {
                    fill_first_fit(problem, &mut open[b], ci, rep, &mut remaining)
                }
                Greedy::BestFit => {
                    fill_best_fit(problem, &mut open[b], ci, rep, &mut remaining)
                }
            }
            index.update(b, &open[b].residual);
            // A fresh bin that admits nothing more for this class still
            // made progress via its seed copy; an *existing* bin the
            // index reported must admit at least one copy.
            debug_assert!(
                remaining < before || target.is_none() || remaining == 0,
                "aggregated fill must make progress"
            );
            if remaining == before && target.is_some() {
                // Defensive: should be unreachable (the index's fit test
                // equals the placement's); avoid a livelock regardless.
                return None;
            }
        }
    }
    Some(expand(classes, &open))
}

/// Expand class-level bins to per-item assignments: each class deals
/// its members out in ascending order as bins consume them.
fn expand(classes: &[ItemClass], open: &[AggBin]) -> Solution {
    let mut cursor = vec![0usize; classes.len()];
    let mut bins = Vec::with_capacity(open.len());
    for ab in open {
        let total: usize = ab.entries.iter().map(|&(_, _, k)| k as usize).sum();
        let mut assignments = Vec::with_capacity(total);
        for &(ci, choice, count) in &ab.entries {
            let start = cursor[ci];
            cursor[ci] += count as usize;
            for &member in &classes[ci].members[start..start + count as usize] {
                assignments.push((member as usize, choice));
            }
        }
        bins.push(PackedBin { bin_type: ab.bin_type, assignments });
    }
    Solution { bins }
}

/// Group a *subset* of the problem's items (e.g. a warm-start delta)
/// into multiplicity classes under the same bit-exact key as
/// [`group_classes`].  Members come back ascending with the rep as the
/// lowest member, whatever order `items` arrives in.
pub(crate) fn group_subset(problem: &MvbpProblem, items: &[usize]) -> Vec<ItemClass> {
    use std::collections::HashMap;
    let mut by_key: HashMap<Vec<u64>, usize> = HashMap::new();
    let mut classes: Vec<ItemClass> = Vec::new();
    for &i in items {
        let item = &problem.items[i];
        let mut key = Vec::with_capacity(1 + item.choices.len() * (problem.dims + 1));
        key.push(item.choices.len() as u64);
        for (c, choice) in item.choices.iter().enumerate() {
            for v in &choice.0 {
                key.push(v.to_bits());
            }
            key.push(problem.choice_cost(i, c).0 as u64);
        }
        match by_key.get(&key) {
            Some(&ci) => classes[ci].members.push(i as u32),
            None => {
                by_key.insert(key, classes.len());
                classes.push(ItemClass { rep: i, members: vec![i as u32] });
            }
        }
    }
    for class in &mut classes {
        class.members.sort_unstable();
        class.rep = class.members[0] as usize;
    }
    classes
}

/// Pack the members of `classes` (an already-grouped delta of unplaced
/// items) into the existing `open` bins under best-fit semantics — the
/// class-aggregated counterpart of [`heuristics::pack_into`], used by
/// the warm-start repacker when a churn epoch delivers many identical
/// streams at once.  One residual-index lookup per *run* instead of per
/// item; classes go hardest-first like the per-item delta order.
/// Returns `false` when some member fits no bin type.
pub(crate) fn pack_delta_classes(
    problem: &MvbpProblem,
    classes: &[ItemClass],
    open: &mut Vec<heuristics::OpenBin>,
) -> bool {
    let residuals: Vec<&ResourceVec> = open.iter().map(|b| &b.residual).collect();
    let mut index = ResidualIndex::new(problem.dims, &residuals);
    drop(residuals);

    let mut class_order: Vec<usize> = (0..classes.len()).collect();
    ItemOrder::HardestFirst.sort_keys(problem, &mut class_order, |&ci| classes[ci].rep);

    let mut candidates: Vec<usize> = Vec::new();
    for &ci in &class_order {
        let rep = classes[ci].rep;
        let choices = &problem.items[rep].choices;
        let members = &classes[ci].members;
        let mut cursor = 0usize; // next member to deal out
        while cursor < members.len() {
            // Best-fit target across surviving and newly opened bins.
            index.may_fit(choices, &mut candidates);
            let mut best: Option<(usize, f64)> = None;
            for &b in &candidates {
                let cap = &problem.bin_types[open[b].bin_type].capacity;
                for req in choices.iter() {
                    if let Some(slack) = heuristics::slack_after(&open[b].residual, req, cap) {
                        if best.map_or(true, |(_, bs)| slack < bs) {
                            best = Some((b, slack));
                        }
                    }
                }
            }
            let (b, opened) = match best {
                Some((b, _)) => (b, false),
                None => {
                    // Cheapest feasible new bin, seeded with one copy.
                    let Some((t, c)) = heuristics::best_new_bin(problem, rep) else {
                        return false;
                    };
                    let mut residual = problem.bin_types[t].capacity.clone();
                    residual.sub_assign(&choices[c]);
                    open.push(heuristics::OpenBin {
                        bin_type: t,
                        residual,
                        assignments: vec![(members[cursor] as usize, c)],
                    });
                    cursor += 1;
                    index.push(&open.last().expect("bin just opened").residual);
                    (open.len() - 1, true)
                }
            };
            // Fill the target copy-by-copy, each on its best choice,
            // until the bin admits none (the argmin stays inside the
            // bin — see `fill_best_fit`).
            let before = cursor;
            let cap = &problem.bin_types[open[b].bin_type].capacity;
            while cursor < members.len() {
                let mut pick: Option<(usize, f64)> = None;
                for (c, req) in choices.iter().enumerate() {
                    if let Some(slack) = heuristics::slack_after(&open[b].residual, req, cap) {
                        if pick.map_or(true, |(_, ps)| slack < ps) {
                            pick = Some((c, slack));
                        }
                    }
                }
                let Some((c, _)) = pick else { break };
                open[b].residual.sub_assign(&choices[c]);
                open[b].assignments.push((members[cursor] as usize, c));
                cursor += 1;
            }
            index.update(b, &open[b].residual);
            if cursor == before && !opened {
                // Defensive: the index reported a fitting bin, so at
                // least one copy must place (mirrors `solve_classes`).
                return false;
            }
        }
    }
    true
}

/// One aggregated greedy pass: group, pack classes, expand.  The
/// aggregated counterpart of [`heuristics::solve_greedy`] — identical
/// packing on instances whose distinct classes have distinct ordering
/// measures (always true away from exact float ties).
pub fn solve_greedy_aggregated(
    problem: &MvbpProblem,
    greedy: Greedy,
    order: ItemOrder,
) -> Option<Solution> {
    problem.validate().ok()?;
    let classes = group_classes(problem);
    solve_classes(problem, &classes, greedy, order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::problem::test_fixtures::small_problem;
    use crate::packing::problem::{BinType, Item};
    use crate::packing::solve_greedy;
    use crate::types::Dollars;

    /// A high-multiplicity instance: `counts[i]` copies of template `i`.
    fn replicated(templates: &[Item], counts: &[usize], bin_types: Vec<BinType>) -> MvbpProblem {
        let mut items = Vec::new();
        for (t, count) in templates.iter().zip(counts) {
            for i in 0..*count {
                items.push(Item {
                    id: format!("{}-{i}", t.id),
                    choices: t.choices.clone(),
                });
            }
        }
        MvbpProblem {
            dims: bin_types[0].capacity.dims(),
            bin_types,
            items,
            choice_costs: vec![],
        }
    }

    fn fixture() -> MvbpProblem {
        let base = small_problem();
        replicated(&base.items, &[7, 5, 9], base.bin_types)
    }

    #[test]
    fn grouping_merges_identical_items_only() {
        let p = fixture();
        let classes = group_classes(&p);
        assert_eq!(classes.len(), 3);
        assert_eq!(
            classes.iter().map(ItemClass::count).collect::<Vec<_>>(),
            vec![7, 5, 9]
        );
        let total: usize = classes.iter().map(ItemClass::count).sum();
        assert_eq!(total, p.items.len());
        // Members ascend and reps are the first member.
        for class in &classes {
            assert!(class.members.windows(2).all(|w| w[0] < w[1]));
            assert_eq!(class.rep, class.members[0] as usize);
        }
        // All-distinct items never pay for aggregation.
        let distinct = small_problem();
        let dc = group_classes(&distinct);
        assert_eq!(dc.len(), 3);
        assert!(!aggregation_pays(dc.len(), distinct.items.len()));
        assert!(aggregation_pays(classes.len(), p.items.len()));
    }

    #[test]
    fn capped_grouping_aborts_past_the_class_budget() {
        // 3 distinct templates: a cap of 2 aborts (routing gate), a cap
        // at or above the true class count returns the full grouping.
        let p = fixture();
        assert!(group_classes_capped(&p, 2).is_none());
        assert_eq!(group_classes_capped(&p, 3).unwrap().len(), 3);
        let distinct = small_problem();
        assert!(group_classes_capped(&distinct, 1).is_none());
        assert_eq!(group_classes_capped(&distinct, 3).unwrap().len(), 3);
    }

    #[test]
    fn aggregated_matches_per_item_on_every_arm() {
        let p = fixture();
        for greedy in [Greedy::FirstFit, Greedy::BestFit] {
            for order in ItemOrder::ALL {
                let per_item = solve_greedy(&p, greedy, order).unwrap();
                let agg = solve_greedy_aggregated(&p, greedy, order).unwrap();
                agg.validate(&p)
                    .unwrap_or_else(|e| panic!("{greedy:?}/{order:?}: {e}"));
                assert_eq!(
                    agg.cost(&p),
                    per_item.cost(&p),
                    "{greedy:?}/{order:?}: aggregated cost diverged"
                );
                assert_eq!(
                    agg.bins_per_type(&p),
                    per_item.bins_per_type(&p),
                    "{greedy:?}/{order:?}: bin mix diverged"
                );
            }
        }
    }

    #[test]
    fn copy_bound_and_place_run_agree_with_fits() {
        let residual = ResourceVec::from_slice(&[10.0, 6.0]);
        let req = ResourceVec::from_slice(&[3.0, 1.0]);
        assert_eq!(copy_bound(&residual, &req), 3);
        let mut r = residual.clone();
        assert_eq!(place_run(&mut r, &req, 100), 3);
        assert!(!req.fits(&r));
        // The limit caps the run.
        let mut r2 = residual.clone();
        assert_eq!(place_run(&mut r2, &req, 2), 2);
        // Zero-requirement dimensions impose no bound.
        let free = ResourceVec::from_slice(&[0.0, 1.0]);
        assert_eq!(copy_bound(&residual, &free), 6);
        // Exact-boundary counts match repeated fits checks (the epsilon
        // keeps 3 × 2.0 fitting capacity 6.0).
        let tight = ResourceVec::from_slice(&[6.0, 6.0]);
        let two = ResourceVec::from_slice(&[2.0, 2.0]);
        assert_eq!(copy_bound(&tight, &two), 3);
    }

    #[test]
    fn infeasible_class_returns_none() {
        let mut p = fixture();
        p.items.push(Item {
            id: "huge-0".into(),
            choices: vec![ResourceVec::from_slice(&[100.0, 0.0])],
        });
        p.items.push(Item {
            id: "huge-1".into(),
            choices: vec![ResourceVec::from_slice(&[100.0, 0.0])],
        });
        for greedy in [Greedy::FirstFit, Greedy::BestFit] {
            assert!(solve_greedy_aggregated(&p, greedy, ItemOrder::HardestFirst).is_none());
        }
    }

    #[test]
    fn single_class_fleet_packs_exactly() {
        // 12 copies of a 3.0-requirement item into cap-10 bins: 3 per
        // bin, 4 bins — the run arithmetic must not over- or underfill.
        let p = replicated(
            &[Item {
                id: "s".into(),
                choices: vec![ResourceVec::from_slice(&[3.0])],
            }],
            &[12],
            vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[10.0]),
            }],
        );
        for greedy in [Greedy::FirstFit, Greedy::BestFit] {
            let s = solve_greedy_aggregated(&p, greedy, ItemOrder::HardestFirst).unwrap();
            s.validate(&p).unwrap();
            assert_eq!(s.bins.len(), 4, "{greedy:?}: floor(10/3)=3 per bin");
            assert_eq!(s.cost(&p), Dollars::from_f64(4.0));
        }
    }

    #[test]
    fn delta_classes_match_the_per_item_delta_packer() {
        let p = fixture();
        let delta = crate::packing::Decreasing::order(&p);
        let mut per_item: Vec<heuristics::OpenBin> = Vec::new();
        assert!(heuristics::pack_into(&p, Greedy::BestFit, &delta, &mut per_item));
        let classes = group_subset(&p, &delta);
        assert!(aggregation_pays(classes.len(), delta.len()));
        let mut aggregated: Vec<heuristics::OpenBin> = Vec::new();
        assert!(pack_delta_classes(&p, &classes, &mut aggregated));
        let s_pi = heuristics::finish(per_item);
        let s_cl = heuristics::finish(aggregated);
        s_cl.validate(&p).unwrap();
        assert_eq!(s_cl.cost(&p), s_pi.cost(&p));
        assert_eq!(s_cl.bins_per_type(&p), s_pi.bins_per_type(&p));
        // An unpackable class reports failure like the per-item packer.
        let mut q = fixture();
        for i in 0..2 {
            q.items.push(Item {
                id: format!("huge-{i}"),
                choices: vec![ResourceVec::from_slice(&[100.0, 0.0])],
            });
        }
        let all = crate::packing::Decreasing::order(&q);
        let qc = group_subset(&q, &all);
        let mut bins: Vec<heuristics::OpenBin> = Vec::new();
        assert!(!pack_delta_classes(&q, &qc, &mut bins));
    }

    #[test]
    fn expansion_assigns_every_member_once() {
        let p = fixture();
        let s = solve_greedy_aggregated(&p, Greedy::BestFit, ItemOrder::SumDecreasing).unwrap();
        let mut seen = vec![false; p.items.len()];
        for bin in &s.bins {
            for &(item, choice) in &bin.assignments {
                assert!(!seen[item], "item {item} assigned twice");
                assert!(choice < p.items[item].choices.len());
                seen[item] = true;
            }
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn fingerprint_is_item_order_independent_and_content_sensitive() {
        let p = fixture();
        let base = problem_fingerprint(&p);

        // Reversing the item list (and renaming ids) leaves the
        // fingerprint unchanged: it digests the class multiset.
        let mut reversed = p.clone();
        reversed.items.reverse();
        for (i, item) in reversed.items.iter_mut().enumerate() {
            item.id = format!("renamed-{i}");
        }
        assert_eq!(problem_fingerprint(&reversed), base);

        // Any change to a requirement, the catalog, or a price moves it.
        let mut req = p.clone();
        req.items[0].choices[0].0[0] += 1.0;
        assert_ne!(problem_fingerprint(&req), base);

        let mut priced = p.clone();
        priced.bin_types[0].cost = priced.bin_types[0].cost + Dollars(1);
        assert_ne!(problem_fingerprint(&priced), base);

        let mut grown = p.clone();
        grown.items.push(p.items[0].clone());
        assert_ne!(problem_fingerprint(&grown), base);
    }
}
