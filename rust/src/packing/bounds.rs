//! Dual-feasible-function (DFF) lower bounds for MVBP.
//!
//! A *dual-feasible function* `f : [0,1] -> [0,1]` satisfies: for every
//! finite set `S` with `sum(S) <= 1`, `sum(f(x) for x in S) <= 1`.
//! Given any weighting `lambda >= 0` of the resource dimensions,
//! project every item to the scalar size
//!
//! ```text
//!   s_i = min over choices c of  sum_d lambda_d * w[i][c][d]
//! ```
//!
//! (the min over choices is the multiple-choice relaxation: whichever
//! choice the optimum picks, its projected size is at least `s_i`) and
//! every bin type to the scalar capacity `C_t = sum_d lambda_d *
//! cap[t][d]`.  In any feasible solution the items of one bin of type
//! `t` satisfy `sum s_i <= C_t`, so `sum f(s_i / C_t) <= 1` and the
//! bin's cost `cost_t` is at least `cost_t * sum f(s_i / C_t)`.
//! Summing over bins and relaxing each item to its cheapest
//! *lambda-feasible* type (`C_t >= s_i`, since no other type can hold
//! it at all under `lambda`):
//!
//! ```text
//!   OPT  >=  sum_i  min over {t : C_t >= s_i}  cost_t * f(s_i / C_t)
//! ```
//!
//! This holds for **every** `(lambda, f)` pair, so the bound is the max
//! over a small family:
//!
//! * `lambda` — one unit vector per dimension (recovering sharpened
//!   per-dimension bounds) plus the combined weighting `lambda_d =
//!   1/roomiest_d`, which is what makes the bound bite on mixed
//!   CPU+GPU catalogs: per-dimension relaxations are nearly vacuous
//!   there (every stream can zero its GPU demand by choosing CPU and
//!   shrink its CPU demand by choosing GPU), but no choice can zero
//!   *both* coordinates of a combined projection at once.
//! * `f` — the identity, the Fekete–Schepers family `f^(k)` for `k in
//!   {1,2,3}`, and threshold functions `u_eps` for `eps in {1/4, 1/3,
//!   1/2}`.
//!
//! Float safety: every rounding in this module errs **downward** so the
//! result stays a true lower bound.  `f^(k)` maps near-boundary inputs
//! to the smaller adjacent step (an exact multiple `x = m/(k+1)` is
//! worth `m/(k+1) >= (m-1)/k`, so `(m-1)/k` is safe whichever side of
//! the boundary the true value lies on), the threshold function takes
//! its lower branch inside an epsilon of each breakpoint, and the final
//! sum gets a relative haircut before flooring to micro-dollars.

use super::problem::MvbpProblem;
use crate::types::Dollars;
use std::sync::atomic::{AtomicBool, Ordering};

/// Ablation knob for benchmarks: when set, [`certified_lower_bound`]
/// (`packing::solver`) skips the DFF term so old-vs-new bound quality
/// can be measured in one process.  Not a tuning surface — production
/// paths leave it off.
///
/// [`certified_lower_bound`]: super::certified_lower_bound
static DFF_DISABLED: AtomicBool = AtomicBool::new(false);

/// Disable (or re-enable) the DFF term of the certified bound.
pub fn set_dff_disabled(disabled: bool) {
    DFF_DISABLED.store(disabled, Ordering::SeqCst);
}

/// Is the DFF term currently disabled?  See [`set_dff_disabled`].
pub fn dff_disabled() -> bool {
    DFF_DISABLED.load(Ordering::SeqCst)
}

/// Relative tolerance for boundary decisions; all uses round the bound
/// *down*.
const REL_EPS: f64 = 1e-9;

/// The DFF family evaluated per `(lambda, f)` pair.
#[derive(Clone, Copy)]
enum Dff {
    /// `f(x) = x` — the fractional (size-proportional) relaxation.
    Identity,
    /// Fekete–Schepers `f^(k)`: `floor(x * (k+1)) / k` away from exact
    /// multiples of `1/(k+1)`.  Jumps items just over `1/(k+1)` up to
    /// `1/k` of a bin — e.g. `k = 1` counts any item over half a bin as
    /// a whole bin.
    FeketeSchepers(u32),
    /// Threshold `u_eps` (`eps <= 1/2`): 1 above `1 - eps`, `x` in the
    /// middle, 0 below `eps`.  Writes off small items to round big ones
    /// up.
    Threshold(f64),
}

impl Dff {
    fn eval(self, x: f64) -> f64 {
        match self {
            Dff::Identity => x,
            Dff::FeketeSchepers(k) => {
                let k = k as f64;
                let y = x * (k + 1.0);
                let r = y.round();
                // Within an epsilon of an integer the true step is
                // ambiguous under floats; take the smaller adjacent
                // value (see module doc).
                let m = if (y - r).abs() < REL_EPS { r - 1.0 } else { y.floor() };
                m.max(0.0) / k
            }
            Dff::Threshold(eps) => {
                if x > 1.0 - eps + REL_EPS {
                    1.0
                } else if x >= eps + REL_EPS {
                    x
                } else {
                    0.0
                }
            }
        }
    }
}

const DFFS: [Dff; 7] = [
    Dff::Identity,
    Dff::FeketeSchepers(1),
    Dff::FeketeSchepers(2),
    Dff::FeketeSchepers(3),
    Dff::Threshold(0.25),
    Dff::Threshold(1.0 / 3.0),
    Dff::Threshold(0.5),
];

/// Best DFF lower bound on the optimal cost of `problem` over the
/// family described in the module docs.  Always a valid lower bound
/// (zero when nothing in the family bites); combine it with other
/// bounds by `max`.
pub fn dff_lower_bound(problem: &MvbpProblem) -> Dollars {
    if problem.items.is_empty() || problem.bin_types.is_empty() {
        return Dollars::ZERO;
    }
    let dims = problem.dims;
    let mut roomiest = vec![0.0f64; dims];
    for bt in &problem.bin_types {
        for (d, room) in roomiest.iter_mut().enumerate() {
            let cap = bt.capacity[d];
            if cap.is_finite() && cap > *room {
                *room = cap;
            }
        }
    }

    let mut lambdas: Vec<Vec<f64>> = Vec::new();
    for d in 0..dims {
        if roomiest[d] > 0.0 {
            let mut unit = vec![0.0; dims];
            unit[d] = 1.0;
            lambdas.push(unit);
        }
    }
    let combined: Vec<f64> = roomiest
        .iter()
        .map(|&room| if room > 0.0 { 1.0 / room } else { 0.0 })
        .collect();
    if combined.iter().any(|&v| v > 0.0) {
        lambdas.push(combined);
    }

    let costs: Vec<f64> = problem.bin_types.iter().map(|bt| bt.cost.as_f64()).collect();
    let mut best = Dollars::ZERO;
    for lambda in &lambdas {
        // Projected capacity per type and projected size per item (min
        // over choices — the multiple-choice relaxation).
        let caps: Vec<f64> = problem
            .bin_types
            .iter()
            .map(|bt| (0..dims).map(|d| lambda[d] * bt.capacity[d].max(0.0)).sum())
            .collect();
        let sizes: Vec<f64> = problem
            .items
            .iter()
            .map(|item| {
                item.choices
                    .iter()
                    .map(|req| {
                        (0..dims)
                            .map(|d| {
                                let w = req[d];
                                if w.is_finite() {
                                    lambda[d] * w.max(0.0)
                                } else {
                                    0.0
                                }
                            })
                            .sum::<f64>()
                    })
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();

        for f in DFFS {
            let mut sum = 0.0f64;
            for &size in &sizes {
                let size = if size.is_finite() { size } else { 0.0 };
                let mut cheapest = f64::INFINITY;
                for (t, &cap) in caps.iter().enumerate() {
                    if cap < size * (1.0 - REL_EPS) {
                        continue; // type cannot hold this item under lambda
                    }
                    let x = if cap > 0.0 { (size / cap).clamp(0.0, 1.0) } else { 0.0 };
                    let value = costs[t] * f.eval(x);
                    if value < cheapest {
                        cheapest = value;
                    }
                }
                if cheapest.is_finite() {
                    sum += cheapest;
                }
            }
            // Haircut before flooring: summation error must never push
            // the bound above the true optimum.
            let floored = Dollars((sum * (1.0 - REL_EPS) * 1e6).floor().max(0.0) as i64);
            if floored > best {
                best = floored;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::problem::{test_fixtures, BinType, Item};
    use crate::packing::solve_exact;
    use crate::types::ResourceVec;

    fn rv(values: &[f64]) -> ResourceVec {
        ResourceVec::from_slice(values)
    }

    fn bin(name: &str, cost: f64, cap: &[f64]) -> BinType {
        BinType { name: name.into(), cost: Dollars::from_f64(cost), capacity: rv(cap) }
    }

    fn item(id: &str, choices: &[&[f64]]) -> Item {
        Item { id: id.into(), choices: choices.iter().map(|c| rv(c)).collect() }
    }

    #[test]
    fn empty_problem_is_zero() {
        let problem = MvbpProblem {
            dims: 1,
            bin_types: vec![bin("b", 1.0, &[1.0])],
            items: vec![],
            choice_costs: vec![],
        };
        assert_eq!(dff_lower_bound(&problem), Dollars::ZERO);
    }

    #[test]
    fn fekete_schepers_closes_the_three_sixths_gap() {
        // Three items of size 6 in bins of 10: fractional bound 1.8,
        // true optimum 3 (no two items share a bin).  f^(1) rounds each
        // item past half a bin up to a whole one.
        let problem = MvbpProblem {
            dims: 1,
            bin_types: vec![bin("b", 1.0, &[10.0])],
            items: (0..3).map(|i| item(&format!("i{i}"), &[&[6.0]])).collect(),
            choice_costs: vec![],
        };
        let lb = dff_lower_bound(&problem);
        assert!(lb >= Dollars::from_f64(2.999), "got {lb}");
        assert!(lb <= Dollars::from_f64(3.0), "got {lb}");
    }

    #[test]
    fn combined_lambda_sees_cross_dimension_demand() {
        // Mixed CPU+GPU with choices: per-dimension relaxations are
        // nearly vacuous (each dimension can be zeroed or shrunk by the
        // other choice), but the combined projection cannot be dodged.
        let problem = MvbpProblem {
            dims: 2,
            bin_types: vec![bin("cpu", 1.0, &[4.0, 0.0]), bin("gpu", 1.0, &[4.0, 4.0])],
            items: (0..4)
                .map(|i| item(&format!("s{i}"), &[&[4.0, 0.0], &[0.5, 4.0]]))
                .collect(),
            choice_costs: vec![],
        };
        let lb = dff_lower_bound(&problem);
        // Combined lambda = (1/4, 1/4): s_i = min(1.0, 1.125) = 1.0,
        // C_cpu = 1, C_gpu = 2 -> identity term min(1.0, 0.5) = 0.5
        // per item, so the bound reaches ~$2 where per-dimension
        // reasoning stalls near $0.5.
        assert!(lb >= Dollars::from_f64(1.9), "got {lb}");
        // Sanity: OPT = $4 (one item per bin either way).
        assert!(lb <= Dollars::from_f64(4.0), "got {lb}");
    }

    #[test]
    fn near_boundary_rounding_is_conservative() {
        // x = 0.25 puts f^(3) exactly on a step boundary (y = 1.0); the
        // safe reading is the lower step, and the identity term still
        // certifies a full bin for four such items.
        let problem = MvbpProblem {
            dims: 1,
            bin_types: vec![bin("b", 1.0, &[10.0])],
            items: (0..4).map(|i| item(&format!("i{i}"), &[&[2.5]])).collect(),
            choice_costs: vec![],
        };
        let lb = dff_lower_bound(&problem);
        assert!(lb >= Dollars::from_f64(0.99), "got {lb}");
        assert!(lb <= Dollars::from_f64(1.0), "got {lb}");
    }

    #[test]
    fn never_exceeds_the_exact_optimum_on_the_small_fixture() {
        let problem = test_fixtures::small_problem();
        let exact = solve_exact(&problem).expect("fixture is feasible");
        assert!(exact.proven_optimal);
        let opt = exact.solution.cost(&problem);
        let lb = dff_lower_bound(&problem);
        assert!(lb <= opt, "dff {lb} exceeds optimum {opt}");
    }
}
