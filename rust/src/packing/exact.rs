//! Exact branch-and-bound solver for MVBP.
//!
//! Two search modes share the node budget, deadline, incumbent seeding,
//! and the per-dimension cost lower bound:
//!
//! * **Per-item** — depth-first over items (sorted hardest-first),
//!   branching on "place item in an existing open bin" and "open a new
//!   bin of each type", under each requirement choice, with
//!   equal-residual bins deduplicated per node.  This is the path for
//!   (mostly) distinct items.
//! * **Class-multiplicity** — when aggregation pays (at least two items
//!   per [`ItemClass`] on average, the same gate the greedy layer
//!   uses), the search branches on "place `k` copies of class `c` into
//!   bin `b`" instead.  Identical items are interchangeable, so a
//!   per-item search wastes `k!` permutations per bin content; class
//!   branching enumerates each *distribution* once, under three
//!   symmetry-breaking rules: classes are placed in a fixed
//!   (hardest-first) order; within a class, placements walk a
//!   nondecreasing `(bin, choice)` cursor with copy counts tried
//!   largest-first; and among equal-residual bins of one type only the
//!   first is branched (swapping the full remaining contents of two
//!   equal-residual bins is a cost-preserving bijection).  Fresh bins
//!   open in non-increasing `(type, choice, count)` key order, so the
//!   interchangeable-at-open bins of one class are enumerated as a
//!   sorted sequence rather than every permutation.
//!
//! Both modes prune on a per-dimension cost lower bound, evaluated in
//! the *parent* before a child is expanded — a dominated child costs
//! one bound evaluation instead of a call frame and a unit of node
//! budget (for run branching this is the difference between paying
//! O(1) and O(k) nodes per dominated run family).  The search is seeded
//! with an incumbent — best-fit-decreasing by default, or any solution
//! the caller already holds (the portfolio seeds its racing winner via
//! [`BranchAndBound::solve_seeded`]).  Proven optimal at paper scale
//! (validated against brute force in the property tests); past the node
//! budget or wall-clock deadline it degrades gracefully to the best
//! incumbent and reports `proven_optimal = false`.

use super::aggregate::{self, ItemClass};
use super::heuristics::solve_best_fit;
use super::problem::{MvbpProblem, PackedBin, Solution};
use crate::types::{Dollars, ResourceVec};
use std::time::Instant;

/// Result of an exact solve, with optimality metadata.
#[derive(Clone, Debug)]
pub struct ExactResult {
    pub solution: Solution,
    pub proven_optimal: bool,
    pub nodes_explored: u64,
}

/// Branch-and-bound solver with a configurable node budget and an
/// optional wall-clock deadline.
pub struct BranchAndBound {
    pub node_budget: u64,
    /// Abandon the proof (keep the incumbent) once this instant passes.
    /// Checked every [`DEADLINE_CHECK_MASK`]+1 nodes, so the overrun is
    /// bounded by one check interval.  The node budget remains the
    /// deterministic cap; the deadline is the safety net for instances
    /// whose nodes are individually expensive.
    pub deadline: Option<Instant>,
    /// Force per-item branching even on instances where class-
    /// multiplicity branching would engage.  Off by default; benches
    /// flip it to measure what class branching buys under an identical
    /// node cap.
    pub per_item: bool,
}

/// Deadline polling interval mask (checked when `nodes & MASK == 0`).
const DEADLINE_CHECK_MASK: u64 = 0xFFF;

impl Default for BranchAndBound {
    fn default() -> Self {
        // Generous for paper-scale instances (<=30 items, <=4 types):
        // those need well under 1e5 nodes.
        BranchAndBound { node_budget: 5_000_000, deadline: None, per_item: false }
    }
}

struct OpenBin {
    bin_type: usize,
    residual: ResourceVec,
    assignments: Vec<(usize, usize)>,
}

struct SearchCtx<'p> {
    problem: &'p MvbpProblem,
    /// Item indices in search order (hardest first).
    order: Vec<usize>,
    /// Per dimension: max over bin types of capacity/cost — the best
    /// capacity purchasable per dollar, used in the lower bound.
    dim_efficiency: Vec<f64>,
    /// Suffix sums of `min_req` along `order`: `suffix_demand[k]` = total
    /// relaxed demand of items `order[k..]`.
    suffix_demand: Vec<ResourceVec>,
    best_cost: Dollars,
    best: Option<Solution>,
    nodes: u64,
    node_budget: u64,
    deadline: Option<Instant>,
    exhausted: bool,
}

/// Per-dimension "best capacity per dollar" vector shared by both
/// search modes' lower bounds.
fn dim_efficiencies(problem: &MvbpProblem) -> Vec<f64> {
    (0..problem.dims)
        .map(|d| {
            problem
                .bin_types
                .iter()
                .map(|bt| {
                    let cost = bt.cost.as_f64();
                    if cost > 0.0 {
                        bt.capacity[d] / cost
                    } else {
                        f64::INFINITY
                    }
                })
                .fold(0.0, f64::max)
        })
        .collect()
}

/// Element-wise max capacity over bin types (the "roomiest bin" the
/// hardness measure normalizes against).
fn roomiest_capacity(problem: &MvbpProblem) -> ResourceVec {
    ResourceVec(
        (0..problem.dims)
            .map(|d| {
                problem
                    .bin_types
                    .iter()
                    .map(|bt| bt.capacity[d])
                    .fold(0.0, f64::max)
            })
            .collect(),
    )
}

/// Relaxed one-copy demand of an item: the min over choices per
/// dimension (whatever choice the optimum picks needs at least this).
fn relaxed_req(problem: &MvbpProblem, item: usize) -> ResourceVec {
    ResourceVec(
        (0..problem.dims)
            .map(|d| {
                problem.items[item]
                    .choices
                    .iter()
                    .map(|c| c[d])
                    .fold(f64::INFINITY, f64::min)
            })
            .collect(),
    )
}

impl BranchAndBound {
    /// Solve to proven optimality (within the node budget), seeding the
    /// search with a fresh best-fit-decreasing incumbent.
    ///
    /// Returns `None` iff some item fits in no bin under any choice.
    pub fn solve(&self, problem: &MvbpProblem) -> Option<ExactResult> {
        self.solve_seeded(problem, solve_best_fit(problem))
    }

    /// Like [`BranchAndBound::solve`] but seeded with a caller-supplied
    /// incumbent (e.g. the portfolio's racing winner), skipping the
    /// internal BFD pass.  An invalid or absent incumbent degrades to an
    /// unseeded search.
    pub fn solve_seeded(
        &self,
        problem: &MvbpProblem,
        incumbent: Option<Solution>,
    ) -> Option<ExactResult> {
        problem.validate().ok()?;
        if !problem.infeasible_items().is_empty() {
            return None;
        }
        if problem.items.is_empty() {
            return Some(ExactResult {
                solution: Solution::default(),
                proven_optimal: true,
                nodes_explored: 0,
            });
        }

        // Incumbent (may not exist for pathological instances); an
        // invalid seed is discarded rather than poisoning the bound.
        let incumbent = incumbent.filter(|s| s.validate(problem).is_ok());

        // Class-multiplicity branching engages exactly when aggregation
        // pays (the capped grouping aborts past items/2 classes, the
        // same "at least two items per class on average" gate the
        // greedy layer uses).
        if !self.per_item {
            if let Some(classes) =
                aggregate::group_classes_capped(problem, problem.items.len() / 2)
            {
                return self.solve_class_search(problem, classes, incumbent);
            }
        }

        // Hardest-first ordering: by decreasing "best-case fullness" —
        // min over choices of the max capacity ratio vs the roomiest bin.
        let roomiest = roomiest_capacity(problem);
        let mut order: Vec<usize> = (0..problem.items.len()).collect();
        let hardness = |i: usize| -> f64 {
            problem.items[i]
                .choices
                .iter()
                .map(|c| c.max_ratio(&roomiest))
                .fold(f64::INFINITY, f64::min)
        };
        // total_cmp for the same reason as `Decreasing::order`: never
        // panic mid-sort, even on inputs validate would reject.
        order.sort_by(|&a, &b| hardness(b).total_cmp(&hardness(a)));

        let dim_efficiency = dim_efficiencies(problem);

        let min_req: Vec<ResourceVec> = (0..problem.items.len())
            .map(|i| relaxed_req(problem, i))
            .collect();

        let mut suffix_demand = vec![ResourceVec::zeros(problem.dims); order.len() + 1];
        for k in (0..order.len()).rev() {
            suffix_demand[k] = suffix_demand[k + 1].add(&min_req[order[k]]);
        }

        let best_cost = incumbent
            .as_ref()
            .map(|s| s.cost(problem))
            .unwrap_or(Dollars(i64::MAX));

        let mut ctx = SearchCtx {
            problem,
            order,
            dim_efficiency,
            suffix_demand,
            best_cost,
            best: incumbent,
            nodes: 0,
            node_budget: self.node_budget,
            deadline: self.deadline,
            exhausted: false,
        };
        let mut open: Vec<OpenBin> = Vec::new();
        dfs(&mut ctx, 0, Dollars::ZERO, &mut open);

        ctx.best.map(|solution| ExactResult {
            solution,
            proven_optimal: !ctx.exhausted,
            nodes_explored: ctx.nodes,
        })
    }

    /// The class-multiplicity search: branch on "place `k` copies of
    /// the current class into bin `b` under choice `c`" (see the module
    /// docs for the symmetry-breaking rules).
    fn solve_class_search(
        &self,
        problem: &MvbpProblem,
        mut classes: Vec<ItemClass>,
        incumbent: Option<Solution>,
    ) -> Option<ExactResult> {
        // Hardest representative first — the class-level analogue of
        // the per-item ordering (ties keep first-occurrence order:
        // sort_by is stable).
        let roomiest = roomiest_capacity(problem);
        let hardness = |rep: usize| -> f64 {
            problem.items[rep]
                .choices
                .iter()
                .map(|c| c.max_ratio(&roomiest))
                .fold(f64::INFINITY, f64::min)
        };
        classes.sort_by(|a, b| hardness(b.rep).total_cmp(&hardness(a.rep)));

        let dim_efficiency = dim_efficiencies(problem);
        let min_req: Vec<ResourceVec> = classes
            .iter()
            .map(|class| relaxed_req(problem, class.rep))
            .collect();

        let mut suffix_demand = vec![ResourceVec::zeros(problem.dims); classes.len() + 1];
        for k in (0..classes.len()).rev() {
            let mut acc = suffix_demand[k + 1].clone();
            let count = classes[k].count() as f64;
            for d in 0..problem.dims {
                acc.0[d] += min_req[k][d] * count;
            }
            suffix_demand[k] = acc;
        }

        let best_cost = incumbent
            .as_ref()
            .map(|s| s.cost(problem))
            .unwrap_or(Dollars(i64::MAX));
        let first_count = classes[0].count() as u32;

        let mut ctx = ClassCtx {
            problem,
            classes,
            min_req,
            dim_efficiency,
            suffix_demand,
            best_cost,
            best: incumbent,
            nodes: 0,
            node_budget: self.node_budget,
            deadline: self.deadline,
            exhausted: false,
        };
        let mut bins: Vec<ClassBin> = Vec::new();
        distribute(&mut ctx, 0, first_count, Dollars::ZERO, &mut bins, (0, 0), None);

        ctx.best.map(|solution| ExactResult {
            solution,
            proven_optimal: !ctx.exhausted,
            nodes_explored: ctx.nodes,
        })
    }
}

/// Cost lower bound for the remaining items `order[k..]` given open-bin
/// residual capacity: extra demand beyond residuals, priced at the best
/// capacity-per-dollar in each dimension; the max over dimensions is a
/// valid bound because every dollar buys capacity in all dims at once.
fn lower_bound(ctx: &SearchCtx, k: usize, open: &[OpenBin]) -> f64 {
    let demand = &ctx.suffix_demand[k];
    let mut bound: f64 = 0.0;
    for d in 0..ctx.problem.dims {
        if demand[d] <= 0.0 {
            continue;
        }
        let residual: f64 = open.iter().map(|b| b.residual[d].max(0.0)).sum();
        let extra = demand[d] - residual;
        if extra > 0.0 && ctx.dim_efficiency[d] > 0.0 {
            bound = bound.max(extra / ctx.dim_efficiency[d]);
        }
    }
    bound
}

/// The child's entry prune (`cost + lower_bound >= incumbent`),
/// evaluated in the parent on the already-mutated state: dominated
/// children are skipped without being expanded, so they cost one bound
/// evaluation instead of a call frame and a unit of node budget.
fn prune_child(ctx: &SearchCtx, k: usize, cost: Dollars, open: &[OpenBin]) -> bool {
    cost.as_f64() + lower_bound(ctx, k, open) >= ctx.best_cost.as_f64() - 1e-9
}

fn dfs(ctx: &mut SearchCtx, k: usize, cost: Dollars, open: &mut Vec<OpenBin>) {
    ctx.nodes += 1;
    if ctx.nodes > ctx.node_budget {
        ctx.exhausted = true;
        return;
    }
    if ctx.nodes & DEADLINE_CHECK_MASK == 0 {
        if let Some(deadline) = ctx.deadline {
            if Instant::now() >= deadline {
                ctx.exhausted = true;
                return;
            }
        }
    }
    if k == ctx.order.len() {
        if cost < ctx.best_cost {
            ctx.best_cost = cost;
            ctx.best = Some(Solution {
                bins: open
                    .iter()
                    .map(|b| PackedBin {
                        bin_type: b.bin_type,
                        assignments: b.assignments.clone(),
                    })
                    .collect(),
            });
        }
        return;
    }
    // Prune: even the relaxed remainder cannot beat the incumbent.
    let lb = cost.as_f64() + lower_bound(ctx, k, open);
    if lb >= ctx.best_cost.as_f64() - 1e-9 {
        return;
    }

    let item_idx = ctx.order[k];
    // Copy the &'p problem reference out of the context so requirement
    // vectors borrow the problem, not `ctx` — the branch loops used to
    // clone a heap-backed ResourceVec per (bin, choice) node to appease
    // the borrow checker.
    let problem = ctx.problem;
    let n_choices = problem.items[item_idx].choices.len();

    // Branch 1: place into an existing open bin.  Dedupe branches that
    // land in bins with identical (type, residual) — permutation symmetry.
    let mut tried: Vec<(usize, Vec<i64>)> = Vec::new();
    for b in 0..open.len() {
        let key: Vec<i64> = open[b]
            .residual
            .0
            .iter()
            .map(|v| (v * 1e6).round() as i64)
            .collect();
        if tried.iter().any(|(t, k2)| *t == open[b].bin_type && *k2 == key) {
            continue;
        }
        tried.push((open[b].bin_type, key));
        for c in 0..n_choices {
            let req = &problem.items[item_idx].choices[c];
            if req.fits(&open[b].residual) {
                let step_cost = cost + problem.choice_cost(item_idx, c);
                open[b].residual.sub_assign(req);
                if prune_child(ctx, k + 1, step_cost, open) {
                    open[b].residual.add_assign(req);
                    continue;
                }
                open[b].assignments.push((item_idx, c));
                dfs(ctx, k + 1, step_cost, open);
                open[b].assignments.pop();
                open[b].residual.add_assign(req);
                if ctx.exhausted {
                    return;
                }
            }
        }
    }

    // Branch 2: open a new bin of each type.
    for (t, bt) in problem.bin_types.iter().enumerate() {
        let new_cost = cost + bt.cost;
        if new_cost >= ctx.best_cost {
            continue;
        }
        for c in 0..n_choices {
            let req = &problem.items[item_idx].choices[c];
            if req.fits(&bt.capacity) {
                let step_cost = new_cost + problem.choice_cost(item_idx, c);
                let mut residual = bt.capacity.clone();
                residual.sub_assign(req);
                open.push(OpenBin {
                    bin_type: t,
                    residual,
                    assignments: vec![(item_idx, c)],
                });
                if prune_child(ctx, k + 1, step_cost, open) {
                    open.pop();
                    continue;
                }
                dfs(ctx, k + 1, step_cost, open);
                open.pop();
                if ctx.exhausted {
                    return;
                }
            }
        }
    }
}

/// One open bin of the class search.
struct ClassBin {
    bin_type: usize,
    residual: ResourceVec,
    /// `(class position in search order, choice, copies)` in placement
    /// order.
    entries: Vec<(usize, usize, u32)>,
}

struct ClassCtx<'p> {
    problem: &'p MvbpProblem,
    /// Classes in search order (hardest representative first).
    classes: Vec<ItemClass>,
    /// Relaxed one-copy demand per class (min over choices per dim).
    min_req: Vec<ResourceVec>,
    dim_efficiency: Vec<f64>,
    /// `suffix_demand[k]` = relaxed demand of classes `k..`, counts
    /// included.
    suffix_demand: Vec<ResourceVec>,
    best_cost: Dollars,
    best: Option<Solution>,
    nodes: u64,
    node_budget: u64,
    deadline: Option<Instant>,
    exhausted: bool,
}

/// Class-search analogue of [`lower_bound`]: relaxed demand of the
/// unplaced copies of class `ci` plus every later class, minus open
/// residuals, priced at the best capacity-per-dollar.
fn class_lower_bound(ctx: &ClassCtx, ci: usize, remaining: u32, bins: &[ClassBin]) -> f64 {
    let mut bound: f64 = 0.0;
    for d in 0..ctx.problem.dims {
        let demand = ctx.suffix_demand[ci + 1][d] + ctx.min_req[ci][d] * remaining as f64;
        if demand <= 0.0 {
            continue;
        }
        let residual: f64 = bins.iter().map(|b| b.residual[d].max(0.0)).sum();
        let extra = demand - residual;
        if extra > 0.0 && ctx.dim_efficiency[d] > 0.0 {
            bound = bound.max(extra / ctx.dim_efficiency[d]);
        }
    }
    bound
}

/// Class-search analogue of [`prune_child`]: evaluate the child's entry
/// prune in the parent.  This is what keeps run branching cheap — the
/// `k-1` shorter runs under a dominated maximal run each cost one bound
/// evaluation, not an expanded node (the per-copy search pays a node per
/// copy no matter what).
fn prune_class_child(
    ctx: &ClassCtx,
    ci: usize,
    remaining: u32,
    cost: Dollars,
    bins: &[ClassBin],
) -> bool {
    cost.as_f64() + class_lower_bound(ctx, ci, remaining, bins) >= ctx.best_cost.as_f64() - 1e-9
}

/// Expand the class-level bins to per-item assignments (members dealt
/// out ascending, exactly like `aggregate::expand`) and record the
/// solution if it beats the incumbent.
fn record_class_leaf(ctx: &mut ClassCtx, cost: Dollars, bins: &[ClassBin]) {
    if cost >= ctx.best_cost {
        return;
    }
    ctx.best_cost = cost;
    let mut cursor = vec![0usize; ctx.classes.len()];
    let mut out = Vec::with_capacity(bins.len());
    for bin in bins {
        let total: usize = bin.entries.iter().map(|&(_, _, k)| k as usize).sum();
        let mut assignments = Vec::with_capacity(total);
        for &(ci, choice, count) in &bin.entries {
            let start = cursor[ci];
            cursor[ci] += count as usize;
            for &member in &ctx.classes[ci].members[start..start + count as usize] {
                assignments.push((member as usize, choice));
            }
        }
        out.push(PackedBin { bin_type: bin.bin_type, assignments });
    }
    ctx.best = Some(Solution { bins: out });
}

/// Distribute the `remaining` unplaced copies of class `ci` and recurse
/// into later classes.
///
/// `from` is the `(bin, choice)` cursor: within one class, placements
/// are generated in strictly increasing cursor order, so each
/// *distribution* (set of `(bin, choice, count)` runs) is enumerated
/// exactly once regardless of placement order.  `last_fresh` is the
/// `(type, choice, count)` key of the class's most recent fresh-opened
/// bin; fresh opens must not increase in that key, which sorts the
/// interchangeable-at-open bins of one class into a canonical sequence.
#[allow(clippy::too_many_arguments)]
fn distribute(
    ctx: &mut ClassCtx,
    ci: usize,
    remaining: u32,
    cost: Dollars,
    bins: &mut Vec<ClassBin>,
    from: (usize, usize),
    last_fresh: Option<(usize, usize, u32)>,
) {
    ctx.nodes += 1;
    if ctx.nodes > ctx.node_budget {
        ctx.exhausted = true;
        return;
    }
    if ctx.nodes & DEADLINE_CHECK_MASK == 0 {
        if let Some(deadline) = ctx.deadline {
            if Instant::now() >= deadline {
                ctx.exhausted = true;
                return;
            }
        }
    }
    if remaining == 0 {
        if ci + 1 == ctx.classes.len() {
            record_class_leaf(ctx, cost, bins);
            return;
        }
        let next_count = ctx.classes[ci + 1].count() as u32;
        distribute(ctx, ci + 1, next_count, cost, bins, (0, 0), None);
        return;
    }
    // Prune: even the relaxed remainder cannot beat the incumbent.
    let lb = cost.as_f64() + class_lower_bound(ctx, ci, remaining, bins);
    if lb >= ctx.best_cost.as_f64() - 1e-9 {
        return;
    }

    let problem = ctx.problem;
    let rep = ctx.classes[ci].rep;
    let n_choices = problem.items[rep].choices.len();

    // Branch 1: runs into existing bins at or past the cursor, with the
    // same equal-(type, residual) dedup as the per-item search —
    // swapping the full remaining contents of two equal-residual bins
    // of one type is a cost-preserving bijection, so branching the
    // first of each group is enough.
    let mut tried: Vec<(usize, Vec<i64>)> = Vec::new();
    for b in from.0..bins.len() {
        let key: Vec<i64> = bins[b]
            .residual
            .0
            .iter()
            .map(|v| (v * 1e6).round() as i64)
            .collect();
        if tried.iter().any(|(t, k2)| *t == bins[b].bin_type && *k2 == key) {
            continue;
        }
        tried.push((bins[b].bin_type, key));
        let c_start = if b == from.0 { from.1 } else { 0 };
        for c in c_start..n_choices {
            let req = &problem.items[rep].choices[c];
            // Subtract copies one by one under the shared `fits`
            // tolerance; `placed` copies are subtracted on exit.
            let mut placed: u32 = 0;
            while placed < remaining && req.fits(&bins[b].residual) {
                bins[b].residual.sub_assign(req);
                placed += 1;
            }
            if placed == 0 {
                continue;
            }
            // Largest run first; `k` copies stay subtracted while the
            // branch for `k` runs.
            let mut k = placed;
            loop {
                let run_cost = cost + problem.choice_cost(rep, c) * k;
                if !prune_class_child(ctx, ci, remaining - k, run_cost, bins) {
                    bins[b].entries.push((ci, c, k));
                    distribute(ctx, ci, remaining - k, run_cost, bins, (b, c + 1), last_fresh);
                    bins[b].entries.pop();
                    if ctx.exhausted {
                        for _ in 0..k {
                            bins[b].residual.add_assign(req);
                        }
                        return;
                    }
                }
                bins[b].residual.add_assign(req);
                if k == 1 {
                    break;
                }
                k -= 1;
            }
        }
    }

    // Branch 2: open a fresh bin with a run of this class, in
    // non-increasing (type, choice, count) key order.
    for (t, bt) in problem.bin_types.iter().enumerate() {
        let new_cost = cost + bt.cost;
        if new_cost >= ctx.best_cost {
            continue;
        }
        for c in 0..n_choices {
            let req = &problem.items[rep].choices[c];
            if !req.fits(&bt.capacity) {
                continue;
            }
            let mut probe = bt.capacity.clone();
            let mut max_k: u32 = 0;
            while max_k < remaining && req.fits(&probe) {
                probe.sub_assign(req);
                max_k += 1;
            }
            for k in (1..=max_k).rev() {
                if let Some(last) = last_fresh {
                    if (t, c, k) > last {
                        continue;
                    }
                }
                let mut residual = bt.capacity.clone();
                for _ in 0..k {
                    residual.sub_assign(req);
                }
                let run_cost = new_cost + problem.choice_cost(rep, c) * k;
                bins.push(ClassBin { bin_type: t, residual, entries: vec![(ci, c, k)] });
                if prune_class_child(ctx, ci, remaining - k, run_cost, bins) {
                    bins.pop();
                    continue;
                }
                let idx = bins.len() - 1;
                distribute(ctx, ci, remaining - k, run_cost, bins, (idx, c + 1), Some((t, c, k)));
                bins.pop();
                if ctx.exhausted {
                    return;
                }
            }
        }
    }
}

/// Convenience wrapper: default budget, discard metadata.
pub fn solve_exact(problem: &MvbpProblem) -> Option<Solution> {
    BranchAndBound::default()
        .solve(problem)
        .map(|r| r.solution)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::problem::test_fixtures::small_problem;
    use crate::packing::problem::{BinType, Item};

    #[test]
    fn packs_small_problem_optimally() {
        let p = small_problem();
        let r = BranchAndBound::default().solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
        assert!(r.proven_optimal);
        // Optimal: everything in one big bin ($1.8) beats two small ($2.0).
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(1.8));
    }

    #[test]
    fn empty_problem_is_trivially_optimal() {
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[1.0]),
            }],
            items: vec![],
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert!(r.solution.bins.is_empty());
        assert!(r.proven_optimal);
    }

    #[test]
    fn infeasible_returns_none() {
        let mut p = small_problem();
        p.items.push(Item {
            id: "huge".into(),
            choices: vec![ResourceVec::from_slice(&[100.0, 0.0])],
        });
        assert!(BranchAndBound::default().solve(&p).is_none());
    }

    #[test]
    fn choice_changes_optimum() {
        // One bin type (cap 4); items 3+3 don't colocate, but 3+1 does if
        // the second item picks its alternative choice.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[4.0]),
            }],
            items: vec![
                Item {
                    id: "x".into(),
                    choices: vec![ResourceVec::from_slice(&[3.0])],
                },
                Item {
                    id: "y".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[3.0]),
                        ResourceVec::from_slice(&[1.0]),
                    ],
                },
            ],
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert_eq!(r.solution.bins.len(), 1);
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(1.0));
        // y must have picked choice 1.
        let picked: Vec<_> = r.solution.bins[0]
            .assignments
            .iter()
            .filter(|(i, _)| *i == 1)
            .collect();
        assert_eq!(picked[0].1, 1);
    }

    #[test]
    fn prefers_cheaper_type_mix() {
        // Big bin is overkill for one tiny item.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![
                BinType {
                    name: "small".into(),
                    cost: Dollars::from_f64(0.4),
                    capacity: ResourceVec::from_slice(&[2.0]),
                },
                BinType {
                    name: "big".into(),
                    cost: Dollars::from_f64(1.0),
                    capacity: ResourceVec::from_slice(&[10.0]),
                },
            ],
            items: vec![Item {
                id: "t".into(),
                choices: vec![ResourceVec::from_slice(&[1.0])],
            }],
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(0.4));
    }

    #[test]
    fn node_budget_degrades_gracefully() {
        let p = small_problem();
        let r = BranchAndBound { node_budget: 1, ..Default::default() }
            .solve(&p)
            .unwrap();
        // Budget hit: still returns the BFD incumbent, flagged non-optimal.
        r.solution.validate(&p).unwrap();
        assert!(!r.proven_optimal);
    }

    #[test]
    fn expired_deadline_degrades_to_the_incumbent() {
        // A deadline already in the past: the first polled check aborts
        // the proof, but the seeded incumbent still comes back valid.
        let p = small_problem();
        let bb = BranchAndBound {
            node_budget: u64::MAX,
            deadline: Some(Instant::now() - std::time::Duration::from_millis(1)),
            ..Default::default()
        };
        let r = bb.solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
    }

    #[test]
    fn seeded_incumbent_is_used_and_invalid_seeds_are_discarded() {
        let p = small_problem();
        let good = crate::packing::solve_first_fit(&p).unwrap();
        let r = BranchAndBound::default()
            .solve_seeded(&p, Some(good.clone()))
            .unwrap();
        assert!(r.proven_optimal);
        assert!(r.solution.cost(&p) <= good.cost(&p));

        // An empty (invalid: items unpacked) seed must not be trusted.
        let r2 = BranchAndBound::default()
            .solve_seeded(&p, Some(Solution::default()))
            .unwrap();
        assert!(r2.proven_optimal);
        assert_eq!(r2.solution.cost(&p), r.solution.cost(&p));
    }

    /// `counts[i]` copies of `small_problem` item `i` — the class path
    /// engages whenever aggregation pays.
    fn replicated_fixture(counts: &[usize]) -> MvbpProblem {
        let base = small_problem();
        let mut items = Vec::new();
        for (t, item) in base.items.iter().enumerate() {
            for i in 0..counts[t] {
                items.push(Item {
                    id: format!("c{t}-{i}"),
                    choices: item.choices.clone(),
                });
            }
        }
        MvbpProblem {
            dims: base.dims,
            bin_types: base.bin_types.clone(),
            items,
            choice_costs: vec![],
        }
    }

    #[test]
    fn class_search_matches_per_item_on_replicated_fixture() {
        let p = replicated_fixture(&[4, 3, 5]); // 12 items, 3 classes
        let class = BranchAndBound::default().solve(&p).unwrap();
        let per_item = BranchAndBound { per_item: true, ..Default::default() }
            .solve(&p)
            .unwrap();
        class.solution.validate(&p).unwrap();
        per_item.solution.validate(&p).unwrap();
        assert!(class.proven_optimal, "class search must prove this scale");
        assert!(per_item.proven_optimal, "per-item search must prove this scale");
        assert_eq!(class.solution.cost(&p), per_item.solution.cost(&p));
    }

    #[test]
    fn class_search_node_budget_degrades_gracefully() {
        let p = replicated_fixture(&[6, 6, 6]);
        let r = BranchAndBound { node_budget: 1, ..Default::default() }
            .solve(&p)
            .unwrap();
        r.solution.validate(&p).unwrap();
        assert!(!r.proven_optimal);
    }

    #[test]
    fn class_search_uses_choices_for_colocation() {
        // Two copies each of x=[3] and y=[3]|[1] into cap-4 bins: the
        // optimum pairs every x with a y on its alternative choice.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[4.0]),
            }],
            items: vec![
                Item { id: "x0".into(), choices: vec![ResourceVec::from_slice(&[3.0])] },
                Item { id: "x1".into(), choices: vec![ResourceVec::from_slice(&[3.0])] },
                Item {
                    id: "y0".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[3.0]),
                        ResourceVec::from_slice(&[1.0]),
                    ],
                },
                Item {
                    id: "y1".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[3.0]),
                        ResourceVec::from_slice(&[1.0]),
                    ],
                },
            ],
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
        assert!(r.proven_optimal);
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(2.0));
    }

    #[test]
    fn single_class_fleet_proves_tight_packing() {
        // 12 copies of [3] into cap-10 bins: 3 per bin, 4 bins, proven.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[10.0]),
            }],
            items: (0..12)
                .map(|i| Item {
                    id: format!("s{i}"),
                    choices: vec![ResourceVec::from_slice(&[3.0])],
                })
                .collect(),
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
        assert!(r.proven_optimal);
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(4.0));
    }
}
