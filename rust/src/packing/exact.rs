//! Exact branch-and-bound solver for MVBP.
//!
//! Depth-first search over items (sorted hardest-first), branching on
//! "place item in an existing open bin" and "open a new bin of each
//! type", under each requirement choice.  Pruned by a per-dimension
//! cost lower bound and seeded with an incumbent — best-fit-decreasing
//! by default, or any solution the caller already holds (the portfolio
//! seeds its racing winner via [`BranchAndBound::solve_seeded`]).
//! Proven optimal at paper scale (validated against brute force in the
//! property tests); past the node budget or wall-clock deadline it
//! degrades gracefully to the best incumbent and reports
//! `proven_optimal = false`.

use super::heuristics::solve_best_fit;
use super::problem::{MvbpProblem, PackedBin, Solution};
use crate::types::{Dollars, ResourceVec};
use std::time::Instant;

/// Result of an exact solve, with optimality metadata.
#[derive(Clone, Debug)]
pub struct ExactResult {
    pub solution: Solution,
    pub proven_optimal: bool,
    pub nodes_explored: u64,
}

/// Branch-and-bound solver with a configurable node budget and an
/// optional wall-clock deadline.
pub struct BranchAndBound {
    pub node_budget: u64,
    /// Abandon the proof (keep the incumbent) once this instant passes.
    /// Checked every [`DEADLINE_CHECK_MASK`]+1 nodes, so the overrun is
    /// bounded by one check interval.  The node budget remains the
    /// deterministic cap; the deadline is the safety net for instances
    /// whose nodes are individually expensive.
    pub deadline: Option<Instant>,
}

/// Deadline polling interval mask (checked when `nodes & MASK == 0`).
const DEADLINE_CHECK_MASK: u64 = 0xFFF;

impl Default for BranchAndBound {
    fn default() -> Self {
        // Generous for paper-scale instances (<=30 items, <=4 types):
        // those need well under 1e5 nodes.
        BranchAndBound { node_budget: 5_000_000, deadline: None }
    }
}

struct OpenBin {
    bin_type: usize,
    residual: ResourceVec,
    assignments: Vec<(usize, usize)>,
}

struct SearchCtx<'p> {
    problem: &'p MvbpProblem,
    /// Item indices in search order (hardest first).
    order: Vec<usize>,
    /// Per dimension: max over bin types of capacity/cost — the best
    /// capacity purchasable per dollar, used in the lower bound.
    dim_efficiency: Vec<f64>,
    /// Suffix sums of `min_req` along `order`: `suffix_demand[k]` = total
    /// relaxed demand of items `order[k..]`.
    suffix_demand: Vec<ResourceVec>,
    best_cost: Dollars,
    best: Option<Solution>,
    nodes: u64,
    node_budget: u64,
    deadline: Option<Instant>,
    exhausted: bool,
}

impl BranchAndBound {
    /// Solve to proven optimality (within the node budget), seeding the
    /// search with a fresh best-fit-decreasing incumbent.
    ///
    /// Returns `None` iff some item fits in no bin under any choice.
    pub fn solve(&self, problem: &MvbpProblem) -> Option<ExactResult> {
        self.solve_seeded(problem, solve_best_fit(problem))
    }

    /// Like [`BranchAndBound::solve`] but seeded with a caller-supplied
    /// incumbent (e.g. the portfolio's racing winner), skipping the
    /// internal BFD pass.  An invalid or absent incumbent degrades to an
    /// unseeded search.
    pub fn solve_seeded(
        &self,
        problem: &MvbpProblem,
        incumbent: Option<Solution>,
    ) -> Option<ExactResult> {
        problem.validate().ok()?;
        if !problem.infeasible_items().is_empty() {
            return None;
        }
        if problem.items.is_empty() {
            return Some(ExactResult {
                solution: Solution::default(),
                proven_optimal: true,
                nodes_explored: 0,
            });
        }

        // Hardest-first ordering: by decreasing "best-case fullness" —
        // min over choices of the max capacity ratio vs the roomiest bin.
        let roomiest = ResourceVec(
            (0..problem.dims)
                .map(|d| {
                    problem
                        .bin_types
                        .iter()
                        .map(|bt| bt.capacity[d])
                        .fold(0.0, f64::max)
                })
                .collect(),
        );
        let mut order: Vec<usize> = (0..problem.items.len()).collect();
        let hardness = |i: usize| -> f64 {
            problem.items[i]
                .choices
                .iter()
                .map(|c| c.max_ratio(&roomiest))
                .fold(f64::INFINITY, f64::min)
        };
        // total_cmp for the same reason as `Decreasing::order`: never
        // panic mid-sort, even on inputs validate would reject.
        order.sort_by(|&a, &b| hardness(b).total_cmp(&hardness(a)));

        let dim_efficiency: Vec<f64> = (0..problem.dims)
            .map(|d| {
                problem
                    .bin_types
                    .iter()
                    .map(|bt| {
                        let cost = bt.cost.as_f64();
                        if cost > 0.0 {
                            bt.capacity[d] / cost
                        } else {
                            f64::INFINITY
                        }
                    })
                    .fold(0.0, f64::max)
            })
            .collect();

        let min_req: Vec<ResourceVec> = problem
            .items
            .iter()
            .map(|it| {
                ResourceVec(
                    (0..problem.dims)
                        .map(|d| {
                            it.choices
                                .iter()
                                .map(|c| c[d])
                                .fold(f64::INFINITY, f64::min)
                        })
                        .collect(),
                )
            })
            .collect();

        let mut suffix_demand = vec![ResourceVec::zeros(problem.dims); order.len() + 1];
        for k in (0..order.len()).rev() {
            suffix_demand[k] = suffix_demand[k + 1].add(&min_req[order[k]]);
        }

        // Incumbent (may not exist for pathological instances); an
        // invalid seed is discarded rather than poisoning the bound.
        let incumbent = incumbent.filter(|s| s.validate(problem).is_ok());
        let best_cost = incumbent
            .as_ref()
            .map(|s| s.cost(problem))
            .unwrap_or(Dollars(i64::MAX));

        let mut ctx = SearchCtx {
            problem,
            order,
            dim_efficiency,
            suffix_demand,
            best_cost,
            best: incumbent,
            nodes: 0,
            node_budget: self.node_budget,
            deadline: self.deadline,
            exhausted: false,
        };
        let mut open: Vec<OpenBin> = Vec::new();
        dfs(&mut ctx, 0, Dollars::ZERO, &mut open);

        ctx.best.map(|solution| ExactResult {
            solution,
            proven_optimal: !ctx.exhausted,
            nodes_explored: ctx.nodes,
        })
    }
}

/// Cost lower bound for the remaining items `order[k..]` given open-bin
/// residual capacity: extra demand beyond residuals, priced at the best
/// capacity-per-dollar in each dimension; the max over dimensions is a
/// valid bound because every dollar buys capacity in all dims at once.
fn lower_bound(ctx: &SearchCtx, k: usize, open: &[OpenBin]) -> f64 {
    let demand = &ctx.suffix_demand[k];
    let mut bound: f64 = 0.0;
    for d in 0..ctx.problem.dims {
        if demand[d] <= 0.0 {
            continue;
        }
        let residual: f64 = open.iter().map(|b| b.residual[d].max(0.0)).sum();
        let extra = demand[d] - residual;
        if extra > 0.0 && ctx.dim_efficiency[d] > 0.0 {
            bound = bound.max(extra / ctx.dim_efficiency[d]);
        }
    }
    bound
}

fn dfs(ctx: &mut SearchCtx, k: usize, cost: Dollars, open: &mut Vec<OpenBin>) {
    ctx.nodes += 1;
    if ctx.nodes > ctx.node_budget {
        ctx.exhausted = true;
        return;
    }
    if ctx.nodes & DEADLINE_CHECK_MASK == 0 {
        if let Some(deadline) = ctx.deadline {
            if Instant::now() >= deadline {
                ctx.exhausted = true;
                return;
            }
        }
    }
    if k == ctx.order.len() {
        if cost < ctx.best_cost {
            ctx.best_cost = cost;
            ctx.best = Some(Solution {
                bins: open
                    .iter()
                    .map(|b| PackedBin {
                        bin_type: b.bin_type,
                        assignments: b.assignments.clone(),
                    })
                    .collect(),
            });
        }
        return;
    }
    // Prune: even the relaxed remainder cannot beat the incumbent.
    let lb = cost.as_f64() + lower_bound(ctx, k, open);
    if lb >= ctx.best_cost.as_f64() - 1e-9 {
        return;
    }

    let item_idx = ctx.order[k];
    // Copy the &'p problem reference out of the context so requirement
    // vectors borrow the problem, not `ctx` — the branch loops used to
    // clone a heap-backed ResourceVec per (bin, choice) node to appease
    // the borrow checker.
    let problem = ctx.problem;
    let n_choices = problem.items[item_idx].choices.len();

    // Branch 1: place into an existing open bin.  Dedupe branches that
    // land in bins with identical (type, residual) — permutation symmetry.
    let mut tried: Vec<(usize, Vec<i64>)> = Vec::new();
    for b in 0..open.len() {
        let key: Vec<i64> = open[b]
            .residual
            .0
            .iter()
            .map(|v| (v * 1e6).round() as i64)
            .collect();
        if tried.iter().any(|(t, k2)| *t == open[b].bin_type && *k2 == key) {
            continue;
        }
        tried.push((open[b].bin_type, key));
        for c in 0..n_choices {
            let req = &problem.items[item_idx].choices[c];
            if req.fits(&open[b].residual) {
                open[b].residual.sub_assign(req);
                open[b].assignments.push((item_idx, c));
                dfs(ctx, k + 1, cost, open);
                open[b].assignments.pop();
                open[b].residual.add_assign(req);
                if ctx.exhausted {
                    return;
                }
            }
        }
    }

    // Branch 2: open a new bin of each type.
    for (t, bt) in problem.bin_types.iter().enumerate() {
        let new_cost = cost + bt.cost;
        if new_cost >= ctx.best_cost {
            continue;
        }
        for c in 0..n_choices {
            let req = &problem.items[item_idx].choices[c];
            if req.fits(&bt.capacity) {
                let mut residual = bt.capacity.clone();
                residual.sub_assign(req);
                open.push(OpenBin {
                    bin_type: t,
                    residual,
                    assignments: vec![(item_idx, c)],
                });
                dfs(ctx, k + 1, new_cost, open);
                open.pop();
                if ctx.exhausted {
                    return;
                }
            }
        }
    }
}

/// Convenience wrapper: default budget, discard metadata.
pub fn solve_exact(problem: &MvbpProblem) -> Option<Solution> {
    BranchAndBound::default()
        .solve(problem)
        .map(|r| r.solution)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::problem::test_fixtures::small_problem;
    use crate::packing::problem::{BinType, Item};

    #[test]
    fn packs_small_problem_optimally() {
        let p = small_problem();
        let r = BranchAndBound::default().solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
        assert!(r.proven_optimal);
        // Optimal: everything in one big bin ($1.8) beats two small ($2.0).
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(1.8));
    }

    #[test]
    fn empty_problem_is_trivially_optimal() {
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[1.0]),
            }],
            items: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert!(r.solution.bins.is_empty());
        assert!(r.proven_optimal);
    }

    #[test]
    fn infeasible_returns_none() {
        let mut p = small_problem();
        p.items.push(Item {
            id: "huge".into(),
            choices: vec![ResourceVec::from_slice(&[100.0, 0.0])],
        });
        assert!(BranchAndBound::default().solve(&p).is_none());
    }

    #[test]
    fn choice_changes_optimum() {
        // One bin type (cap 4); items 3+3 don't colocate, but 3+1 does if
        // the second item picks its alternative choice.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[4.0]),
            }],
            items: vec![
                Item {
                    id: "x".into(),
                    choices: vec![ResourceVec::from_slice(&[3.0])],
                },
                Item {
                    id: "y".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[3.0]),
                        ResourceVec::from_slice(&[1.0]),
                    ],
                },
            ],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert_eq!(r.solution.bins.len(), 1);
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(1.0));
        // y must have picked choice 1.
        let picked: Vec<_> = r.solution.bins[0]
            .assignments
            .iter()
            .filter(|(i, _)| *i == 1)
            .collect();
        assert_eq!(picked[0].1, 1);
    }

    #[test]
    fn prefers_cheaper_type_mix() {
        // Big bin is overkill for one tiny item.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![
                BinType {
                    name: "small".into(),
                    cost: Dollars::from_f64(0.4),
                    capacity: ResourceVec::from_slice(&[2.0]),
                },
                BinType {
                    name: "big".into(),
                    cost: Dollars::from_f64(1.0),
                    capacity: ResourceVec::from_slice(&[10.0]),
                },
            ],
            items: vec![Item {
                id: "t".into(),
                choices: vec![ResourceVec::from_slice(&[1.0])],
            }],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(0.4));
    }

    #[test]
    fn node_budget_degrades_gracefully() {
        let p = small_problem();
        let r = BranchAndBound { node_budget: 1, ..Default::default() }
            .solve(&p)
            .unwrap();
        // Budget hit: still returns the BFD incumbent, flagged non-optimal.
        r.solution.validate(&p).unwrap();
        assert!(!r.proven_optimal);
    }

    #[test]
    fn expired_deadline_degrades_to_the_incumbent() {
        // A deadline already in the past: the first polled check aborts
        // the proof, but the seeded incumbent still comes back valid.
        let p = small_problem();
        let bb = BranchAndBound {
            node_budget: u64::MAX,
            deadline: Some(Instant::now() - std::time::Duration::from_millis(1)),
        };
        let r = bb.solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
    }

    #[test]
    fn seeded_incumbent_is_used_and_invalid_seeds_are_discarded() {
        let p = small_problem();
        let good = crate::packing::solve_first_fit(&p).unwrap();
        let r = BranchAndBound::default()
            .solve_seeded(&p, Some(good.clone()))
            .unwrap();
        assert!(r.proven_optimal);
        assert!(r.solution.cost(&p) <= good.cost(&p));

        // An empty (invalid: items unpacked) seed must not be trusted.
        let r2 = BranchAndBound::default()
            .solve_seeded(&p, Some(Solution::default()))
            .unwrap();
        assert!(r2.proven_optimal);
        assert_eq!(r2.solution.cost(&p), r.solution.cost(&p));
    }
}
