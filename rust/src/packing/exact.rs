//! Exact branch-and-bound solver for MVBP.
//!
//! Two search modes share the node budget, deadline, incumbent seeding,
//! and the per-dimension cost lower bound:
//!
//! * **Per-item** — depth-first over items (sorted hardest-first),
//!   branching on "place item in an existing open bin" and "open a new
//!   bin of each type", under each requirement choice, with
//!   equal-residual bins deduplicated per node.  This is the path for
//!   (mostly) distinct items.
//! * **Class-multiplicity** — when aggregation pays (at least two items
//!   per [`ItemClass`] on average, the same gate the greedy layer
//!   uses), the search branches on "place `k` copies of class `c` into
//!   bin `b`" instead.  Identical items are interchangeable, so a
//!   per-item search wastes `k!` permutations per bin content; class
//!   branching enumerates each *distribution* once, under three
//!   symmetry-breaking rules: classes are placed in a fixed
//!   (hardest-first) order; within a class, placements walk a
//!   nondecreasing `(bin, choice)` cursor with copy counts tried
//!   largest-first; and among equal-residual bins of one type only the
//!   first is branched (swapping the full remaining contents of two
//!   equal-residual bins is a cost-preserving bijection).  Fresh bins
//!   open in non-increasing `(type, choice, count)` key order, so the
//!   interchangeable-at-open bins of one class are enumerated as a
//!   sorted sequence rather than every permutation.
//!
//! Both modes prune on a per-dimension cost lower bound, evaluated in
//! the *parent* before a child is expanded — a dominated child costs
//! one bound evaluation instead of a call frame and a unit of node
//! budget.  Everything the bound needs that is a function of the
//! problem alone (capacity-per-dollar, relaxed demands, suffix sums)
//! is precomputed once per solve into a read-only [`BoundCtx`] shared
//! by every worker, so the per-node cost is one pass over dimensions.
//! The search is seeded with an incumbent — best-fit-decreasing by
//! default, or any solution the caller already holds (the portfolio
//! seeds its racing winner via [`BranchAndBound::solve_seeded`]; an
//! invalid seed is discarded and surfaced via
//! [`ExactResult::seed_dropped`]).
//!
//! # Multi-root parallel search
//!
//! With [`BranchAndBound::threads`] != 1 the solve runs in two phases:
//!
//! 1. **Frontier expansion** (sequential): the root is expanded
//!    level-synchronously — each round replaces every unexplored
//!    subtree by its children, kept in DFS order, pruning only against
//!    the *seed* incumbent — until the frontier holds enough subtree
//!    tasks to feed the workers, the tree is enumerated outright, or
//!    [`FRONTIER_MAX_ROUNDS`] rounds pass.  In class mode one round
//!    expands the first unplaced class's `(bin, choice, count)`
//!    placements; in per-item mode, the next item's choices.  Complete
//!    solutions met along the way are kept as indexed leaf candidates.
//! 2. **Subtree workers**: the frontier tasks run on the portfolio's
//!    scoped task pool (`race_tasks`), each a full DFS over its
//!    subtree.  Workers prune against their own local incumbent
//!    (starting from the seed) exactly like the sequential search, and
//!    *additionally* against a shared incumbent — an `AtomicU64`
//!    holding the bits of the globally best recorded cost, maintained
//!    with a lock-free `fetch_min` (solution costs are non-negative,
//!    and non-negative IEEE doubles order like their bit patterns).
//!
//! # Determinism contract
//!
//! A run that completes its proof (`proven_optimal`) returns a
//! bit-identical solution for *any* thread count: the first leaf in
//! sequential DFS order attaining the optimal cost.  Two rules make
//! this hold.  The shared incumbent prunes only *strictly* costlier
//! subtrees (`bound >= shared + 1e-9`, vs the sequential-local
//! `bound >= local - 1e-9`), so a subtree that could still tie the
//! optimum is never shed on another worker's account; and the winner
//! is chosen by the fixed tie-break (cost, then frontier entry index),
//! never by arrival order.  Costs are whole micro-dollars, so distinct
//! costs differ by >= 1e-6 and the epsilons cannot cross.
//! `nodes_explored` — and therefore *where* a budget- or
//! deadline-capped run stops — is **not** part of the contract for
//! threads > 1: pruning depends on when workers publish improvements,
//! so only completed proofs are bit-identical.
//!
//! # Budget semantics
//!
//! `node_budget` and the deadline bind globally.  Workers flush their
//! local node count into a shared atomic in chunks of
//! [`SHARED_FLUSH_MASK`]` + 1` nodes and trip a shared stop flag once
//! the global count passes the budget or the deadline fires, so the
//! budget overrun is bounded by `threads x chunk`.  Sequential runs
//! (`threads == 1`) keep the exact single-counter semantics they have
//! always had.

use super::aggregate::{self, ItemClass};
use super::heuristics::solve_best_fit;
use super::problem::{MvbpProblem, PackedBin, Solution};
use super::solver::{race_chunks_remote, race_tasks, HedgeCfg, RemoteOutcome};
use crate::net::fleet::{Fleet, RpcClass, RpcOutcome};
use crate::net::proto::{
    dollars_from_json, dollars_to_json, problem_from_json, problem_to_json, resources_from_json,
    resources_to_json, solution_from_json, solution_to_json,
};
use crate::types::{Dollars, ResourceVec};
use crate::util::error::{anyhow, ensure, Result};
use crate::util::json::Json;
use crate::util::profiling;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Result of an exact solve, with optimality metadata.
#[derive(Clone, Debug)]
pub struct ExactResult {
    pub solution: Solution,
    pub proven_optimal: bool,
    pub nodes_explored: u64,
    /// The caller-supplied incumbent failed validation and was
    /// discarded — the solve ran cold.  Surfaced (plus the
    /// `exact:seed-dropped` profiling counter) so a broken seed path
    /// upstream cannot masquerade as an intentional cold solve.
    pub seed_dropped: bool,
}

/// Branch-and-bound solver with a configurable node budget, an
/// optional wall-clock deadline, and a worker thread count for the
/// multi-root parallel search.
pub struct BranchAndBound {
    pub node_budget: u64,
    /// Abandon the proof (keep the incumbent) once this instant passes.
    /// Checked every [`DEADLINE_CHECK_MASK`]+1 nodes, so the overrun is
    /// bounded by one check interval.  The node budget remains the
    /// deterministic cap; the deadline is the safety net for instances
    /// whose nodes are individually expensive.
    pub deadline: Option<Instant>,
    /// Force per-item branching even on instances where class-
    /// multiplicity branching would engage.  Off by default; benches
    /// flip it to measure what class branching buys under an identical
    /// node cap.
    pub per_item: bool,
    /// Worker threads for the multi-root parallel search: `1` (the
    /// default) is the classic sequential search, `0` means one per
    /// available core, any value is clamped to 16.  Completed proofs
    /// are bit-identical for every setting (see the module docs).
    pub threads: usize,
}

/// Deadline polling interval mask (checked when `nodes & MASK == 0`).
const DEADLINE_CHECK_MASK: u64 = 0xFFF;

/// Parallel workers flush their local node count into the shared
/// global counter — and poll the global budget and stop flag — every
/// `SHARED_FLUSH_MASK + 1` nodes, bounding both the atomic traffic and
/// the budget overrun (`threads x chunk` nodes worst case).
const SHARED_FLUSH_MASK: u64 = 0xFF;

/// Frontier expansion targets `threads * FRONTIER_FACTOR` subtree
/// tasks so the task pool stays busy even when subtree sizes are
/// skewed...
const FRONTIER_FACTOR: usize = 4;

/// ...but gives up after this many level-synchronous rounds (a
/// too-deep frontier spends the budget on bookkeeping)...
const FRONTIER_MAX_ROUNDS: usize = 4;

/// ...and never holds more than this many tasks (memory guard against
/// extremely bushy roots — each task clones its open-bin state).
const FRONTIER_MAX_TASKS: usize = 4096;

impl Default for BranchAndBound {
    fn default() -> Self {
        // Generous for paper-scale instances (<=30 items, <=4 types):
        // those need well under 1e5 nodes.
        BranchAndBound { node_budget: 5_000_000, deadline: None, per_item: false, threads: 1 }
    }
}

/// Read-only bound context shared by every worker of one solve: the
/// per-dimension capacity-per-dollar vector, the relaxed one-copy
/// demand per search position, and its suffix sums — everything the
/// per-node lower bound needs that depends on the problem alone,
/// hoisted out of the per-node path (and out of per-worker setup) so
/// it is computed exactly once per solve.
pub(crate) struct BoundCtx {
    /// Per dimension: max over bin types of capacity/cost — the best
    /// capacity purchasable per dollar.
    dim_efficiency: Vec<f64>,
    /// Relaxed one-copy demand (min over choices per dimension) per
    /// search position: per *item* in per-item mode, per *class* in
    /// class mode.
    min_req: Vec<ResourceVec>,
    /// `suffix_demand[k]` = total relaxed demand of positions `k..`
    /// (count-weighted in class mode).
    suffix_demand: Vec<ResourceVec>,
}

impl BoundCtx {
    /// Bound context for the per-item search over `order`.
    fn for_items(problem: &MvbpProblem, order: &[usize]) -> BoundCtx {
        let dim_efficiency = dim_efficiencies(problem);
        let min_req: Vec<ResourceVec> = (0..problem.items.len())
            .map(|i| relaxed_req(problem, i))
            .collect();
        let mut suffix_demand = vec![ResourceVec::zeros(problem.dims); order.len() + 1];
        for k in (0..order.len()).rev() {
            suffix_demand[k] = suffix_demand[k + 1].add(&min_req[order[k]]);
        }
        BoundCtx { dim_efficiency, min_req, suffix_demand }
    }

    /// Bound context for the class search over `classes` (already in
    /// search order); suffix demands are count-weighted.
    fn for_classes(problem: &MvbpProblem, classes: &[ItemClass]) -> BoundCtx {
        let dim_efficiency = dim_efficiencies(problem);
        let min_req: Vec<ResourceVec> = classes
            .iter()
            .map(|class| relaxed_req(problem, class.rep))
            .collect();
        let mut suffix_demand = vec![ResourceVec::zeros(problem.dims); classes.len() + 1];
        for k in (0..classes.len()).rev() {
            let mut acc = suffix_demand[k + 1].clone();
            let count = classes[k].count() as f64;
            for d in 0..problem.dims {
                acc.0[d] += min_req[k][d] * count;
            }
            suffix_demand[k] = acc;
        }
        BoundCtx { dim_efficiency, min_req, suffix_demand }
    }
}

/// State shared by the workers of one multi-root parallel solve.
struct SharedSearch {
    /// Bits of the best cost (as `f64`) any worker has recorded.
    /// Solution costs are non-negative (`MvbpProblem::validate`
    /// rejects negative capacities, requirements, and costs), and
    /// non-negative IEEE doubles order like their bit patterns, so
    /// `fetch_min` on the bits is a lock-free monotone minimum.
    best_bits: AtomicU64,
    /// Global node counter (chunk-flushed; see [`SHARED_FLUSH_MASK`]).
    nodes: AtomicU64,
    /// Raised when the budget or deadline is hit anywhere: every
    /// worker unwinds at its next flush point.
    stop: AtomicBool,
}

impl SharedSearch {
    fn new(seed_cost: Dollars, expansion_nodes: u64) -> SharedSearch {
        SharedSearch {
            best_bits: AtomicU64::new(seed_cost.as_f64().to_bits()),
            nodes: AtomicU64::new(expansion_nodes),
            stop: AtomicBool::new(false),
        }
    }

    fn best(&self) -> f64 {
        f64::from_bits(self.best_bits.load(Ordering::Relaxed))
    }

    fn relax(&self, cost: Dollars) {
        self.best_bits.fetch_min(cost.as_f64().to_bits(), Ordering::Relaxed);
    }
}

/// Node accounting for one search context: counter, budget, deadline,
/// and — in a parallel worker — the handle to the shared counters
/// (budget and deadline then bind globally).
struct Accounting<'s> {
    nodes: u64,
    node_budget: u64,
    deadline: Option<Instant>,
    shared: Option<&'s SharedSearch>,
    exhausted: bool,
}

impl<'s> Accounting<'s> {
    fn new(
        node_budget: u64,
        deadline: Option<Instant>,
        shared: Option<&'s SharedSearch>,
    ) -> Accounting<'s> {
        Accounting { nodes: 0, node_budget, deadline, shared, exhausted: false }
    }

    /// Count one node; `true` aborts the search (budget or deadline
    /// hit — or, in a worker, another worker tripped the global stop).
    #[inline]
    fn step(&mut self) -> bool {
        self.nodes += 1;
        match self.shared {
            None => {
                if self.nodes > self.node_budget {
                    self.exhausted = true;
                    return true;
                }
                if self.nodes & DEADLINE_CHECK_MASK == 0 {
                    if let Some(deadline) = self.deadline {
                        if Instant::now() >= deadline {
                            self.exhausted = true;
                            return true;
                        }
                    }
                }
            }
            Some(shared) => {
                if self.nodes & SHARED_FLUSH_MASK == 0 {
                    let chunk = SHARED_FLUSH_MASK + 1;
                    let global = shared.nodes.fetch_add(chunk, Ordering::Relaxed) + chunk;
                    if global > self.node_budget {
                        shared.stop.store(true, Ordering::Relaxed);
                    }
                    if shared.stop.load(Ordering::Relaxed) {
                        self.exhausted = true;
                        return true;
                    }
                }
                if self.nodes & DEADLINE_CHECK_MASK == 0 {
                    if let Some(deadline) = self.deadline {
                        if Instant::now() >= deadline {
                            shared.stop.store(true, Ordering::Relaxed);
                            self.exhausted = true;
                            return true;
                        }
                    }
                }
            }
        }
        false
    }

    /// Push the nodes not yet flushed to the shared counter (flushes
    /// happen exactly at chunk multiples, so the remainder is
    /// `nodes % chunk`).  No-op for sequential accounting.
    fn flush_remainder(&self) {
        if let Some(shared) = self.shared {
            shared.nodes.fetch_add(self.nodes & SHARED_FLUSH_MASK, Ordering::Relaxed);
        }
    }
}

/// The bound value at or above which a node is pruned: the local
/// incumbent less epsilon — and, under a shared incumbent, the
/// globally best cost *plus* epsilon.  The shared term sheds only
/// strictly costlier subtrees, so a subtree that could still tie the
/// optimum always survives; that asymmetry is what keeps the parallel
/// winner bit-identical to the sequential search (see module docs).
#[inline]
fn prune_limit(best_cost: Dollars, shared: Option<&SharedSearch>) -> f64 {
    let local = best_cost.as_f64() - 1e-9;
    match shared {
        Some(s) => local.min(s.best() + 1e-9),
        None => local,
    }
}

#[derive(Clone)]
struct OpenBin {
    bin_type: usize,
    residual: ResourceVec,
    assignments: Vec<(usize, usize)>,
}

/// An unexplored per-item subtree: the DFS state at its root.
#[derive(Clone)]
struct ItemTask {
    k: usize,
    cost: Dollars,
    open: Vec<OpenBin>,
}

/// One frontier entry of the per-item parallel search, in DFS order.
enum ItemEntry {
    Task(ItemTask),
    Leaf { cost: Dollars, solution: Solution },
}

struct SearchCtx<'p, 's> {
    problem: &'p MvbpProblem,
    /// Item indices in search order (hardest first).
    order: &'s [usize],
    bounds: &'s BoundCtx,
    best_cost: Dollars,
    best: Option<Solution>,
    acct: Accounting<'s>,
    /// Frontier expansion: spill (collect, don't expand) subtrees
    /// rooted at this depth into `spill` instead of recursing.
    /// `usize::MAX` = off (normal search).
    spill_depth: usize,
    spill: Vec<ItemEntry>,
}

/// Per-dimension "best capacity per dollar" vector shared by both
/// search modes' lower bounds.
fn dim_efficiencies(problem: &MvbpProblem) -> Vec<f64> {
    (0..problem.dims)
        .map(|d| {
            problem
                .bin_types
                .iter()
                .map(|bt| {
                    let cost = bt.cost.as_f64();
                    if cost > 0.0 {
                        bt.capacity[d] / cost
                    } else {
                        f64::INFINITY
                    }
                })
                .fold(0.0, f64::max)
        })
        .collect()
}

/// Element-wise max capacity over bin types (the "roomiest bin" the
/// hardness measure normalizes against).
fn roomiest_capacity(problem: &MvbpProblem) -> ResourceVec {
    ResourceVec(
        (0..problem.dims)
            .map(|d| {
                problem
                    .bin_types
                    .iter()
                    .map(|bt| bt.capacity[d])
                    .fold(0.0, f64::max)
            })
            .collect(),
    )
}

/// Relaxed one-copy demand of an item: the min over choices per
/// dimension (whatever choice the optimum picks needs at least this).
fn relaxed_req(problem: &MvbpProblem, item: usize) -> ResourceVec {
    ResourceVec(
        (0..problem.dims)
            .map(|d| {
                problem.items[item]
                    .choices
                    .iter()
                    .map(|c| c[d])
                    .fold(f64::INFINITY, f64::min)
            })
            .collect(),
    )
}

/// Item indices in search order: hardest first, by decreasing
/// "best-case fullness" — min over choices of the max capacity ratio vs
/// the roomiest bin.  Factored out of the solve so a remote worker
/// ([`run_remote_exact`]) re-derives the *bit-identical* ordering from
/// the shipped problem: subtree tasks reference positions in this
/// order, so coordinator and worker must agree on it exactly.
fn item_search_order(problem: &MvbpProblem) -> Vec<usize> {
    let roomiest = roomiest_capacity(problem);
    let mut order: Vec<usize> = (0..problem.items.len()).collect();
    let hardness = |i: usize| -> f64 {
        problem.items[i]
            .choices
            .iter()
            .map(|c| c.max_ratio(&roomiest))
            .fold(f64::INFINITY, f64::min)
    };
    // total_cmp for the same reason as `Decreasing::order`: never
    // panic mid-sort, even on inputs validate would reject.
    order.sort_by(|&a, &b| hardness(b).total_cmp(&hardness(a)));
    order
}

/// Classes in search order: hardest representative first — the
/// class-level analogue of [`item_search_order`] (ties keep
/// first-occurrence order: `sort_by` is stable).  Factored out for the
/// same reason: remote workers must re-derive the identical order.
fn sort_classes(problem: &MvbpProblem, classes: &mut [ItemClass]) {
    let roomiest = roomiest_capacity(problem);
    let hardness = |rep: usize| -> f64 {
        problem.items[rep]
            .choices
            .iter()
            .map(|c| c.max_ratio(&roomiest))
            .fold(f64::INFINITY, f64::min)
    };
    classes.sort_by(|a, b| hardness(b.rep).total_cmp(&hardness(a.rep)));
}

impl BranchAndBound {
    /// Solve to proven optimality (within the node budget), seeding the
    /// search with a fresh best-fit-decreasing incumbent.
    ///
    /// Returns `None` iff some item fits in no bin under any choice.
    pub fn solve(&self, problem: &MvbpProblem) -> Option<ExactResult> {
        self.solve_seeded(problem, solve_best_fit(problem))
    }

    /// Like [`BranchAndBound::solve`] but seeded with a caller-supplied
    /// incumbent (e.g. the portfolio's racing winner), skipping the
    /// internal BFD pass.  An invalid or absent incumbent degrades to
    /// an unseeded search; a *dropped* (invalid) incumbent is counted
    /// and surfaced via [`ExactResult::seed_dropped`].
    pub fn solve_seeded(
        &self,
        problem: &MvbpProblem,
        incumbent: Option<Solution>,
    ) -> Option<ExactResult> {
        problem.validate().ok()?;
        if !problem.infeasible_items().is_empty() {
            return None;
        }
        if problem.items.is_empty() {
            return Some(ExactResult {
                solution: Solution::default(),
                proven_optimal: true,
                nodes_explored: 0,
                seed_dropped: false,
            });
        }

        // Incumbent (may not exist for pathological instances); an
        // invalid seed is discarded rather than poisoning the bound —
        // and the drop is surfaced, so a broken seed path upstream
        // cannot silently masquerade as a cold solve.
        let had_seed = incumbent.is_some();
        let incumbent = incumbent.filter(|s| s.validate(problem).is_ok());
        let seed_dropped = had_seed && incumbent.is_none();
        if seed_dropped {
            profiling::bump("exact:seed-dropped");
        }

        // Class-multiplicity branching engages exactly when aggregation
        // pays (the capped grouping aborts past items/2 classes, the
        // same "at least two items per class on average" gate the
        // greedy layer uses).
        let classes = (!self.per_item)
            .then(|| aggregate::group_classes_capped(problem, problem.items.len() / 2))
            .flatten();
        let result = match classes {
            Some(classes) => self.solve_class_search(problem, classes, incumbent),
            None => self.solve_item_search(problem, incumbent),
        };
        result.map(|mut r| {
            r.seed_dropped = seed_dropped;
            r
        })
    }

    /// Effective worker count: `0` means one per available core; any
    /// value is clamped to 16 (the portfolio pool's cap).
    fn effective_threads(&self) -> usize {
        match self.threads {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .clamp(1, 16),
            n => n.min(16),
        }
    }

    /// The per-item search (sequential or multi-root parallel).
    fn solve_item_search(
        &self,
        problem: &MvbpProblem,
        incumbent: Option<Solution>,
    ) -> Option<ExactResult> {
        let order = item_search_order(problem);

        let bounds = BoundCtx::for_items(problem, &order);
        let best_cost = incumbent
            .as_ref()
            .map(|s| s.cost(problem))
            .unwrap_or(Dollars(i64::MAX));

        // A registered worker fleet routes through the multi-root path
        // even at one local thread — the frontier tasks are the unit of
        // distribution.
        let threads = self.effective_threads();
        let fleet = crate::net::fleet::active();
        if threads > 1 || fleet.is_some() {
            return self.solve_item_parallel(
                problem, &order, &bounds, incumbent, best_cost, threads, fleet,
            );
        }

        let mut ctx = SearchCtx {
            problem,
            order: &order,
            bounds: &bounds,
            best_cost,
            best: incumbent,
            acct: Accounting::new(self.node_budget, self.deadline, None),
            spill_depth: usize::MAX,
            spill: Vec::new(),
        };
        let mut open: Vec<OpenBin> = Vec::new();
        dfs(&mut ctx, 0, Dollars::ZERO, &mut open);

        ctx.best.map(|solution| ExactResult {
            solution,
            proven_optimal: !ctx.acct.exhausted,
            nodes_explored: ctx.acct.nodes,
            seed_dropped: false,
        })
    }

    /// Multi-root parallel per-item search: expand the root frontier
    /// sequentially, then race the subtree tasks on the portfolio's
    /// worker pool under a shared incumbent (see module docs).
    #[allow(clippy::too_many_arguments)]
    fn solve_item_parallel(
        &self,
        problem: &MvbpProblem,
        order: &[usize],
        bounds: &BoundCtx,
        incumbent: Option<Solution>,
        seed_cost: Dollars,
        threads: usize,
        fleet: Option<Arc<Fleet>>,
    ) -> Option<ExactResult> {
        // Phase 1: level-synchronous frontier expansion.  Prunes only
        // against the immutable seed cost — tightening here would prune
        // by cross-subtree arrival order and break plan identity.
        let mut ctx = SearchCtx {
            problem,
            order,
            bounds,
            best_cost: seed_cost,
            best: None,
            acct: Accounting::new(self.node_budget, self.deadline, None),
            spill_depth: 0,
            spill: Vec::new(),
        };
        let mut entries: Vec<ItemEntry> =
            vec![ItemEntry::Task(ItemTask { k: 0, cost: Dollars::ZERO, open: Vec::new() })];
        // Each fleet worker digests chunks of tasks, so it widens the
        // frontier target like several local threads would.  Frontier
        // *shape* is already non-contractual (it varies with `threads`
        // too); the winner fold is what keeps proofs bit-identical.
        let fan_out = threads + fleet.as_ref().map_or(0, |f| f.live_count() * FRONTIER_FACTOR);
        let target = (fan_out * FRONTIER_FACTOR).min(FRONTIER_MAX_TASKS);
        for _ in 0..FRONTIER_MAX_ROUNDS {
            let tasks = entries.iter().filter(|e| matches!(e, ItemEntry::Task(_))).count();
            if tasks == 0 || tasks >= target || ctx.acct.exhausted {
                break;
            }
            let mut next: Vec<ItemEntry> = Vec::with_capacity(entries.len() * 2);
            for entry in entries {
                match entry {
                    ItemEntry::Leaf { .. } => next.push(entry),
                    ItemEntry::Task(task) if ctx.acct.exhausted => {
                        next.push(ItemEntry::Task(task));
                    }
                    ItemEntry::Task(task) => {
                        ctx.spill_depth = task.k + 1;
                        let mut open = task.open;
                        dfs(&mut ctx, task.k, task.cost, &mut open);
                        next.append(&mut ctx.spill);
                    }
                }
            }
            entries = next;
        }
        ctx.spill_depth = usize::MAX;
        let expansion_nodes = ctx.acct.nodes;

        let task_ids: Vec<usize> = entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| matches!(e, ItemEntry::Task(_)).then_some(i))
            .collect();

        // Fully enumerated during expansion (or the budget died there):
        // compose the winner from the leaf candidates alone.
        if task_ids.is_empty() || ctx.acct.exhausted {
            let exhausted = ctx.acct.exhausted;
            let (_, best) = compose_winner(
                entries.into_iter().map(|e| match e {
                    ItemEntry::Leaf { cost, solution } => Some((cost, solution)),
                    ItemEntry::Task(_) => None,
                }),
                seed_cost,
                incumbent,
            );
            return best.map(|solution| ExactResult {
                solution,
                proven_optimal: !exhausted,
                nodes_explored: expansion_nodes,
                seed_dropped: false,
            });
        }

        // Phase 2: subtree workers under the shared incumbent — local
        // threads plus, with a fleet, one dispatcher per live worker
        // shipping task chunks over the wire.
        let shared = SharedSearch::new(seed_cost, expansion_nodes);
        let node_budget = self.node_budget;
        let deadline = self.deadline;
        let entries_ref = &entries;
        let shared_ref = &shared;
        let run_local = |i: usize| {
            let task = match &entries_ref[task_ids[i]] {
                ItemEntry::Task(task) => task,
                ItemEntry::Leaf { .. } => unreachable!("task_ids index only Task entries"),
            };
            let mut wctx = SearchCtx {
                problem,
                order,
                bounds,
                best_cost: seed_cost,
                best: None,
                acct: Accounting::new(node_budget, deadline, Some(shared_ref)),
                spill_depth: usize::MAX,
                spill: Vec::new(),
            };
            let mut open = task.open.clone();
            dfs(&mut wctx, task.k, task.cost, &mut open);
            wctx.acct.flush_remainder();
            wctx.best.map(|solution| (wctx.best_cost, solution))
        };
        let serialize_tasks = || {
            task_ids
                .iter()
                .map(|&id| match &entries_ref[id] {
                    ItemEntry::Task(task) => item_task_to_json(task),
                    ItemEntry::Leaf { .. } => unreachable!("task_ids index only Task entries"),
                })
                .collect()
        };
        let mut results = race_frontier(
            fleet.as_ref(),
            threads,
            task_ids.len(),
            "item",
            seed_cost,
            node_budget,
            deadline,
            problem,
            shared_ref,
            serialize_tasks,
            run_local,
        );

        // Deterministic winner: cheapest cost, then lowest frontier
        // entry index — identical to the sequential first-improver.
        let mut cursor = 0;
        let (_, best) = compose_winner(
            entries.iter().map(|e| match e {
                ItemEntry::Leaf { cost, solution } => Some((*cost, solution.clone())),
                ItemEntry::Task(_) => {
                    let r = results[cursor].take();
                    cursor += 1;
                    r
                }
            }),
            seed_cost,
            incumbent,
        );
        let stopped = shared.stop.load(Ordering::Relaxed);
        best.map(|solution| ExactResult {
            solution,
            proven_optimal: !stopped,
            nodes_explored: shared.nodes.load(Ordering::Relaxed),
            seed_dropped: false,
        })
    }

    /// The class-multiplicity search: branch on "place `k` copies of
    /// the current class into bin `b` under choice `c`" (see the module
    /// docs for the symmetry-breaking rules).
    fn solve_class_search(
        &self,
        problem: &MvbpProblem,
        mut classes: Vec<ItemClass>,
        incumbent: Option<Solution>,
    ) -> Option<ExactResult> {
        sort_classes(problem, &mut classes);

        let bounds = BoundCtx::for_classes(problem, &classes);
        let best_cost = incumbent
            .as_ref()
            .map(|s| s.cost(problem))
            .unwrap_or(Dollars(i64::MAX));

        // A registered worker fleet routes through the multi-root path
        // even at one local thread, exactly like the per-item search.
        let threads = self.effective_threads();
        let fleet = crate::net::fleet::active();
        if threads > 1 || fleet.is_some() {
            return self.solve_class_parallel(
                problem, &classes, &bounds, incumbent, best_cost, threads, fleet,
            );
        }

        let first_count = classes[0].count() as u32;
        let mut ctx = ClassCtx {
            problem,
            classes: &classes,
            bounds: &bounds,
            best_cost,
            best: incumbent,
            acct: Accounting::new(self.node_budget, self.deadline, None),
            spill_depth: usize::MAX,
            spill: Vec::new(),
        };
        let mut bins: Vec<ClassBin> = Vec::new();
        distribute(&mut ctx, 0, first_count, Dollars::ZERO, &mut bins, (0, 0), None, 0);

        ctx.best.map(|solution| ExactResult {
            solution,
            proven_optimal: !ctx.acct.exhausted,
            nodes_explored: ctx.acct.nodes,
            seed_dropped: false,
        })
    }

    /// Multi-root parallel class search — the class-mode twin of
    /// [`BranchAndBound::solve_item_parallel`].
    #[allow(clippy::too_many_arguments)]
    fn solve_class_parallel(
        &self,
        problem: &MvbpProblem,
        classes: &[ItemClass],
        bounds: &BoundCtx,
        incumbent: Option<Solution>,
        seed_cost: Dollars,
        threads: usize,
        fleet: Option<Arc<Fleet>>,
    ) -> Option<ExactResult> {
        // Phase 1: frontier expansion, pruning only against the seed.
        // Each round expands every task exactly one level (class-mode
        // depth is relative to the task root, so the spill depth is a
        // constant 1).
        let mut ctx = ClassCtx {
            problem,
            classes,
            bounds,
            best_cost: seed_cost,
            best: None,
            acct: Accounting::new(self.node_budget, self.deadline, None),
            spill_depth: 1,
            spill: Vec::new(),
        };
        let root = ClassTask {
            ci: 0,
            remaining: classes[0].count() as u32,
            cost: Dollars::ZERO,
            bins: Vec::new(),
            from: (0, 0),
            last_fresh: None,
        };
        let mut entries: Vec<ClassEntry> = vec![ClassEntry::Task(root)];
        // Each fleet worker digests chunks of tasks, so it widens the
        // frontier target like several local threads would.  Frontier
        // *shape* is already non-contractual (it varies with `threads`
        // too); the winner fold is what keeps proofs bit-identical.
        let fan_out = threads + fleet.as_ref().map_or(0, |f| f.live_count() * FRONTIER_FACTOR);
        let target = (fan_out * FRONTIER_FACTOR).min(FRONTIER_MAX_TASKS);
        for _ in 0..FRONTIER_MAX_ROUNDS {
            let tasks = entries.iter().filter(|e| matches!(e, ClassEntry::Task(_))).count();
            if tasks == 0 || tasks >= target || ctx.acct.exhausted {
                break;
            }
            let mut next: Vec<ClassEntry> = Vec::with_capacity(entries.len() * 2);
            for entry in entries {
                match entry {
                    ClassEntry::Leaf { .. } => next.push(entry),
                    ClassEntry::Task(task) if ctx.acct.exhausted => {
                        next.push(ClassEntry::Task(task));
                    }
                    ClassEntry::Task(task) => {
                        let mut bins = task.bins;
                        distribute(
                            &mut ctx,
                            task.ci,
                            task.remaining,
                            task.cost,
                            &mut bins,
                            task.from,
                            task.last_fresh,
                            0,
                        );
                        next.append(&mut ctx.spill);
                    }
                }
            }
            entries = next;
        }
        ctx.spill_depth = usize::MAX;
        let expansion_nodes = ctx.acct.nodes;

        let task_ids: Vec<usize> = entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| matches!(e, ClassEntry::Task(_)).then_some(i))
            .collect();

        if task_ids.is_empty() || ctx.acct.exhausted {
            let exhausted = ctx.acct.exhausted;
            let (_, best) = compose_winner(
                entries.into_iter().map(|e| match e {
                    ClassEntry::Leaf { cost, solution } => Some((cost, solution)),
                    ClassEntry::Task(_) => None,
                }),
                seed_cost,
                incumbent,
            );
            return best.map(|solution| ExactResult {
                solution,
                proven_optimal: !exhausted,
                nodes_explored: expansion_nodes,
                seed_dropped: false,
            });
        }

        // Phase 2: subtree workers under the shared incumbent — local
        // threads plus, with a fleet, one dispatcher per live worker
        // shipping task chunks over the wire.
        let shared = SharedSearch::new(seed_cost, expansion_nodes);
        let node_budget = self.node_budget;
        let deadline = self.deadline;
        let entries_ref = &entries;
        let shared_ref = &shared;
        let run_local = |i: usize| {
            let task = match &entries_ref[task_ids[i]] {
                ClassEntry::Task(task) => task,
                ClassEntry::Leaf { .. } => unreachable!("task_ids index only Task entries"),
            };
            let mut wctx = ClassCtx {
                problem,
                classes,
                bounds,
                best_cost: seed_cost,
                best: None,
                acct: Accounting::new(node_budget, deadline, Some(shared_ref)),
                spill_depth: usize::MAX,
                spill: Vec::new(),
            };
            let mut bins = task.bins.clone();
            distribute(
                &mut wctx,
                task.ci,
                task.remaining,
                task.cost,
                &mut bins,
                task.from,
                task.last_fresh,
                0,
            );
            wctx.acct.flush_remainder();
            wctx.best.map(|solution| (wctx.best_cost, solution))
        };
        let serialize_tasks = || {
            task_ids
                .iter()
                .map(|&id| match &entries_ref[id] {
                    ClassEntry::Task(task) => class_task_to_json(task),
                    ClassEntry::Leaf { .. } => unreachable!("task_ids index only Task entries"),
                })
                .collect()
        };
        let mut results = race_frontier(
            fleet.as_ref(),
            threads,
            task_ids.len(),
            "class",
            seed_cost,
            node_budget,
            deadline,
            problem,
            shared_ref,
            serialize_tasks,
            run_local,
        );

        let mut cursor = 0;
        let (_, best) = compose_winner(
            entries.iter().map(|e| match e {
                ClassEntry::Leaf { cost, solution } => Some((*cost, solution.clone())),
                ClassEntry::Task(_) => {
                    let r = results[cursor].take();
                    cursor += 1;
                    r
                }
            }),
            seed_cost,
            incumbent,
        );
        let stopped = shared.stop.load(Ordering::Relaxed);
        best.map(|solution| ExactResult {
            solution,
            proven_optimal: !stopped,
            nodes_explored: shared.nodes.load(Ordering::Relaxed),
            seed_dropped: false,
        })
    }
}

/// Fold root-frontier candidates (in entry order) into the final
/// winner: strictly-cheaper-than-seed candidates only, first entry
/// winning cost ties — the same "first leaf attaining the optimum in
/// DFS order" the sequential search returns.
fn compose_winner(
    candidates: impl Iterator<Item = Option<(Dollars, Solution)>>,
    seed_cost: Dollars,
    incumbent: Option<Solution>,
) -> (Dollars, Option<Solution>) {
    let mut best_cost = seed_cost;
    let mut best = incumbent;
    for (cost, solution) in candidates.flatten() {
        if cost < best_cost {
            best_cost = cost;
            best = Some(solution);
        }
    }
    (best_cost, best)
}

/// Phase-2 task racing with optional fleet distribution.  Without a
/// fleet (or with no worker currently in rotation) this is *exactly*
/// the pre-existing local pool — `race_tasks` with no shedding.  With
/// a fleet, `race_chunks_remote` adds one dispatcher thread per ready
/// worker: each claimed chunk is shipped as one `exact` request
/// carrying the problem, the serialized subtree tasks, and the global
/// incumbent at request-build time (improvement broadcast at chunk
/// granularity — the shared incumbent only ever sheds strictly
/// costlier subtrees, so a staler value merely prunes less).  A worker
/// failure re-runs the chunk through `run_local`, a malformed reply
/// quarantines the worker, a straggling claim is hedged locally, and
/// the winner fold upstream is order-strict — so outcomes are
/// bit-identical for any worker count, deaths, restarts, and hedge
/// timing included.
#[allow(clippy::too_many_arguments)]
fn race_frontier(
    fleet: Option<&Arc<Fleet>>,
    threads: usize,
    count: usize,
    mode: &str,
    seed_cost: Dollars,
    node_budget: u64,
    deadline: Option<Instant>,
    problem: &MvbpProblem,
    shared: &SharedSearch,
    serialize_tasks: impl FnOnce() -> Vec<Json>,
    run_local: impl Fn(usize) -> Option<(Dollars, Solution)> + Sync,
) -> Vec<Option<(Dollars, Solution)>> {
    // `ready_workers` is the probe point: `Open` workers whose
    // cooldown elapsed get their half-open ping here, so a restarted
    // worker rejoins before this fan-out rather than after the run.
    let live = fleet.map(|f| f.ready_workers()).unwrap_or_default();
    if live.is_empty() {
        return race_tasks(
            threads,
            count,
            None, // no shedding: every subtree must run for the proof
            |_| 0,
            run_local,
        );
    }
    let fleet = fleet.expect("live workers imply a fleet");
    let (problem_json, tasks): (Json, Vec<Json>) =
        profiling::time_phase("net:serialize", || (problem_to_json(problem), serialize_tasks()));
    // Chunks of ~count/(4 x workers): big enough to amortize a round
    // trip, small enough to rebalance when subtree sizes skew.
    let chunk = count.div_ceil(live.len() * FRONTIER_FACTOR).max(1);
    let tuning = fleet.tuning();
    let on_hedge = || fleet.note_hedged();
    let hedge = tuning.hedge.then(|| HedgeCfg {
        after: std::time::Duration::from_millis(tuning.hedge_after_ms),
        factor: tuning.hedge_factor,
        on_hedge: &on_hedge,
    });
    race_chunks_remote(
        live.len(),
        threads,
        count,
        chunk,
        hedge,
        |w, range, cancelled| {
            // Once the shared budget is exhausted a worker can only add
            // redundant exploration (each request carries the full
            // budget so completed proofs stay worker-count-invariant).
            // Failing the claim downshifts this dispatcher to local
            // claims — near-free once `stop` is set — without touching
            // the worker's breaker.
            if shared.stop.load(Ordering::Relaxed) {
                return RemoteOutcome::Failed;
            }
            let request = Json::obj(vec![
                ("type".to_string(), Json::Str("exact".to_string())),
                ("mode".to_string(), Json::Str(mode.to_string())),
                ("seed_cost".to_string(), dollars_to_json(seed_cost)),
                ("incumbent".to_string(), Json::Num(shared.best())),
                // Budgets beyond 2^53 nodes are unreachable wall-clock
                // fiction; clamping keeps the JSON number exact.
                (
                    "node_budget".to_string(),
                    Json::Num(node_budget.min(1 << 53) as f64),
                ),
                (
                    "time_left_ms".to_string(),
                    match deadline {
                        Some(d) => Json::Num(
                            d.saturating_duration_since(Instant::now()).as_millis() as f64,
                        ),
                        None => Json::Null,
                    },
                ),
                ("problem".to_string(), problem_json.clone()),
                ("tasks".to_string(), Json::arr(tasks[range.clone()].iter().cloned())),
            ]);
            let reply = match fleet.rpc_cancellable(live[w], request, RpcClass::Exact, &cancelled)
            {
                RpcOutcome::Reply(reply) => reply,
                RpcOutcome::Abandoned => return RemoteOutcome::Abandoned,
                RpcOutcome::Lost => return RemoteOutcome::Failed,
            };
            match profiling::time_phase("net:merge", || {
                merge_exact_reply(&reply, problem, shared, range.len())
            }) {
                Ok(results) => RemoteOutcome::Done(results),
                Err(e) => {
                    fleet.report_violation(live[w], &format!("bad exact reply: {e:#}"));
                    RemoteOutcome::Failed
                }
            }
        },
        run_local,
    )
}

/// Decode and validate a worker's `exact_result` reply.  Shared state
/// (incumbent, node count, stop flag) is touched only after the whole
/// reply validates: a malformed reply must leave no trace, because its
/// chunk is re-run locally as if the worker never existed.
fn merge_exact_reply(
    reply: &Json,
    problem: &MvbpProblem,
    shared: &SharedSearch,
    expected: usize,
) -> Result<Vec<Option<(Dollars, Solution)>>> {
    let kind = reply.str_field("type")?;
    ensure!(kind == "exact_result", "expected exact_result, got {kind:?}");
    let nodes = reply.u64_field("nodes")?;
    let exhausted = reply
        .field("exhausted")?
        .as_bool()
        .ok_or_else(|| anyhow!("exhausted is not a bool"))?;
    let candidates = reply.arr_field("candidates")?;
    ensure!(
        candidates.len() == expected,
        "worker answered {} candidates for {expected} tasks",
        candidates.len()
    );
    let mut out = Vec::with_capacity(expected);
    for c in candidates {
        match c {
            Json::Null => out.push(None),
            s => {
                let solution = solution_from_json(s)?;
                solution
                    .validate(problem)
                    .map_err(|e| anyhow!("worker solution invalid: {e:#}"))?;
                // Recompute the cost locally: both sides sum the same
                // whole micro-dollar bin + choice costs, so this equals
                // the worker's running cost exactly — and a corrupt
                // reply cannot smuggle in a mispriced candidate.
                let cost = solution.cost(problem);
                out.push(Some((cost, solution)));
            }
        }
    }
    for (cost, _) in out.iter().flatten() {
        shared.relax(*cost);
    }
    shared.nodes.fetch_add(nodes, Ordering::Relaxed);
    if exhausted {
        shared.stop.store(true, Ordering::Relaxed);
    }
    Ok(out)
}

fn open_bin_to_json(bin: &OpenBin) -> Json {
    Json::obj(vec![
        ("t".to_string(), Json::Num(bin.bin_type as f64)),
        ("r".to_string(), resources_to_json(&bin.residual)),
        (
            "a".to_string(),
            Json::arr(bin.assignments.iter().map(|&(item, choice)| {
                Json::arr(vec![Json::Num(item as f64), Json::Num(choice as f64)])
            })),
        ),
    ])
}

/// Serialize a per-item subtree task.  The DFS state ships verbatim —
/// residual capacities are `f64`s, which `util::json` round-trips
/// bit-exactly, so the worker resumes the identical search state.
fn item_task_to_json(task: &ItemTask) -> Json {
    Json::obj(vec![
        ("k".to_string(), Json::Num(task.k as f64)),
        ("cost".to_string(), dollars_to_json(task.cost)),
        ("open".to_string(), Json::arr(task.open.iter().map(open_bin_to_json))),
    ])
}

/// Decode a per-item subtree task, bounds-checking every index: the
/// search assumes well-formed state, and a worker must answer a
/// corrupt task with an error, never a panic (one worker process
/// serves many requests).
fn item_task_from_json(j: &Json, problem: &MvbpProblem, n_positions: usize) -> Result<ItemTask> {
    let k = j.u64_field("k")? as usize;
    ensure!(k <= n_positions, "task depth {k} past the {n_positions} search positions");
    let cost = dollars_from_json(j.field("cost")?)?;
    let mut open = Vec::new();
    for bin in j.arr_field("open")? {
        let bin_type = bin.u64_field("t")? as usize;
        ensure!(bin_type < problem.bin_types.len(), "open-bin type {bin_type} out of range");
        let residual = resources_from_json(bin.field("r")?, problem.dims)?;
        let mut assignments = Vec::new();
        for pair in bin.arr_field("a")? {
            let pair = pair.as_arr().ok_or_else(|| anyhow!("assignment is not a pair"))?;
            ensure!(pair.len() == 2, "assignment pair has {} entries", pair.len());
            let item = pair[0].as_u64().ok_or_else(|| anyhow!("assignment item index"))? as usize;
            let choice =
                pair[1].as_u64().ok_or_else(|| anyhow!("assignment choice index"))? as usize;
            ensure!(item < problem.items.len(), "assigned item {item} out of range");
            ensure!(
                choice < problem.items[item].choices.len(),
                "choice {choice} out of range for item {item}"
            );
            assignments.push((item, choice));
        }
        open.push(OpenBin { bin_type, residual, assignments });
    }
    Ok(ItemTask { k, cost, open })
}

/// Serialize a class-mode subtree task (the `distribute` state at its
/// root: class cursor, unplaced copies, bins with `(class, choice,
/// copies)` runs, placement cursor, fresh-open key).
fn class_task_to_json(task: &ClassTask) -> Json {
    Json::obj(vec![
        ("ci".to_string(), Json::Num(task.ci as f64)),
        ("rem".to_string(), Json::Num(task.remaining as f64)),
        ("cost".to_string(), dollars_to_json(task.cost)),
        (
            "bins".to_string(),
            Json::arr(task.bins.iter().map(|bin| {
                Json::obj(vec![
                    ("t".to_string(), Json::Num(bin.bin_type as f64)),
                    ("r".to_string(), resources_to_json(&bin.residual)),
                    (
                        "e".to_string(),
                        Json::arr(bin.entries.iter().map(|&(ci, c, copies)| {
                            Json::arr(vec![
                                Json::Num(ci as f64),
                                Json::Num(c as f64),
                                Json::Num(copies as f64),
                            ])
                        })),
                    ),
                ])
            })),
        ),
        (
            "from".to_string(),
            Json::arr(vec![Json::Num(task.from.0 as f64), Json::Num(task.from.1 as f64)]),
        ),
        (
            "lf".to_string(),
            match task.last_fresh {
                None => Json::Null,
                Some((t, c, copies)) => Json::arr(vec![
                    Json::Num(t as f64),
                    Json::Num(c as f64),
                    Json::Num(copies as f64),
                ]),
            },
        ),
    ])
}

/// Decode a class-mode subtree task.  Beyond per-index bounds checks,
/// this enforces the placement invariant `record_class_leaf` indexes
/// class members by: classes before `ci` fully placed, `ci` missing
/// exactly `remaining` copies, later classes untouched — so a corrupt
/// task cannot drive the member-slicing past a class's member list.
fn class_task_from_json(
    j: &Json,
    problem: &MvbpProblem,
    classes: &[ItemClass],
) -> Result<ClassTask> {
    let ci = j.u64_field("ci")? as usize;
    ensure!(ci < classes.len(), "task class {ci} out of range");
    let remaining = u32::try_from(j.u64_field("rem")?)
        .map_err(|_| anyhow!("remaining copy count overflows"))?;
    let cost = dollars_from_json(j.field("cost")?)?;
    let mut placed = vec![0usize; classes.len()];
    let mut bins = Vec::new();
    for bin in j.arr_field("bins")? {
        let bin_type = bin.u64_field("t")? as usize;
        ensure!(bin_type < problem.bin_types.len(), "class-bin type {bin_type} out of range");
        let residual = resources_from_json(bin.field("r")?, problem.dims)?;
        let mut entries = Vec::new();
        for row in bin.arr_field("e")? {
            let row = row.as_arr().ok_or_else(|| anyhow!("bin entry is not a triple"))?;
            ensure!(row.len() == 3, "bin entry has {} fields", row.len());
            let eci = row[0].as_u64().ok_or_else(|| anyhow!("entry class index"))? as usize;
            let choice = row[1].as_u64().ok_or_else(|| anyhow!("entry choice index"))? as usize;
            let copies = u32::try_from(
                row[2].as_u64().ok_or_else(|| anyhow!("entry copy count"))?,
            )
            .map_err(|_| anyhow!("entry copy count overflows"))?;
            ensure!(eci < classes.len(), "entry class {eci} out of range");
            ensure!(
                choice < problem.items[classes[eci].rep].choices.len(),
                "entry choice {choice} out of range for class {eci}"
            );
            placed[eci] += copies as usize;
            entries.push((eci, choice, copies));
        }
        bins.push(ClassBin { bin_type, residual, entries });
    }
    for (c, class) in classes.iter().enumerate() {
        let expect = match c.cmp(&ci) {
            std::cmp::Ordering::Less => class.count(),
            std::cmp::Ordering::Equal => class
                .count()
                .checked_sub(remaining as usize)
                .ok_or_else(|| anyhow!("remaining exceeds class {c}'s size"))?,
            std::cmp::Ordering::Greater => 0,
        };
        ensure!(
            placed[c] == expect,
            "class {c} has {} copies placed, expected {expect}",
            placed[c]
        );
    }
    let from_arr = j.arr_field("from")?;
    ensure!(from_arr.len() == 2, "placement cursor has {} fields", from_arr.len());
    let from = (
        from_arr[0].as_u64().ok_or_else(|| anyhow!("cursor bin index"))? as usize,
        from_arr[1].as_u64().ok_or_else(|| anyhow!("cursor choice index"))? as usize,
    );
    ensure!(from.0 <= bins.len(), "cursor bin {} past the {} bins", from.0, bins.len());
    let last_fresh = match j.field("lf")? {
        Json::Null => None,
        arr => {
            let row = arr.as_arr().ok_or_else(|| anyhow!("fresh-open key is not a triple"))?;
            ensure!(row.len() == 3, "fresh-open key has {} fields", row.len());
            Some((
                row[0].as_u64().ok_or_else(|| anyhow!("fresh-open type"))? as usize,
                row[1].as_u64().ok_or_else(|| anyhow!("fresh-open choice"))? as usize,
                u32::try_from(row[2].as_u64().ok_or_else(|| anyhow!("fresh-open count"))?)
                    .map_err(|_| anyhow!("fresh-open count overflows"))?,
            ))
        }
    };
    Ok(ClassTask { ci, remaining, cost, bins, from, last_fresh })
}

/// Worker-side execution of one `exact` request: decode the problem,
/// re-derive the search order (bit-identical to the coordinator's —
/// [`item_search_order`] / [`sort_classes`] are shared code paths),
/// validate and run each shipped subtree task sequentially under the
/// request's seed + incumbent, and answer with one candidate per task.
///
/// Malformed requests return `Err` — the serve loop answers with an
/// `error` message and survives; a worker must never panic on a bad
/// payload.
pub(crate) fn run_remote_exact(request: &Json) -> Result<Json> {
    let problem = problem_from_json(request.field("problem")?)?;
    let seed_cost = dollars_from_json(request.field("seed_cost")?)?;
    let incumbent = request.f64_field("incumbent")?;
    let node_budget = request.u64_field("node_budget")?;
    let deadline = match request.field("time_left_ms")? {
        Json::Null => None,
        ms => {
            let ms = ms.as_u64().ok_or_else(|| anyhow!("time_left_ms is not a count"))?;
            Some(Instant::now() + Duration::from_millis(ms))
        }
    };
    // Worker-local shared state: the request's incumbent seeds the
    // prune bound, node budget and stop flag bind across this
    // request's tasks (the budget is global only approximately — the
    // same non-contract as local `nodes_explored` at threads > 1).
    let shared = SharedSearch::new(seed_cost, 0);
    shared.best_bits.fetch_min(incumbent.to_bits(), Ordering::Relaxed);

    let tasks = request.arr_field("tasks")?;
    let mut candidates = Vec::with_capacity(tasks.len());
    match request.str_field("mode")? {
        "item" => {
            let order = item_search_order(&problem);
            let bounds = BoundCtx::for_items(&problem, &order);
            for t in tasks {
                let task = item_task_from_json(t, &problem, order.len())?;
                let mut wctx = SearchCtx {
                    problem: &problem,
                    order: &order,
                    bounds: &bounds,
                    best_cost: seed_cost,
                    best: None,
                    acct: Accounting::new(node_budget, deadline, Some(&shared)),
                    spill_depth: usize::MAX,
                    spill: Vec::new(),
                };
                let mut open = task.open;
                dfs(&mut wctx, task.k, task.cost, &mut open);
                wctx.acct.flush_remainder();
                candidates
                    .push(wctx.best.map(|s| solution_to_json(&s)).unwrap_or(Json::Null));
            }
        }
        "class" => {
            let mut classes =
                aggregate::group_classes_capped(&problem, problem.items.len() / 2).ok_or_else(
                    || anyhow!("class-mode request on a problem where aggregation does not engage"),
                )?;
            sort_classes(&problem, &mut classes);
            let bounds = BoundCtx::for_classes(&problem, &classes);
            for t in tasks {
                let task = class_task_from_json(t, &problem, &classes)?;
                let mut wctx = ClassCtx {
                    problem: &problem,
                    classes: &classes,
                    bounds: &bounds,
                    best_cost: seed_cost,
                    best: None,
                    acct: Accounting::new(node_budget, deadline, Some(&shared)),
                    spill_depth: usize::MAX,
                    spill: Vec::new(),
                };
                let mut bins = task.bins;
                distribute(
                    &mut wctx,
                    task.ci,
                    task.remaining,
                    task.cost,
                    &mut bins,
                    task.from,
                    task.last_fresh,
                    0,
                );
                wctx.acct.flush_remainder();
                candidates
                    .push(wctx.best.map(|s| solution_to_json(&s)).unwrap_or(Json::Null));
            }
        }
        other => return Err(anyhow!("unknown exact mode {other:?}")),
    }
    Ok(Json::obj(vec![
        ("type".to_string(), Json::Str("exact_result".to_string())),
        ("nodes".to_string(), Json::Num(shared.nodes.load(Ordering::Relaxed) as f64)),
        ("exhausted".to_string(), Json::Bool(shared.stop.load(Ordering::Relaxed))),
        ("candidates".to_string(), Json::arr(candidates)),
    ]))
}

/// Cost lower bound for the remaining items `order[k..]` given open-bin
/// residual capacity: extra demand beyond residuals, priced at the best
/// capacity-per-dollar in each dimension; the max over dimensions is a
/// valid bound because every dollar buys capacity in all dims at once.
fn lower_bound(ctx: &SearchCtx, k: usize, open: &[OpenBin]) -> f64 {
    let demand = &ctx.bounds.suffix_demand[k];
    let mut bound: f64 = 0.0;
    for d in 0..ctx.problem.dims {
        if demand[d] <= 0.0 {
            continue;
        }
        let residual: f64 = open.iter().map(|b| b.residual[d].max(0.0)).sum();
        let extra = demand[d] - residual;
        if extra > 0.0 && ctx.bounds.dim_efficiency[d] > 0.0 {
            bound = bound.max(extra / ctx.bounds.dim_efficiency[d]);
        }
    }
    bound
}

/// The child's entry prune (`cost + lower_bound >= limit`), evaluated
/// in the parent on the already-mutated state: dominated children are
/// skipped without being expanded, so they cost one bound evaluation
/// instead of a call frame and a unit of node budget.
fn prune_child(ctx: &SearchCtx, k: usize, cost: Dollars, open: &[OpenBin]) -> bool {
    cost.as_f64() + lower_bound(ctx, k, open) >= prune_limit(ctx.best_cost, ctx.acct.shared)
}

/// Record a complete per-item packing: in normal search, tighten the
/// (local) incumbent and publish to the shared one; during frontier
/// expansion, collect it as an indexed leaf candidate instead (the
/// incumbent must stay pinned at the seed there — see module docs).
fn record_item_leaf(ctx: &mut SearchCtx, cost: Dollars, open: &[OpenBin]) {
    if cost >= ctx.best_cost {
        return;
    }
    let solution = Solution {
        bins: open
            .iter()
            .map(|b| PackedBin {
                bin_type: b.bin_type,
                assignments: b.assignments.clone(),
            })
            .collect(),
    };
    if ctx.spill_depth != usize::MAX {
        ctx.spill.push(ItemEntry::Leaf { cost, solution });
        return;
    }
    ctx.best_cost = cost;
    if let Some(shared) = ctx.acct.shared {
        shared.relax(cost);
    }
    ctx.best = Some(solution);
}

fn dfs(ctx: &mut SearchCtx, k: usize, cost: Dollars, open: &mut Vec<OpenBin>) {
    // Frontier expansion: unexplored subtrees at the spill depth are
    // collected (in DFS order) instead of expanded; complete leaves
    // fall through to `record_item_leaf`, which collects them too.
    if k == ctx.spill_depth && k < ctx.order.len() {
        ctx.spill.push(ItemEntry::Task(ItemTask { k, cost, open: open.clone() }));
        return;
    }
    if ctx.acct.step() {
        return;
    }
    if k == ctx.order.len() {
        record_item_leaf(ctx, cost, open);
        return;
    }
    // Prune: even the relaxed remainder cannot beat the incumbent.
    let lb = cost.as_f64() + lower_bound(ctx, k, open);
    if lb >= prune_limit(ctx.best_cost, ctx.acct.shared) {
        return;
    }

    let item_idx = ctx.order[k];
    // Copy the &'p problem reference out of the context so requirement
    // vectors borrow the problem, not `ctx` — the branch loops used to
    // clone a heap-backed ResourceVec per (bin, choice) node to appease
    // the borrow checker.
    let problem = ctx.problem;
    let n_choices = problem.items[item_idx].choices.len();

    // Branch 1: place into an existing open bin.  Dedupe branches that
    // land in bins with identical (type, residual) — permutation symmetry.
    let mut tried: Vec<(usize, Vec<i64>)> = Vec::new();
    for b in 0..open.len() {
        let key: Vec<i64> = open[b]
            .residual
            .0
            .iter()
            .map(|v| (v * 1e6).round() as i64)
            .collect();
        if tried.iter().any(|(t, k2)| *t == open[b].bin_type && *k2 == key) {
            continue;
        }
        tried.push((open[b].bin_type, key));
        for c in 0..n_choices {
            let req = &problem.items[item_idx].choices[c];
            if req.fits(&open[b].residual) {
                let step_cost = cost + problem.choice_cost(item_idx, c);
                open[b].residual.sub_assign(req);
                if prune_child(ctx, k + 1, step_cost, open) {
                    open[b].residual.add_assign(req);
                    continue;
                }
                open[b].assignments.push((item_idx, c));
                dfs(ctx, k + 1, step_cost, open);
                open[b].assignments.pop();
                open[b].residual.add_assign(req);
                if ctx.acct.exhausted {
                    return;
                }
            }
        }
    }

    // Branch 2: open a new bin of each type.
    for (t, bt) in problem.bin_types.iter().enumerate() {
        let new_cost = cost + bt.cost;
        if new_cost >= ctx.best_cost {
            continue;
        }
        for c in 0..n_choices {
            let req = &problem.items[item_idx].choices[c];
            if req.fits(&bt.capacity) {
                let step_cost = new_cost + problem.choice_cost(item_idx, c);
                let mut residual = bt.capacity.clone();
                residual.sub_assign(req);
                open.push(OpenBin {
                    bin_type: t,
                    residual,
                    assignments: vec![(item_idx, c)],
                });
                if prune_child(ctx, k + 1, step_cost, open) {
                    open.pop();
                    continue;
                }
                dfs(ctx, k + 1, step_cost, open);
                open.pop();
                if ctx.acct.exhausted {
                    return;
                }
            }
        }
    }
}

/// One open bin of the class search.
#[derive(Clone)]
struct ClassBin {
    bin_type: usize,
    residual: ResourceVec,
    /// `(class position in search order, choice, copies)` in placement
    /// order.
    entries: Vec<(usize, usize, u32)>,
}

/// An unexplored class-mode subtree: the `distribute` state at its
/// root.
#[derive(Clone)]
struct ClassTask {
    ci: usize,
    remaining: u32,
    cost: Dollars,
    bins: Vec<ClassBin>,
    from: (usize, usize),
    last_fresh: Option<(usize, usize, u32)>,
}

/// One frontier entry of the class-mode parallel search, in DFS order.
enum ClassEntry {
    Task(ClassTask),
    Leaf { cost: Dollars, solution: Solution },
}

struct ClassCtx<'p, 's> {
    problem: &'p MvbpProblem,
    /// Classes in search order (hardest representative first).
    classes: &'s [ItemClass],
    bounds: &'s BoundCtx,
    best_cost: Dollars,
    best: Option<Solution>,
    acct: Accounting<'s>,
    /// Frontier expansion: spill subtrees `spill_depth` levels below
    /// the task root instead of recursing (`usize::MAX` = off).
    spill_depth: usize,
    spill: Vec<ClassEntry>,
}

/// Class-search analogue of [`lower_bound`]: relaxed demand of the
/// unplaced copies of class `ci` plus every later class, minus open
/// residuals, priced at the best capacity-per-dollar.
fn class_lower_bound(ctx: &ClassCtx, ci: usize, remaining: u32, bins: &[ClassBin]) -> f64 {
    let mut bound: f64 = 0.0;
    for d in 0..ctx.problem.dims {
        let demand =
            ctx.bounds.suffix_demand[ci + 1][d] + ctx.bounds.min_req[ci][d] * remaining as f64;
        if demand <= 0.0 {
            continue;
        }
        let residual: f64 = bins.iter().map(|b| b.residual[d].max(0.0)).sum();
        let extra = demand - residual;
        if extra > 0.0 && ctx.bounds.dim_efficiency[d] > 0.0 {
            bound = bound.max(extra / ctx.bounds.dim_efficiency[d]);
        }
    }
    bound
}

/// Class-search analogue of [`prune_child`]: evaluate the child's entry
/// prune in the parent.  This is what keeps run branching cheap — the
/// `k-1` shorter runs under a dominated maximal run each cost one bound
/// evaluation, not an expanded node (the per-copy search pays a node per
/// copy no matter what).
fn prune_class_child(
    ctx: &ClassCtx,
    ci: usize,
    remaining: u32,
    cost: Dollars,
    bins: &[ClassBin],
) -> bool {
    cost.as_f64() + class_lower_bound(ctx, ci, remaining, bins)
        >= prune_limit(ctx.best_cost, ctx.acct.shared)
}

/// Expand the class-level bins to per-item assignments (members dealt
/// out ascending, exactly like `aggregate::expand`) and record the
/// solution if it beats the incumbent — or, during frontier expansion,
/// collect it as an indexed leaf candidate (the incumbent stays pinned
/// at the seed there; see module docs).
fn record_class_leaf(ctx: &mut ClassCtx, cost: Dollars, bins: &[ClassBin]) {
    if cost >= ctx.best_cost {
        return;
    }
    let mut cursor = vec![0usize; ctx.classes.len()];
    let mut out = Vec::with_capacity(bins.len());
    for bin in bins {
        let total: usize = bin.entries.iter().map(|&(_, _, k)| k as usize).sum();
        let mut assignments = Vec::with_capacity(total);
        for &(ci, choice, count) in &bin.entries {
            let start = cursor[ci];
            cursor[ci] += count as usize;
            for &member in &ctx.classes[ci].members[start..start + count as usize] {
                assignments.push((member as usize, choice));
            }
        }
        out.push(PackedBin { bin_type: bin.bin_type, assignments });
    }
    let solution = Solution { bins: out };
    if ctx.spill_depth != usize::MAX {
        ctx.spill.push(ClassEntry::Leaf { cost, solution });
        return;
    }
    ctx.best_cost = cost;
    if let Some(shared) = ctx.acct.shared {
        shared.relax(cost);
    }
    ctx.best = Some(solution);
}

/// Distribute the `remaining` unplaced copies of class `ci` and recurse
/// into later classes.
///
/// `from` is the `(bin, choice)` cursor: within one class, placements
/// are generated in strictly increasing cursor order, so each
/// *distribution* (set of `(bin, choice, count)` runs) is enumerated
/// exactly once regardless of placement order.  `last_fresh` is the
/// `(type, choice, count)` key of the class's most recent fresh-opened
/// bin; fresh opens must not increase in that key, which sorts the
/// interchangeable-at-open bins of one class into a canonical sequence.
/// `depth` counts levels below the search (or subtree-task) root; the
/// frontier expansion spills at `depth == ctx.spill_depth`.
#[allow(clippy::too_many_arguments)]
fn distribute(
    ctx: &mut ClassCtx,
    ci: usize,
    remaining: u32,
    cost: Dollars,
    bins: &mut Vec<ClassBin>,
    from: (usize, usize),
    last_fresh: Option<(usize, usize, u32)>,
    depth: usize,
) {
    // Frontier expansion: collect the subtree (in DFS order) instead
    // of expanding it.
    if depth == ctx.spill_depth {
        ctx.spill.push(ClassEntry::Task(ClassTask {
            ci,
            remaining,
            cost,
            bins: bins.clone(),
            from,
            last_fresh,
        }));
        return;
    }
    if ctx.acct.step() {
        return;
    }
    if remaining == 0 {
        if ci + 1 == ctx.classes.len() {
            record_class_leaf(ctx, cost, bins);
            return;
        }
        let next_count = ctx.classes[ci + 1].count() as u32;
        distribute(ctx, ci + 1, next_count, cost, bins, (0, 0), None, depth + 1);
        return;
    }
    // Prune: even the relaxed remainder cannot beat the incumbent.
    let lb = cost.as_f64() + class_lower_bound(ctx, ci, remaining, bins);
    if lb >= prune_limit(ctx.best_cost, ctx.acct.shared) {
        return;
    }

    let problem = ctx.problem;
    let rep = ctx.classes[ci].rep;
    let n_choices = problem.items[rep].choices.len();

    // Branch 1: runs into existing bins at or past the cursor, with the
    // same equal-(type, residual) dedup as the per-item search —
    // swapping the full remaining contents of two equal-residual bins
    // of one type is a cost-preserving bijection, so branching the
    // first of each group is enough.
    let mut tried: Vec<(usize, Vec<i64>)> = Vec::new();
    for b in from.0..bins.len() {
        let key: Vec<i64> = bins[b]
            .residual
            .0
            .iter()
            .map(|v| (v * 1e6).round() as i64)
            .collect();
        if tried.iter().any(|(t, k2)| *t == bins[b].bin_type && *k2 == key) {
            continue;
        }
        tried.push((bins[b].bin_type, key));
        let c_start = if b == from.0 { from.1 } else { 0 };
        for c in c_start..n_choices {
            let req = &problem.items[rep].choices[c];
            // Subtract copies one by one under the shared `fits`
            // tolerance; `placed` copies are subtracted on exit.
            let mut placed: u32 = 0;
            while placed < remaining && req.fits(&bins[b].residual) {
                bins[b].residual.sub_assign(req);
                placed += 1;
            }
            if placed == 0 {
                continue;
            }
            // Largest run first; `k` copies stay subtracted while the
            // branch for `k` runs.
            let mut k = placed;
            loop {
                let run_cost = cost + problem.choice_cost(rep, c) * k;
                if !prune_class_child(ctx, ci, remaining - k, run_cost, bins) {
                    bins[b].entries.push((ci, c, k));
                    distribute(
                        ctx,
                        ci,
                        remaining - k,
                        run_cost,
                        bins,
                        (b, c + 1),
                        last_fresh,
                        depth + 1,
                    );
                    bins[b].entries.pop();
                    if ctx.acct.exhausted {
                        for _ in 0..k {
                            bins[b].residual.add_assign(req);
                        }
                        return;
                    }
                }
                bins[b].residual.add_assign(req);
                if k == 1 {
                    break;
                }
                k -= 1;
            }
        }
    }

    // Branch 2: open a fresh bin with a run of this class, in
    // non-increasing (type, choice, count) key order.
    for (t, bt) in problem.bin_types.iter().enumerate() {
        let new_cost = cost + bt.cost;
        if new_cost >= ctx.best_cost {
            continue;
        }
        for c in 0..n_choices {
            let req = &problem.items[rep].choices[c];
            if !req.fits(&bt.capacity) {
                continue;
            }
            let mut probe = bt.capacity.clone();
            let mut max_k: u32 = 0;
            while max_k < remaining && req.fits(&probe) {
                probe.sub_assign(req);
                max_k += 1;
            }
            for k in (1..=max_k).rev() {
                if let Some(last) = last_fresh {
                    if (t, c, k) > last {
                        continue;
                    }
                }
                let mut residual = bt.capacity.clone();
                for _ in 0..k {
                    residual.sub_assign(req);
                }
                let run_cost = new_cost + problem.choice_cost(rep, c) * k;
                bins.push(ClassBin { bin_type: t, residual, entries: vec![(ci, c, k)] });
                if prune_class_child(ctx, ci, remaining - k, run_cost, bins) {
                    bins.pop();
                    continue;
                }
                let idx = bins.len() - 1;
                distribute(
                    ctx,
                    ci,
                    remaining - k,
                    run_cost,
                    bins,
                    (idx, c + 1),
                    Some((t, c, k)),
                    depth + 1,
                );
                bins.pop();
                if ctx.acct.exhausted {
                    return;
                }
            }
        }
    }
}

/// Convenience wrapper: default budget, discard metadata.
pub fn solve_exact(problem: &MvbpProblem) -> Option<Solution> {
    BranchAndBound::default()
        .solve(problem)
        .map(|r| r.solution)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::problem::test_fixtures::small_problem;
    use crate::packing::problem::{BinType, Item};

    #[test]
    fn packs_small_problem_optimally() {
        let p = small_problem();
        let r = BranchAndBound::default().solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
        assert!(r.proven_optimal);
        // Optimal: everything in one big bin ($1.8) beats two small ($2.0).
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(1.8));
    }

    #[test]
    fn empty_problem_is_trivially_optimal() {
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[1.0]),
            }],
            items: vec![],
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert!(r.solution.bins.is_empty());
        assert!(r.proven_optimal);
    }

    #[test]
    fn infeasible_returns_none() {
        let mut p = small_problem();
        p.items.push(Item {
            id: "huge".into(),
            choices: vec![ResourceVec::from_slice(&[100.0, 0.0])],
        });
        assert!(BranchAndBound::default().solve(&p).is_none());
    }

    #[test]
    fn choice_changes_optimum() {
        // One bin type (cap 4); items 3+3 don't colocate, but 3+1 does if
        // the second item picks its alternative choice.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[4.0]),
            }],
            items: vec![
                Item {
                    id: "x".into(),
                    choices: vec![ResourceVec::from_slice(&[3.0])],
                },
                Item {
                    id: "y".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[3.0]),
                        ResourceVec::from_slice(&[1.0]),
                    ],
                },
            ],
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert_eq!(r.solution.bins.len(), 1);
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(1.0));
        // y must have picked choice 1.
        let picked: Vec<_> = r.solution.bins[0]
            .assignments
            .iter()
            .filter(|(i, _)| *i == 1)
            .collect();
        assert_eq!(picked[0].1, 1);
    }

    #[test]
    fn prefers_cheaper_type_mix() {
        // Big bin is overkill for one tiny item.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![
                BinType {
                    name: "small".into(),
                    cost: Dollars::from_f64(0.4),
                    capacity: ResourceVec::from_slice(&[2.0]),
                },
                BinType {
                    name: "big".into(),
                    cost: Dollars::from_f64(1.0),
                    capacity: ResourceVec::from_slice(&[10.0]),
                },
            ],
            items: vec![Item {
                id: "t".into(),
                choices: vec![ResourceVec::from_slice(&[1.0])],
            }],
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(0.4));
    }

    #[test]
    fn node_budget_degrades_gracefully() {
        let p = small_problem();
        let r = BranchAndBound { node_budget: 1, ..Default::default() }
            .solve(&p)
            .unwrap();
        // Budget hit: still returns the BFD incumbent, flagged non-optimal.
        r.solution.validate(&p).unwrap();
        assert!(!r.proven_optimal);
    }

    #[test]
    fn expired_deadline_degrades_to_the_incumbent() {
        // A deadline already in the past: the first polled check aborts
        // the proof, but the seeded incumbent still comes back valid.
        let p = small_problem();
        let bb = BranchAndBound {
            node_budget: u64::MAX,
            deadline: Some(Instant::now() - std::time::Duration::from_millis(1)),
            ..Default::default()
        };
        let r = bb.solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
    }

    #[test]
    fn seeded_incumbent_is_used_and_invalid_seeds_are_discarded() {
        let p = small_problem();
        let good = crate::packing::solve_first_fit(&p).unwrap();
        let r = BranchAndBound::default()
            .solve_seeded(&p, Some(good.clone()))
            .unwrap();
        assert!(r.proven_optimal);
        assert!(!r.seed_dropped, "a valid seed must not be flagged dropped");
        assert!(r.solution.cost(&p) <= good.cost(&p));

        // An empty (invalid: items unpacked) seed must not be trusted —
        // and the drop must be surfaced.
        let r2 = BranchAndBound::default()
            .solve_seeded(&p, Some(Solution::default()))
            .unwrap();
        assert!(r2.proven_optimal);
        assert!(r2.seed_dropped, "an invalid seed must be flagged dropped");
        assert_eq!(r2.solution.cost(&p), r.solution.cost(&p));

        // An unseeded solve is a cold solve, not a dropped seed.
        let r3 = BranchAndBound::default().solve_seeded(&p, None).unwrap();
        assert!(!r3.seed_dropped);
    }

    /// `counts[i]` copies of `small_problem` item `i` — the class path
    /// engages whenever aggregation pays.
    fn replicated_fixture(counts: &[usize]) -> MvbpProblem {
        let base = small_problem();
        let mut items = Vec::new();
        for (t, item) in base.items.iter().enumerate() {
            for i in 0..counts[t] {
                items.push(Item {
                    id: format!("c{t}-{i}"),
                    choices: item.choices.clone(),
                });
            }
        }
        MvbpProblem {
            dims: base.dims,
            bin_types: base.bin_types.clone(),
            items,
            choice_costs: vec![],
        }
    }

    #[test]
    fn class_search_matches_per_item_on_replicated_fixture() {
        let p = replicated_fixture(&[4, 3, 5]); // 12 items, 3 classes
        let class = BranchAndBound::default().solve(&p).unwrap();
        let per_item = BranchAndBound { per_item: true, ..Default::default() }
            .solve(&p)
            .unwrap();
        class.solution.validate(&p).unwrap();
        per_item.solution.validate(&p).unwrap();
        assert!(class.proven_optimal, "class search must prove this scale");
        assert!(per_item.proven_optimal, "per-item search must prove this scale");
        assert_eq!(class.solution.cost(&p), per_item.solution.cost(&p));
    }

    #[test]
    fn class_search_node_budget_degrades_gracefully() {
        let p = replicated_fixture(&[6, 6, 6]);
        let r = BranchAndBound { node_budget: 1, ..Default::default() }
            .solve(&p)
            .unwrap();
        r.solution.validate(&p).unwrap();
        assert!(!r.proven_optimal);
    }

    #[test]
    fn class_search_uses_choices_for_colocation() {
        // Two copies each of x=[3] and y=[3]|[1] into cap-4 bins: the
        // optimum pairs every x with a y on its alternative choice.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[4.0]),
            }],
            items: vec![
                Item { id: "x0".into(), choices: vec![ResourceVec::from_slice(&[3.0])] },
                Item { id: "x1".into(), choices: vec![ResourceVec::from_slice(&[3.0])] },
                Item {
                    id: "y0".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[3.0]),
                        ResourceVec::from_slice(&[1.0]),
                    ],
                },
                Item {
                    id: "y1".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[3.0]),
                        ResourceVec::from_slice(&[1.0]),
                    ],
                },
            ],
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
        assert!(r.proven_optimal);
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(2.0));
    }

    #[test]
    fn single_class_fleet_proves_tight_packing() {
        // 12 copies of [3] into cap-10 bins: 3 per bin, 4 bins, proven.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[10.0]),
            }],
            items: (0..12)
                .map(|i| Item {
                    id: format!("s{i}"),
                    choices: vec![ResourceVec::from_slice(&[3.0])],
                })
                .collect(),
            choice_costs: vec![],
        };
        let r = BranchAndBound::default().solve(&p).unwrap();
        r.solution.validate(&p).unwrap();
        assert!(r.proven_optimal);
        assert_eq!(r.solution.cost(&p), Dollars::from_f64(4.0));
    }

    #[test]
    fn bound_ctx_matches_per_call_computation_bitwise() {
        // The hoisted BoundCtx must be bit-identical to computing each
        // piece per call (the pre-hoist code path): same fold order,
        // same arithmetic.
        let p = replicated_fixture(&[4, 3, 5]);

        // Per-item: order is by hardness, same as solve_item_search.
        let roomiest = roomiest_capacity(&p);
        let mut order: Vec<usize> = (0..p.items.len()).collect();
        let hardness = |i: usize| -> f64 {
            p.items[i]
                .choices
                .iter()
                .map(|c| c.max_ratio(&roomiest))
                .fold(f64::INFINITY, f64::min)
        };
        order.sort_by(|&a, &b| hardness(b).total_cmp(&hardness(a)));
        let ctx = BoundCtx::for_items(&p, &order);
        for (d, &eff) in ctx.dim_efficiency.iter().enumerate() {
            assert_eq!(eff.to_bits(), dim_efficiencies(&p)[d].to_bits());
        }
        for k in (0..order.len()).rev() {
            // Per-call recomputation: fold the relaxed demands from the
            // end, exactly as the pre-hoist suffix construction did.
            let mut acc = ResourceVec::zeros(p.dims);
            for j in (k..order.len()).rev() {
                acc = acc.add(&relaxed_req(&p, order[j]));
            }
            for d in 0..p.dims {
                assert_eq!(
                    ctx.suffix_demand[k][d].to_bits(),
                    acc[d].to_bits(),
                    "per-item suffix_demand[{k}][{d}] drifted from the per-call value"
                );
            }
        }

        // Class mode: classes sorted by representative hardness, same
        // as solve_class_search.
        let mut classes =
            aggregate::group_classes_capped(&p, p.items.len() / 2).expect("aggregation pays here");
        classes.sort_by(|a, b| hardness(b.rep).total_cmp(&hardness(a.rep)));
        let cctx = BoundCtx::for_classes(&p, &classes);
        for k in (0..classes.len()).rev() {
            let mut acc = ResourceVec::zeros(p.dims);
            for j in (k..classes.len()).rev() {
                let req = relaxed_req(&p, classes[j].rep);
                let count = classes[j].count() as f64;
                for d in 0..p.dims {
                    acc.0[d] += req[d] * count;
                }
            }
            for d in 0..p.dims {
                assert_eq!(
                    cctx.suffix_demand[k][d].to_bits(),
                    acc[d].to_bits(),
                    "class suffix_demand[{k}][{d}] drifted from the per-call value"
                );
            }
        }
    }

    #[test]
    fn parallel_item_search_is_bit_identical_to_sequential() {
        // small_problem has three distinct items, so aggregation never
        // pays and this exercises the per-item parallel path.
        let p = small_problem();
        let seq = BranchAndBound::default().solve(&p).unwrap();
        for threads in [2, 8] {
            let par = BranchAndBound { threads, ..Default::default() }
                .solve(&p)
                .unwrap();
            assert!(par.proven_optimal);
            assert_eq!(par.solution, seq.solution, "threads={threads} diverged");
        }
    }

    #[test]
    fn parallel_class_search_is_bit_identical_to_sequential() {
        let p = replicated_fixture(&[4, 3, 5]);
        let seq = BranchAndBound::default().solve(&p).unwrap();
        for threads in [2, 8] {
            let par = BranchAndBound { threads, ..Default::default() }
                .solve(&p)
                .unwrap();
            assert!(par.proven_optimal);
            assert_eq!(par.solution, seq.solution, "threads={threads} diverged");
        }
    }

    #[test]
    fn parallel_all_cores_and_budget_exhaustion_degrade_gracefully() {
        // threads: 0 = one per core; still proves and matches.
        let p = replicated_fixture(&[4, 3, 5]);
        let seq = BranchAndBound::default().solve(&p).unwrap();
        let par = BranchAndBound { threads: 0, ..Default::default() }
            .solve(&p)
            .unwrap();
        assert!(par.proven_optimal);
        assert_eq!(par.solution, seq.solution);

        // A starved global budget still returns the seed incumbent,
        // flagged non-optimal.
        let starved = BranchAndBound { threads: 4, node_budget: 1, ..Default::default() }
            .solve(&p)
            .unwrap();
        starved.solution.validate(&p).unwrap();
        assert!(!starved.proven_optimal);
    }
}
