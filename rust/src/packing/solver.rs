//! The first-class solver layer: a [`Solver`] trait every MVBP strategy
//! implements, a [`PortfolioSolver`] that races strategies on scoped
//! threads, and the certified lower bound every outcome carries.
//!
//! Three ideas compose here:
//!
//! 1. **Trait, not free functions.**  [`Solver::solve`] takes a
//!    problem and a [`SolveBudget`] and returns a [`SolveOutcome`]
//!    carrying the solution *plus* a certified cost lower bound and the
//!    resulting optimality gap — every allocation self-certifies
//!    instead of handing back a blind answer.
//! 2. **Portfolio racing.**  [`PortfolioSolver`] runs first-fit and
//!    best-fit under several item orderings concurrently on
//!    `std::thread::scope` threads (zero external deps), then polishes
//!    the winner with a deadline-bounded exact search seeded with the
//!    racing incumbent.  Above [`PortfolioSolver::full_arm_cutoff`]
//!    items the full-scan arms switch to *sharded* arms: the ordered
//!    item list is split into chunks packed independently and
//!    concatenated, trading a few percent of packing quality for a
//!    quadratic reduction in bin-scan work (each shard scans only its
//!    own bins).
//! 3. **Budget-based selection.**  [`SolverChoice::Auto`] replaces the
//!    old `solve_auto` cliff: small instances get the exact solver
//!    (deadline-bounded, so the budget — not an item count alone —
//!    decides how much proof is affordable), larger ones the portfolio,
//!    whose own exact arm keeps polishing mid-size instances instead of
//!    falling off a heuristic cliff.
//!
//! The lower bound is the max of three bound families, each valid for
//! the multi-dimensional multiple-choice problem because items are
//! counted at their cheapest choice: the arc-flow L2 bound
//! ([`arcflow::l2_lower_bound`]) evaluated on each dimension's relaxed
//! 1-D projection (weights rounded *down*, see
//! [`arcflow::discretize_relaxed`]), priced at the cheapest bin type;
//! the capacity-per-dollar bound (every dollar buys at most the best
//! capacity-per-dollar in each dimension); and the dual-feasible-
//! function bounds of [`super::bounds`], evaluated over weighted
//! dimension *combinations*.  The DFF term closes what used to be a
//! documented looseness on mixed CPU+GPU catalogs: per-dimension
//! projections are nearly vacuous there, because every stream can zero
//! its GPU-dimension demand by choosing CPU and shrink its
//! CPU-dimension demand by choosing GPU — a combined projection
//! normalized by each dimension's roomiest capacity cannot be dodged
//! by either choice, so the certificate tightens exactly where the
//! warm-drift gate needs it.

use super::aggregate;
use super::arcflow;
use super::bounds;
use super::exact::BranchAndBound;
use super::heuristics::{self, Greedy, ItemOrder};
use super::problem::{MvbpProblem, Solution};
use super::SolverKind;
use crate::types::Dollars;
use crate::util::profiling;
use std::time::{Duration, Instant};

/// Static per-arm labels for the phase profiler (no allocation on the
/// hot path, nothing at all unless the `profiling` feature is on).
fn arm_label(greedy: Greedy, order: ItemOrder) -> &'static str {
    match (greedy, order) {
        (Greedy::FirstFit, ItemOrder::HardestFirst) => "arm:ff-hardest",
        (Greedy::FirstFit, ItemOrder::SumDecreasing) => "arm:ff-sum",
        (Greedy::FirstFit, ItemOrder::FewestChoices) => "arm:ff-fewest",
        (Greedy::BestFit, ItemOrder::HardestFirst) => "arm:bf-hardest",
        (Greedy::BestFit, ItemOrder::SumDecreasing) => "arm:bf-sum",
        (Greedy::BestFit, ItemOrder::FewestChoices) => "arm:bf-fewest",
    }
}

/// Resource limits a solve may spend, replacing the old hard-coded
/// `exact_cutoff` field with an explicit, CLI-settable budget.
#[derive(Clone, Copy, Debug)]
pub struct SolveBudget {
    /// Wall-clock deadline in milliseconds for deadline-bounded solvers
    /// (`0` = no deadline).  Determinism note: results are reproducible
    /// whenever solves finish within the node budget before the
    /// deadline fires, which holds for paper-scale instances by a wide
    /// margin.
    pub time_ms: u64,
    /// Item count at or below which [`SolverChoice::Auto`] runs the
    /// exact solver directly (the portfolio takes over above it).
    pub exact_cutoff: usize,
    /// Node budget for branch-and-bound (the deterministic cap).
    pub node_budget: u64,
    /// Warm-start acceptance: how far a warm-started plan's certified
    /// gap may drift above the previous plan's before the manager falls
    /// back to a cold solve (see `ResourceManager::allocate_warm`).
    pub warm_gap_margin: f64,
    /// Worker threads for the exact search's multi-root parallel mode:
    /// `1` (the default) keeps the classic sequential search, `0` means
    /// one per available core, clamped to 16 either way.  Completed
    /// proofs are bit-identical for every setting (see
    /// `packing::exact`), so this is a pure wall-clock knob.
    pub exact_threads: usize,
}

impl Default for SolveBudget {
    fn default() -> Self {
        SolveBudget {
            // Generous: the node budget is the deterministic cap; the
            // deadline only rescues instances whose nodes are
            // individually expensive.
            time_ms: 10_000,
            exact_cutoff: 24,
            node_budget: 5_000_000,
            warm_gap_margin: 0.05,
            exact_threads: 1,
        }
    }
}

impl SolveBudget {
    /// The wall-clock deadline counted from now (`None` if disabled).
    pub fn deadline(&self) -> Option<Instant> {
        (self.time_ms > 0).then(|| Instant::now() + Duration::from_millis(self.time_ms))
    }
}

/// A solution plus its certificate: what the packing costs, the best
/// proven cost lower bound, and whether optimality was proven.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub solution: Solution,
    /// Which solver (or portfolio) produced the solution.
    pub solver: SolverKind,
    pub cost: Dollars,
    /// Certified cost lower bound (`lower_bound <= cost` always).
    pub lower_bound: Dollars,
    pub proven_optimal: bool,
}

/// Relative certified optimality gap `(cost - lower_bound) / cost`, in
/// `[0, 1]` and always finite (`0` for a zero-cost packing).  The one
/// formula shared by [`SolveOutcome::gap`] and `AllocationPlan::gap`,
/// so the gap the warm-start drift gate compares is the gap the reports
/// print.
pub fn certified_gap(cost: Dollars, lower_bound: Dollars) -> f64 {
    if cost.0 <= 0 {
        return 0.0;
    }
    (cost.0 - lower_bound.0).max(0) as f64 / cost.0 as f64
}

impl SolveOutcome {
    /// Relative optimality gap — see [`certified_gap`].
    pub fn gap(&self) -> f64 {
        certified_gap(self.cost, self.lower_bound)
    }
}

/// A pluggable MVBP solving strategy.
///
/// `solve` returns `None` when the instance is invalid or genuinely
/// unpackable (some item fits in no bin under any choice); otherwise
/// the outcome's solution is validate-clean and its `lower_bound` is a
/// proven bound on any feasible packing's cost.
pub trait Solver: Sync {
    fn name(&self) -> &'static str;
    fn solve(&self, problem: &MvbpProblem, budget: &SolveBudget) -> Option<SolveOutcome>;

    /// Like [`Solver::solve`], with an optional lower bound the caller
    /// has *already certified* for this exact problem (e.g. carried
    /// over from a declined warm-start solve of the same instance).  A
    /// valid hint substitutes for recomputing
    /// [`certified_lower_bound`] on the outcome path — the bound
    /// evaluation is pure, so re-running it on the same problem can
    /// only reproduce the hint.  The default ignores the hint.
    fn solve_with(
        &self,
        problem: &MvbpProblem,
        budget: &SolveBudget,
        bound_hint: Option<Dollars>,
    ) -> Option<SolveOutcome> {
        let _ = bound_hint;
        self.solve(problem, budget)
    }
}

/// Certified cost lower bound for an MVBP instance: the max of
///
/// * per dimension, the arc-flow L2 bin bound (relaxed grid, priced at
///   the cheapest type) and the capacity-per-dollar bound — valid
///   because every feasible packing covers each dimension's relaxed
///   demand (items counted at their cheapest choice), every opened bin
///   costs at least the cheapest type, and every dollar buys at most
///   the best capacity-per-dollar in each dimension;
/// * the dual-feasible-function bound ([`bounds::dff_lower_bound`])
///   over weighted dimension combinations, which stays sharp on mixed
///   CPU+GPU catalogs where the per-dimension projections above go
///   slack (each dimension individually can be dodged via the other
///   execution choice; the combined projection cannot).
///
/// The result is never weaker than the pre-DFF bound: the DFF term
/// only enters through a `max`.
pub fn certified_lower_bound(problem: &MvbpProblem) -> Dollars {
    if problem.items.is_empty() || problem.bin_types.is_empty() {
        return Dollars::ZERO;
    }
    const GRID: u32 = 4096;
    let min_cost = problem
        .bin_types
        .iter()
        .map(|bt| bt.cost)
        .min()
        .unwrap_or(Dollars::ZERO);
    let mut best = Dollars::ZERO;
    for d in 0..problem.dims {
        let roomiest = problem
            .bin_types
            .iter()
            .map(|bt| bt.capacity[d])
            .fold(0.0f64, f64::max);
        if roomiest <= 0.0 {
            continue;
        }
        // Relaxed per-item demand: the cheapest choice in this dimension.
        let weights: Vec<f64> = problem
            .items
            .iter()
            .map(|it| {
                let w = it
                    .choices
                    .iter()
                    .map(|c| c[d])
                    .fold(f64::INFINITY, f64::min);
                if w.is_finite() {
                    w.max(0.0)
                } else {
                    0.0 // no choices: validate rejects; bound stays safe
                }
            })
            .collect();
        let (grid_w, grid_cap) = arcflow::discretize_relaxed(&weights, roomiest, GRID);
        let bins = arcflow::l2_lower_bound(&grid_w, grid_cap);
        if bins != u32::MAX {
            let l2_cost = min_cost * bins;
            if l2_cost > best {
                best = l2_cost;
            }
        }
        // Capacity-per-dollar: cost >= demand / max_t(cap_t / cost_t).
        let efficiency = problem
            .bin_types
            .iter()
            .map(|bt| {
                let cost = bt.cost.as_f64();
                if cost > 0.0 {
                    bt.capacity[d] / cost
                } else {
                    f64::INFINITY
                }
            })
            .fold(0.0f64, f64::max);
        let demand: f64 = weights.iter().sum();
        if efficiency.is_finite() && efficiency > 0.0 && demand > 0.0 {
            // Floor: never round a float bound *up* past the true bound.
            let eff_cost = Dollars(((demand / efficiency) * 1e6).floor() as i64);
            if eff_cost > best {
                best = eff_cost;
            }
        }
    }
    // The DFF family (gated only for old-vs-new bench ablation).
    if !bounds::dff_disabled() {
        let dff = bounds::dff_lower_bound(problem);
        if dff > best {
            best = dff;
        }
    }
    // Choice-cost floor: every item pays at least its cheapest per-
    // choice assignment cost on top of the bin-opening bound (zero
    // unless the problem carries choice costs).
    let floor: Dollars = (0..problem.items.len())
        .map(|i| {
            (0..problem.items[i].choices.len())
                .map(|c| problem.choice_cost(i, c))
                .min()
                .unwrap_or(Dollars::ZERO)
        })
        .sum();
    best + floor
}

/// Build a certified outcome.  A proven-optimal solution is its own
/// certificate, so the bound evaluation is skipped outright; otherwise
/// `bound_hint` — a lower bound the caller already certified for this
/// exact problem — substitutes for recomputing [`certified_lower_bound`]
/// (the evaluation is pure, so re-running it would only reproduce the
/// hint).
fn outcome_with(
    problem: &MvbpProblem,
    solution: Solution,
    solver: SolverKind,
    proven_optimal: bool,
    bound_hint: Option<Dollars>,
) -> SolveOutcome {
    let cost = solution.cost(problem);
    if proven_optimal {
        return SolveOutcome { solution, solver, cost, lower_bound: cost, proven_optimal };
    }
    // Clamp: the bound is valid by construction, but `cost` is the
    // invariant reports and tests lean on.
    let lower_bound = bound_hint
        .unwrap_or_else(|| certified_lower_bound(problem))
        .min(cost);
    let proven_optimal = lower_bound == cost;
    SolveOutcome { solution, solver, cost, lower_bound, proven_optimal }
}

fn outcome_for(
    problem: &MvbpProblem,
    solution: Solution,
    solver: SolverKind,
    proven_optimal: bool,
) -> SolveOutcome {
    outcome_with(problem, solution, solver, proven_optimal, None)
}

/// First-fit-decreasing behind the trait.
pub struct FfdSolver;

impl Solver for FfdSolver {
    fn name(&self) -> &'static str {
        "ffd"
    }

    fn solve(&self, problem: &MvbpProblem, budget: &SolveBudget) -> Option<SolveOutcome> {
        self.solve_with(problem, budget, None)
    }

    fn solve_with(
        &self,
        problem: &MvbpProblem,
        _budget: &SolveBudget,
        bound_hint: Option<Dollars>,
    ) -> Option<SolveOutcome> {
        let solution = heuristics::solve_first_fit(problem)?;
        Some(outcome_with(problem, solution, SolverKind::FirstFit, false, bound_hint))
    }
}

/// Best-fit-decreasing behind the trait.
pub struct BfdSolver;

impl Solver for BfdSolver {
    fn name(&self) -> &'static str {
        "bfd"
    }

    fn solve(&self, problem: &MvbpProblem, budget: &SolveBudget) -> Option<SolveOutcome> {
        self.solve_with(problem, budget, None)
    }

    fn solve_with(
        &self,
        problem: &MvbpProblem,
        _budget: &SolveBudget,
        bound_hint: Option<Dollars>,
    ) -> Option<SolveOutcome> {
        let solution = heuristics::solve_best_fit(problem)?;
        Some(outcome_with(problem, solution, SolverKind::BestFit, false, bound_hint))
    }
}

/// Branch-and-bound behind the trait, bounded by the budget's node
/// count and wall-clock deadline.
pub struct ExactSolver;

impl Solver for ExactSolver {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn solve(&self, problem: &MvbpProblem, budget: &SolveBudget) -> Option<SolveOutcome> {
        self.solve_with(problem, budget, None)
    }

    fn solve_with(
        &self,
        problem: &MvbpProblem,
        budget: &SolveBudget,
        bound_hint: Option<Dollars>,
    ) -> Option<SolveOutcome> {
        let bb = BranchAndBound {
            node_budget: budget.node_budget,
            deadline: budget.deadline(),
            threads: budget.exact_threads,
            ..Default::default()
        };
        let result = bb.solve(problem)?;
        Some(outcome_with(
            problem,
            result.solution,
            SolverKind::Exact,
            result.proven_optimal,
            bound_hint,
        ))
    }
}

/// Node cap of the portfolio's exact polish arm: enough to prove
/// optimality on paper-scale instances, small enough that the arm's
/// cost stays deterministic and bounded at mid scale.
const EXACT_ARM_NODE_CAP: u64 = 200_000;

/// Races FFD/BFD under every [`ItemOrder`] on scoped threads, then
/// polishes the cheapest validate-clean result with a deadline-bounded
/// exact search seeded with that incumbent; returns the cheapest
/// validate-clean solution overall.
///
/// When `aggregate` is on, the instance has real item multiplicity
/// (at least two items per distinct requirement class on average, see
/// [`aggregate::aggregation_pays`]), and there are at most
/// `full_arm_cutoff` classes (class-level arms run unsharded, so the
/// class count is bounded exactly like the item count is for full
/// arms), every arm runs over *classes with counts* instead of items —
/// the class-aggregated packing matches the per-item arm's result
/// while the work drops from O(items × bins) to near-linear in items.
/// All-distinct and barely-multiplicitous instances bypass aggregation
/// onto the per-item (sharded) path.
///
/// On the per-item path, at or below `full_arm_cutoff` items every arm
/// packs the full instance, so the portfolio can never return a
/// costlier solution than plain FFD or BFD (they are arms).  Above the
/// cutoff the arms shard: the ordered item list is chunked, each chunk
/// packed into its own bins, and the chunks concatenated — each shard
/// scans only its own open bins, cutting the quadratic bin-scan cost by
/// the shard count squared at the price of at most one underfilled bin
/// per shard.
pub struct PortfolioSolver {
    /// Largest instance the full-scan arms handle before sharding.
    pub full_arm_cutoff: usize,
    /// Items per shard in sharded mode.
    pub shard_size: usize,
    /// Run arms over multiplicity classes when grouping pays (the
    /// default).  Off forces the per-item (sharded) path — benches use
    /// this to measure what aggregation buys.
    pub aggregate: bool,
}

impl Default for PortfolioSolver {
    fn default() -> Self {
        PortfolioSolver { full_arm_cutoff: 1024, shard_size: 1024, aggregate: true }
    }
}

impl PortfolioSolver {
    /// The exact polish arm runs only on instances a bounded search can
    /// still improve within budget: a small multiple of the auto
    /// cutoff.
    fn exact_arm_limit(budget: &SolveBudget) -> usize {
        budget.exact_cutoff.saturating_mul(4)
    }
}

/// Default pool size for `count` tasks: one thread per core, clamped
/// to 16, never more than the task count.
fn pool_threads(count: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(1, 16)
        .min(count)
}

/// Run `count` tasks across a small scoped worker pool of `threads`
/// workers; returns one optional result per task, in task order.
/// Workers claim tasks from an atomic cursor, so thread count never
/// changes *which* results exist — only how fast they arrive.  The
/// exact search's multi-root parallel mode reuses this pool for its
/// subtree tasks (`packing::exact`), hence the generic result type and
/// the explicit thread count.
///
/// An expired `deadline` sheds every task whose `arm_of` is > 0 at
/// claim time: the first arm always completes, so a tight
/// `--solve-budget-ms` degrades the portfolio to a single-arm solve
/// instead of no solve.  (Which extra arms finish under a fired
/// deadline is wall-clock-dependent; the default budget is far above
/// any solve the tests or paper scale run, so results stay
/// deterministic in practice.)
pub(crate) fn race_tasks<T: Send>(
    threads: usize,
    count: usize,
    deadline: Option<Instant>,
    arm_of: impl Fn(usize) -> usize + Sync,
    run: impl Fn(usize) -> Option<T> + Sync,
) -> Vec<Option<T>> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let threads = threads.clamp(1, 16).min(count.max(1));
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..count).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= count {
                    break;
                }
                if arm_of(i) != 0 {
                    if let Some(d) = deadline {
                        if Instant::now() >= d {
                            continue; // shed: slot stays None, arm incomplete
                        }
                    }
                }
                *slots[i].lock().expect("portfolio slot") = run(i);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().expect("portfolio slot"))
        .collect()
}

/// How one remote chunk claim resolved (see [`race_chunks_remote`]).
pub(crate) enum RemoteOutcome<T> {
    /// The worker replied and the reply validated: one result per task
    /// in the claimed range.
    Done(Vec<Option<T>>),
    /// The worker failed terminally — the dispatcher must run the
    /// chunk locally and downshift to single-task claims.
    Failed,
    /// The claim was hedged and the local re-run won: the in-flight
    /// RPC was abandoned, its slots are already filled, and the
    /// dispatcher stays in rotation.
    Abandoned,
}

/// Straggler-hedging knobs for [`race_chunks_remote`], derived from
/// [`FleetTuning`](crate::net::fleet::FleetTuning) by the dispatch
/// sites.
pub(crate) struct HedgeCfg<'a> {
    /// Floor before any claim can be considered a straggler.
    pub after: Duration,
    /// A claim is overdue past `factor` × the median completed-claim
    /// duration (subject to the floor above).
    pub factor: f64,
    /// Called once per hedged claim (counter hook).
    pub on_hedge: &'a (dyn Fn() + Sync),
}

/// One in-flight (or settled) remote chunk claim.
struct Claim {
    range: std::ops::Range<usize>,
    started: Instant,
    done: bool,
    hedged: bool,
}

/// Shared view of remote claim progress, for the hedging loop.
struct Ledger {
    claims: Vec<Claim>,
    /// Durations of *completed* remote claims — the straggler
    /// threshold is a multiple of their median.
    durations: Vec<Duration>,
}

impl Ledger {
    fn median_duration(&self) -> Duration {
        if self.durations.is_empty() {
            return Duration::ZERO;
        }
        let mut sorted = self.durations.clone();
        sorted.sort();
        sorted[sorted.len() / 2]
    }
}

/// [`race_tasks`]' remote sibling: the same claim-from-a-cursor pool,
/// extended with `remote_workers` dispatcher threads that claim
/// *chunks* of `chunk_size` consecutive tasks and ship each chunk to a
/// fleet worker (`remote(w, range, cancelled)`), while `local_threads`
/// threads claim single tasks and run them in-process (`local(i)`).
///
/// The degradation contract is what makes workers safe to race: a
/// dispatcher whose claim [`Failed`](RemoteOutcome::Failed) runs every
/// task of the chunk through `local` itself and then downshifts to
/// single-task local claims, so every task always produces exactly the
/// result the pure-local pool would have produced for it.
///
/// With `hedge` set, local threads that drain the cursor turn into
/// straggler watchers: a remote claim outstanding longer than
/// `factor` × the median completed-claim duration (floored at `after`)
/// is re-run locally, and the `cancelled` predicate handed to `remote`
/// turns true once every slot of its range is filled — the dispatcher
/// abandons the RPC and stays in rotation.  Slots are first-wins:
/// whichever copy of a task's result lands first is kept.  That is
/// outcome-preserving because both copies are the *same* result —
/// workers execute the identical search the local closure runs — so
/// the caller's order-strict fold sees the same candidates regardless
/// of worker count, worker deaths, or hedge timing.
pub(crate) fn race_chunks_remote<T: Send>(
    remote_workers: usize,
    local_threads: usize,
    count: usize,
    chunk_size: usize,
    hedge: Option<HedgeCfg<'_>>,
    remote: impl Fn(usize, std::ops::Range<usize>, &dyn Fn() -> bool) -> RemoteOutcome<T> + Sync,
    local: impl Fn(usize) -> Option<T> + Sync,
) -> Vec<Option<T>> {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Mutex;
    const HEDGE_POLL: Duration = Duration::from_millis(10);
    let chunk_size = chunk_size.max(1);
    // Progress must never depend on the fleet: with no dispatchers
    // there must be at least one local thread.
    let local_threads = if remote_workers == 0 { local_threads.max(1) } else { local_threads };
    let cursor = AtomicUsize::new(0);
    // Outer `None` = unfilled; `Some(result)` = resolved (first-wins).
    let slots: Vec<Mutex<Option<Option<T>>>> = (0..count).map(|_| Mutex::new(None)).collect();
    // Guards so each task runs `local` at most once even when a hedger
    // and a downshifting dispatcher race for the same chunk.
    let local_started: Vec<AtomicBool> = (0..count).map(|_| AtomicBool::new(false)).collect();
    let ledger = Mutex::new(Ledger { claims: Vec::new(), durations: Vec::new() });

    let filled = |i: usize| slots[i].lock().expect("task slot").is_some();
    let fill = |i: usize, result: Option<T>| {
        let mut slot = slots[i].lock().expect("task slot");
        if slot.is_none() {
            *slot = Some(result);
        }
    };
    let run_local_once = |i: usize| {
        if !local_started[i].swap(true, Ordering::Relaxed) && !filled(i) {
            fill(i, local(i));
        }
    };

    std::thread::scope(|scope| {
        for w in 0..remote_workers {
            let (run_local_once, filled, fill) = (&run_local_once, &filled, &fill);
            let (cursor, ledger, remote) = (&cursor, &ledger, &remote);
            scope.spawn(move || {
                let mut alive = true;
                loop {
                    let step = if alive { chunk_size } else { 1 };
                    let start = cursor.fetch_add(step, Ordering::Relaxed);
                    if start >= count {
                        break;
                    }
                    let end = (start + step).min(count);
                    if !alive {
                        for i in start..end {
                            run_local_once(i);
                        }
                        continue;
                    }
                    let claim_id = {
                        let mut ledger = ledger.lock().expect("claim ledger");
                        ledger.claims.push(Claim {
                            range: start..end,
                            started: Instant::now(),
                            done: false,
                            hedged: false,
                        });
                        ledger.claims.len() - 1
                    };
                    let cancelled = || (start..end).all(filled);
                    let outcome = remote(w, start..end, &cancelled);
                    let record = |with_duration: bool| {
                        let mut ledger = ledger.lock().expect("claim ledger");
                        let claim = &mut ledger.claims[claim_id];
                        claim.done = true;
                        if with_duration {
                            let elapsed = claim.started.elapsed();
                            ledger.durations.push(elapsed);
                        }
                    };
                    match outcome {
                        RemoteOutcome::Done(results) if results.len() == end - start => {
                            record(true);
                            for (offset, result) in results.into_iter().enumerate() {
                                fill(start + offset, result);
                            }
                        }
                        RemoteOutcome::Abandoned => record(false),
                        _ => {
                            record(false);
                            alive = false;
                            for i in start..end {
                                run_local_once(i);
                            }
                        }
                    }
                }
            });
        }
        for _ in 0..local_threads {
            let (run_local_once, cursor, ledger, hedge) = (&run_local_once, &cursor, &ledger, &hedge);
            scope.spawn(move || {
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= count {
                        break;
                    }
                    run_local_once(i);
                }
                let Some(hedge) = hedge else { return };
                // Straggler watch: re-run overdue remote claims
                // locally until every claim is settled or hedged.
                loop {
                    let overdue = {
                        let mut ledger = ledger.lock().expect("claim ledger");
                        let threshold = hedge
                            .after
                            .max(ledger.median_duration().mul_f64(hedge.factor.max(1.0)));
                        let mut pick: Option<(usize, Instant)> = None;
                        let mut outstanding = false;
                        for (id, claim) in ledger.claims.iter().enumerate() {
                            if claim.done || claim.hedged {
                                continue;
                            }
                            outstanding = true;
                            if claim.started.elapsed() > threshold
                                && pick.map_or(true, |(_, started)| claim.started < started)
                            {
                                pick = Some((id, claim.started));
                            }
                        }
                        if !outstanding {
                            return;
                        }
                        if let Some((id, _)) = pick {
                            ledger.claims[id].hedged = true;
                            Some(ledger.claims[id].range.clone())
                        } else {
                            None
                        }
                    };
                    match overdue {
                        Some(range) => {
                            (hedge.on_hedge)();
                            for i in range {
                                run_local_once(i);
                            }
                        }
                        None => std::thread::sleep(HEDGE_POLL),
                    }
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().expect("task slot").expect("every task produced a result"))
        .collect()
}

/// The per-item task runner: one greedy pass over one item slice per
/// task (kept as the named entry point the shed-semantics test pins).
fn run_tasks(
    problem: &MvbpProblem,
    tasks: &[(usize, Greedy, &[usize])],
    deadline: Option<Instant>,
) -> Vec<Option<Solution>> {
    race_tasks(
        pool_threads(tasks.len()),
        tasks.len(),
        deadline,
        |i| tasks[i].0,
        |i| {
            let (_, greedy, items) = tasks[i];
            let label = match greedy {
                Greedy::FirstFit => "arm:ff-shard",
                Greedy::BestFit => "arm:bf-shard",
            };
            profiling::time_phase(label, || {
                let mut open = Vec::new();
                heuristics::pack_into(problem, greedy, items, &mut open)
                    .then(|| heuristics::finish(open))
            })
        },
    )
}

impl PortfolioSolver {
    /// The aggregated racing path: every (greedy, ordering) arm packs
    /// multiplicity classes with counts (`packing::aggregate`) instead
    /// of individual items, then the usual exact polish runs.  Arms
    /// race on the same shed-on-deadline worker pool as the per-item
    /// path; arm iteration order breaks cost ties, so the winner is
    /// deterministic.
    fn solve_aggregated(
        &self,
        problem: &MvbpProblem,
        budget: &SolveBudget,
        classes: &[aggregate::ItemClass],
        deadline: Option<Instant>,
        bound_hint: Option<Dollars>,
    ) -> Option<SolveOutcome> {
        let arms: Vec<(Greedy, ItemOrder)> = [Greedy::FirstFit, Greedy::BestFit]
            .iter()
            .flat_map(|&g| ItemOrder::ALL.iter().map(move |&o| (g, o)))
            .collect();
        let results = race_tasks(
            pool_threads(arms.len()),
            arms.len(),
            deadline,
            |i| i,
            |i| {
                let (greedy, order) = arms[i];
                profiling::time_phase(arm_label(greedy, order), || {
                    aggregate::solve_classes(problem, classes, greedy, order)
                })
            },
        );
        let mut best: Option<(Solution, Dollars)> = None;
        for candidate in results.into_iter().flatten() {
            if candidate.validate(problem).is_err() {
                continue;
            }
            let cost = candidate.cost(problem);
            if best.as_ref().map_or(true, |(_, bc)| cost < *bc) {
                best = Some((candidate, cost));
            }
        }
        let (best, proven) = self.polish(problem, budget, deadline, best);
        best.map(|(solution, _)| {
            outcome_with(problem, solution, SolverKind::Portfolio, proven, bound_hint)
        })
    }

    /// Exact polish shared by both racing paths: seeded with the racing
    /// winner, bounded by the remaining deadline and a deterministic
    /// node cap, and only attempted on instances small enough for a
    /// bounded search to improve within budget.
    fn polish(
        &self,
        problem: &MvbpProblem,
        budget: &SolveBudget,
        deadline: Option<Instant>,
        mut best: Option<(Solution, Dollars)>,
    ) -> (Option<(Solution, Dollars)>, bool) {
        let mut proven = false;
        if problem.items.len() <= Self::exact_arm_limit(budget) {
            let bb = BranchAndBound {
                node_budget: budget.node_budget.min(EXACT_ARM_NODE_CAP),
                deadline,
                threads: budget.exact_threads,
                ..Default::default()
            };
            let incumbent = best.as_ref().map(|(s, _)| s.clone());
            let polished =
                profiling::time_phase("arm:exact-polish", || bb.solve_seeded(problem, incumbent));
            if let Some(result) = polished {
                // The racing winner already passed validate in the arm
                // fold; if the polish dropped it, the seed path is
                // broken upstream.
                debug_assert!(
                    !result.seed_dropped,
                    "portfolio seeded the exact polish with an invalid incumbent"
                );
                if result.solution.validate(problem).is_ok() {
                    let cost = result.solution.cost(problem);
                    if best.as_ref().map_or(true, |(_, bc)| cost < *bc) {
                        best = Some((result.solution, cost));
                    }
                    proven = result.proven_optimal;
                }
            }
        }
        (best, proven)
    }
}

impl Solver for PortfolioSolver {
    fn name(&self) -> &'static str {
        "portfolio"
    }

    fn solve(&self, problem: &MvbpProblem, budget: &SolveBudget) -> Option<SolveOutcome> {
        self.solve_with(problem, budget, None)
    }

    fn solve_with(
        &self,
        problem: &MvbpProblem,
        budget: &SolveBudget,
        bound_hint: Option<Dollars>,
    ) -> Option<SolveOutcome> {
        problem.validate().ok()?;
        let n = problem.items.len();
        if n == 0 {
            return Some(outcome_for(problem, Solution::default(), SolverKind::Portfolio, true));
        }
        let deadline = budget.deadline();
        if self.aggregate {
            // Two gates, folded into the grouping cap so an all-distinct
            // fleet aborts the scan almost immediately: aggregation must
            // pay (≤ n/2 classes, i.e. ≥ 2 items per class on average,
            // see [`aggregate::aggregation_pays`]), and the *class
            // count* must be small enough for unsharded class-level
            // arms — `full_arm_cutoff` plays the same role it does for
            // items.  A 100k-item fleet of 50k duplicated pairs fails
            // the cap and takes the sharded per-item path instead of
            // reintroducing the unbounded full scan sharding exists to
            // prevent.
            let cap = (n / 2).min(self.full_arm_cutoff);
            if let Some(classes) = aggregate::group_classes_capped(problem, cap) {
                debug_assert!(aggregate::aggregation_pays(classes.len(), n));
                return self.solve_aggregated(problem, budget, &classes, deadline, bound_hint);
            }
        }
        let sharded = n > self.full_arm_cutoff;
        // Sharded mode drops the FewestChoices ordering: constrained-
        // first placement matters while bins are few, and two orderings
        // halve the total scan work at scale.
        let order_pool: &[ItemOrder] = if sharded {
            &[ItemOrder::HardestFirst, ItemOrder::SumDecreasing]
        } else {
            &ItemOrder::ALL
        };
        let orders: Vec<Vec<usize>> = order_pool.iter().map(|o| o.order(problem)).collect();
        let arms: Vec<(Greedy, usize)> = [Greedy::FirstFit, Greedy::BestFit]
            .iter()
            .flat_map(|&g| (0..orders.len()).map(move |o| (g, o)))
            .collect();

        let shard = if sharded { self.shard_size.max(1) } else { n };
        let mut tasks: Vec<(usize, Greedy, &[usize])> = Vec::new();
        for (a, &(greedy, o)) in arms.iter().enumerate() {
            for chunk in orders[o].chunks(shard) {
                tasks.push((a, greedy, chunk));
            }
        }
        let results = run_tasks(problem, &tasks, deadline);

        // Reassemble each arm's shards and keep the cheapest clean
        // packing.  Arm iteration order (not thread timing) breaks
        // ties, so the winner is deterministic.
        let mut best: Option<(Solution, Dollars)> = None;
        for a in 0..arms.len() {
            let mut bins = Vec::new();
            let mut complete = true;
            for (task, result) in tasks.iter().zip(&results) {
                if task.0 != a {
                    continue;
                }
                match result {
                    Some(s) => bins.extend(s.bins.iter().cloned()),
                    None => {
                        complete = false;
                        break;
                    }
                }
            }
            if !complete {
                continue;
            }
            let candidate = Solution { bins };
            if candidate.validate(problem).is_err() {
                continue;
            }
            let cost = candidate.cost(problem);
            if best.as_ref().map_or(true, |(_, bc)| cost < *bc) {
                best = Some((candidate, cost));
            }
        }

        // Exact polish: seeded with the racing winner, bounded by the
        // remaining deadline and a deterministic node cap.
        let (best, proven) = self.polish(problem, budget, deadline, best);
        best.map(|(solution, _)| {
            outcome_with(problem, solution, SolverKind::Portfolio, proven, bound_hint)
        })
    }
}

/// Which solver the manager routes an allocation through — the CLI's
/// `--solver` values.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum SolverChoice {
    /// Budget-based selection: exact at or below the budget's
    /// `exact_cutoff` items, the portfolio above it.
    #[default]
    Auto,
    Ffd,
    Bfd,
    Exact,
    Portfolio,
}

impl SolverChoice {
    pub const ALL: [SolverChoice; 5] = [
        SolverChoice::Auto,
        SolverChoice::Ffd,
        SolverChoice::Bfd,
        SolverChoice::Exact,
        SolverChoice::Portfolio,
    ];

    /// Solve `problem` under this routing.
    pub fn solve(self, problem: &MvbpProblem, budget: &SolveBudget) -> Option<SolveOutcome> {
        self.solve_with(problem, budget, None)
    }

    /// [`SolverChoice::solve`] with an already-certified lower bound
    /// hint — see [`Solver::solve_with`].
    pub fn solve_with(
        self,
        problem: &MvbpProblem,
        budget: &SolveBudget,
        bound_hint: Option<Dollars>,
    ) -> Option<SolveOutcome> {
        match self {
            SolverChoice::Auto => {
                if problem.items.len() <= budget.exact_cutoff {
                    ExactSolver.solve_with(problem, budget, bound_hint)
                } else {
                    PortfolioSolver::default().solve_with(problem, budget, bound_hint)
                }
            }
            SolverChoice::Ffd => FfdSolver.solve_with(problem, budget, bound_hint),
            SolverChoice::Bfd => BfdSolver.solve_with(problem, budget, bound_hint),
            SolverChoice::Exact => ExactSolver.solve_with(problem, budget, bound_hint),
            SolverChoice::Portfolio => {
                PortfolioSolver::default().solve_with(problem, budget, bound_hint)
            }
        }
    }
}

impl std::fmt::Display for SolverChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SolverChoice::Auto => "auto",
            SolverChoice::Ffd => "ffd",
            SolverChoice::Bfd => "bfd",
            SolverChoice::Exact => "exact",
            SolverChoice::Portfolio => "portfolio",
        })
    }
}

impl std::str::FromStr for SolverChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(SolverChoice::Auto),
            "ffd" | "first-fit" => Ok(SolverChoice::Ffd),
            "bfd" | "best-fit" => Ok(SolverChoice::Bfd),
            "exact" | "bb" | "exact-bb" => Ok(SolverChoice::Exact),
            "portfolio" => Ok(SolverChoice::Portfolio),
            other => Err(format!(
                "unknown solver {other:?} (expected auto, ffd, bfd, exact, or portfolio)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::problem::test_fixtures::small_problem;
    use crate::packing::problem::{BinType, Item};
    use crate::types::ResourceVec;

    fn all_solvers() -> Vec<Box<dyn Solver>> {
        vec![
            Box::new(FfdSolver),
            Box::new(BfdSolver),
            Box::new(ExactSolver),
            Box::new(PortfolioSolver::default()),
        ]
    }

    #[test]
    fn hedging_rescues_a_straggling_remote_claim() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // One dispatcher whose every claim straggles forever: the
        // remote closure only yields once the hedger has filled its
        // slots.  Slow local claims guarantee the dispatcher gets a
        // chunk before the cursor drains; the tiny hedge floor makes
        // the watcher fire fast.
        let hedges = AtomicUsize::new(0);
        let results = race_chunks_remote(
            1,
            1,
            4,
            2,
            Some(HedgeCfg {
                after: Duration::from_millis(10),
                factor: 2.0,
                on_hedge: &|| {
                    hedges.fetch_add(1, Ordering::Relaxed);
                },
            }),
            |_w, _range, cancelled: &dyn Fn() -> bool| {
                while !cancelled() {
                    std::thread::sleep(Duration::from_millis(2));
                }
                RemoteOutcome::Abandoned
            },
            |i| {
                std::thread::sleep(Duration::from_millis(20));
                Some(i * 10)
            },
        );
        assert_eq!(results, vec![Some(0), Some(10), Some(20), Some(30)]);
        assert!(hedges.load(Ordering::Relaxed) >= 1, "the straggler was never hedged");
    }

    #[test]
    fn failed_remote_claims_degrade_to_local_results() {
        // A dispatcher that always fails must still yield the local
        // results for every task, with no hedging configured.
        let results = race_chunks_remote(
            2,
            1,
            7,
            3,
            None,
            |_w, _range, _cancelled| RemoteOutcome::Failed::<Option<usize>>,
            |i| Some(Some(i)),
        );
        assert_eq!(results, (0..7).map(|i| Some(Some(i))).collect::<Vec<_>>());
    }

    #[test]
    fn every_solver_certifies_the_small_problem() {
        let p = small_problem();
        let budget = SolveBudget::default();
        for solver in all_solvers() {
            let out = solver
                .solve(&p, &budget)
                .unwrap_or_else(|| panic!("{} must solve", solver.name()));
            out.solution
                .validate(&p)
                .unwrap_or_else(|e| panic!("{}: {e}", solver.name()));
            assert!(
                out.lower_bound <= out.cost,
                "{}: bound {} > cost {}",
                solver.name(),
                out.lower_bound,
                out.cost
            );
            assert!(out.gap().is_finite() && (0.0..=1.0).contains(&out.gap()));
        }
    }

    #[test]
    fn exact_solver_proves_and_closes_the_gap() {
        let p = small_problem();
        let out = ExactSolver.solve(&p, &SolveBudget::default()).unwrap();
        assert!(out.proven_optimal);
        assert_eq!(out.lower_bound, out.cost);
        assert_eq!(out.gap(), 0.0);
        assert_eq!(out.cost, Dollars::from_f64(1.8));
    }

    #[test]
    fn portfolio_never_trails_its_own_arms() {
        let p = small_problem();
        let budget = SolveBudget::default();
        let ffd = FfdSolver.solve(&p, &budget).unwrap();
        let bfd = BfdSolver.solve(&p, &budget).unwrap();
        let portfolio = PortfolioSolver::default().solve(&p, &budget).unwrap();
        assert!(portfolio.cost <= ffd.cost.min(bfd.cost));
        assert_eq!(portfolio.solver, SolverKind::Portfolio);
    }

    #[test]
    fn sharded_mode_still_packs_clean() {
        // Force sharding on a 12-item instance: shards of 3 items each
        // open their own bins; the concatenation must still validate
        // and stay within the certified bound.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[10.0]),
            }],
            items: (0..12)
                .map(|i| Item {
                    id: format!("i{i}"),
                    choices: vec![ResourceVec::from_slice(&[3.0 + (i % 3) as f64])],
                })
                .collect(),
            choice_costs: vec![],
        };
        // aggregate off: the weights repeat (three classes), and the
        // point here is exercising the *sharded per-item* path.
        let sharded = PortfolioSolver { full_arm_cutoff: 4, shard_size: 3, aggregate: false };
        let out = sharded.solve(&p, &SolveBudget::default()).unwrap();
        out.solution.validate(&p).unwrap();
        assert!(out.lower_bound <= out.cost);
        assert!(out.gap().is_finite());
    }

    /// `copies` copies of every `small_problem` item — a
    /// high-multiplicity fleet in miniature.
    fn replicated_small(copies: usize) -> MvbpProblem {
        let base = small_problem();
        let mut items = Vec::new();
        for (t, item) in base.items.iter().enumerate() {
            for i in 0..copies {
                items.push(Item {
                    id: format!("c{t}-{i}"),
                    choices: item.choices.clone(),
                });
            }
        }
        MvbpProblem {
            dims: base.dims,
            bin_types: base.bin_types.clone(),
            items,
            choice_costs: vec![],
        }
    }

    #[test]
    fn aggregated_portfolio_matches_per_item_portfolio() {
        // Aggregation pays (3 classes × 40 members); with the exact
        // polish disabled (cutoff 0) both paths are pure racing arms
        // and must agree exactly.
        let p = replicated_small(40);
        let budget = SolveBudget { exact_cutoff: 0, ..Default::default() };
        let agg = PortfolioSolver::default().solve(&p, &budget).unwrap();
        let per_item = PortfolioSolver { aggregate: false, ..Default::default() }
            .solve(&p, &budget)
            .unwrap();
        agg.solution.validate(&p).unwrap();
        per_item.solution.validate(&p).unwrap();
        assert_eq!(agg.cost, per_item.cost);
        assert_eq!(
            agg.solution.bins_per_type(&p),
            per_item.solution.bins_per_type(&p)
        );
        assert!(agg.lower_bound <= agg.cost);
        assert!(agg.gap().is_finite());
    }

    #[test]
    fn aggregated_portfolio_is_deterministic_and_certified() {
        let p = replicated_small(25);
        let budget = SolveBudget::default();
        let a = PortfolioSolver::default().solve(&p, &budget).unwrap();
        let b = PortfolioSolver::default().solve(&p, &budget).unwrap();
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.lower_bound, b.lower_bound);
        assert_eq!(a.solver, SolverKind::Portfolio);
    }

    #[test]
    fn run_tasks_sheds_only_later_arms_on_expired_deadline() {
        let p = small_problem();
        let order = ItemOrder::HardestFirst.order(&p);
        let tasks: Vec<(usize, Greedy, &[usize])> = vec![
            (0, Greedy::FirstFit, order.as_slice()),
            (1, Greedy::BestFit, order.as_slice()),
        ];
        let expired = Some(Instant::now() - std::time::Duration::from_millis(10));
        let results = run_tasks(&p, &tasks, expired);
        assert!(results[0].is_some(), "the first arm must always complete");
        assert!(results[1].is_none(), "later arms shed once the deadline passes");
    }

    #[test]
    fn tight_deadline_degrades_to_fewer_arms_not_failure() {
        // A 1 ms budget can shed every arm but the first; the portfolio
        // must still return a valid certified solution.
        let p = small_problem();
        let budget = SolveBudget { time_ms: 1, ..Default::default() };
        let out = PortfolioSolver::default().solve(&p, &budget).unwrap();
        out.solution.validate(&p).unwrap();
        assert!(out.lower_bound <= out.cost);
        assert!(out.gap().is_finite());
    }

    #[test]
    fn portfolio_is_deterministic() {
        let p = small_problem();
        let budget = SolveBudget::default();
        let a = PortfolioSolver::default().solve(&p, &budget).unwrap();
        let b = PortfolioSolver::default().solve(&p, &budget).unwrap();
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.lower_bound, b.lower_bound);
    }

    #[test]
    fn infeasible_item_fails_every_solver() {
        let mut p = small_problem();
        p.items.push(Item {
            id: "huge".into(),
            choices: vec![ResourceVec::from_slice(&[100.0, 0.0])],
        });
        let budget = SolveBudget::default();
        for solver in all_solvers() {
            assert!(solver.solve(&p, &budget).is_none(), "{}", solver.name());
        }
    }

    #[test]
    fn empty_problem_is_a_zero_cost_certificate() {
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[1.0]),
            }],
            items: vec![],
            choice_costs: vec![],
        };
        let out = PortfolioSolver::default().solve(&p, &SolveBudget::default()).unwrap();
        assert_eq!(out.cost, Dollars::ZERO);
        assert_eq!(out.lower_bound, Dollars::ZERO);
        assert!(out.proven_optimal);
        assert_eq!(certified_lower_bound(&p), Dollars::ZERO);
    }

    #[test]
    fn lower_bound_dominates_naive_and_respects_optimum() {
        // Three items of 6 into cap-10 bins of cost $1: the optimum is
        // 3 bins (L2 sees it); the naive sum bound would say 2.
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[10.0]),
            }],
            items: (0..3)
                .map(|i| Item {
                    id: format!("i{i}"),
                    choices: vec![ResourceVec::from_slice(&[6.0])],
                })
                .collect(),
            choice_costs: vec![],
        };
        let lb = certified_lower_bound(&p);
        assert_eq!(lb, Dollars::from_f64(3.0));
        let out = ExactSolver.solve(&p, &SolveBudget::default()).unwrap();
        assert_eq!(out.cost, Dollars::from_f64(3.0));
        assert!(lb <= out.cost);
    }

    #[test]
    fn auto_routes_by_budget_cutoff() {
        let p = small_problem(); // 3 items
        let tight = SolveBudget { exact_cutoff: 2, ..Default::default() };
        let roomy = SolveBudget { exact_cutoff: 24, ..Default::default() };
        // Above the cutoff: portfolio; at/below: exact.  Both must agree
        // on the optimum here (the portfolio's exact arm closes it).
        let via_portfolio = SolverChoice::Auto.solve(&p, &tight).unwrap();
        let via_exact = SolverChoice::Auto.solve(&p, &roomy).unwrap();
        assert_eq!(via_portfolio.solver, SolverKind::Portfolio);
        assert_eq!(via_exact.solver, SolverKind::Exact);
        assert_eq!(via_portfolio.cost, via_exact.cost);
    }

    #[test]
    fn solver_choice_parse_round_trip() {
        for c in SolverChoice::ALL {
            assert_eq!(c.to_string().parse::<SolverChoice>().unwrap(), c);
        }
        assert_eq!("best-fit".parse::<SolverChoice>().unwrap(), SolverChoice::Bfd);
        assert!("simplex".parse::<SolverChoice>().is_err());
    }
}
