//! Arc-flow machinery (Brandão & Pedroso, 2016) for the 1-D projection.
//!
//! VPSolver's exact method builds a DAG over discretized capacity states
//! whose min-cost integer flow equals the optimal packing.  This module
//! reproduces the parts of that machinery the rest of the crate uses:
//!
//! * [`ArcFlowGraph`] — the state graph for one bin type's 1-D
//!   projection, including the *graph compression* step (merging
//!   equivalent states), with before/after size stats (Ablation B);
//! * [`l2_lower_bound`] — the Martello-Toth L2 bound on bin count,
//!   evaluated over the graph's discretized weights (a valid cost bound
//!   for any dimension projection);
//! * [`solve_1d_exact`] — bitmask-DP exact 1-D single-type packing used
//!   to cross-validate the branch-and-bound solver in tests.
//!
//! The full multi-dimensional exact search lives in [`super::exact`];
//! DESIGN.md documents this substitution (VPSolver's ILP backend → native
//! B&B) and why it preserves the paper's behaviour at its problem sizes.

use std::collections::BTreeSet;

/// Arc in the state graph: consume item `item` going from capacity state
/// `from` to `to` (`item == usize::MAX` marks a loss arc to the sink).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Arc {
    pub from: u32,
    pub to: u32,
    pub item: usize,
}

/// The arc-flow state graph of a 1-D bin-packing projection.
#[derive(Clone, Debug)]
pub struct ArcFlowGraph {
    /// Bin capacity in grid units.
    pub capacity: u32,
    /// Item weights in grid units (sorted decreasing, as in VPSolver).
    pub weights: Vec<u32>,
    /// Nodes = reachable capacity states (always contains 0).
    pub nodes: Vec<u32>,
    pub arcs: Vec<Arc>,
    /// Node/arc counts before the compression step.
    pub uncompressed_nodes: usize,
    pub uncompressed_arcs: usize,
}

/// Discretize fractional weights/capacity onto an integer grid.
///
/// Weights round *up* and capacity rounds *down*, so the discretized
/// problem is a restriction: any packing valid on the grid is valid in
/// the original (the bound direction VPSolver relies on).
pub fn discretize(weights: &[f64], capacity: f64, grid: u32) -> (Vec<u32>, u32) {
    debug_assert!(grid > 0);
    let cap = capacity.max(0.0);
    let w = weights
        .iter()
        .map(|&x| {
            let frac = if cap > 0.0 { x / cap } else { 1.0 };
            ((frac * grid as f64) - 1e-9).ceil().max(0.0) as u32
        })
        .collect();
    (w, grid)
}

/// Discretize in the *relaxation* direction: weights round **down** and
/// the capacity maps exactly onto the grid, so every packing valid in
/// the original stays valid on the grid.  This is the rounding a lower
/// bound needs — the opposite of [`discretize`], whose restriction
/// direction serves exact solving.  Weights above capacity clamp to the
/// full grid (such items cannot fit anyway; the clamp keeps the bound
/// finite instead of overflowing the grid).
pub fn discretize_relaxed(weights: &[f64], capacity: f64, grid: u32) -> (Vec<u32>, u32) {
    debug_assert!(grid > 0);
    let cap = capacity.max(0.0);
    let w = weights
        .iter()
        .map(|&x| {
            let frac = if cap > 0.0 { (x / cap).clamp(0.0, 1.0) } else { 1.0 };
            ((frac * grid as f64) + 1e-9).floor() as u32
        })
        .collect();
    (w, grid)
}

impl ArcFlowGraph {
    /// Build the graph for `weights` (grid units) into bins of `capacity`.
    ///
    /// Construction follows VPSolver: items are processed in decreasing
    /// weight order; level `k` states are capacities reachable using only
    /// the first `k` item classes, which keeps the graph acyclic and
    /// avoids symmetric paths.  Compression then merges states with equal
    /// *suffix behaviour*: each state is relabelled to the largest
    /// capacity still reachable from it using the remaining items
    /// (VPSolver's "step-3" main compression), collapsing states that
    /// admit identical completions.
    pub fn build(weights: &[u32], capacity: u32) -> ArcFlowGraph {
        let mut sorted: Vec<u32> = weights.iter().copied().filter(|w| *w > 0).collect();
        sorted.sort_unstable_by(|a, b| b.cmp(a));

        // Forward reachability, level by level (uncompressed graph).
        let mut reachable: BTreeSet<u32> = BTreeSet::new();
        reachable.insert(0);
        let mut raw_arcs: Vec<Arc> = Vec::new();
        for (idx, &w) in sorted.iter().enumerate() {
            // Snapshot: arcs for item idx leave states reachable via items < idx.
            let current: Vec<u32> = reachable.iter().copied().collect();
            for &u in &current {
                if u + w <= capacity {
                    raw_arcs.push(Arc { from: u, to: u + w, item: idx });
                    reachable.insert(u + w);
                }
            }
        }
        let uncompressed_nodes = reachable.len() + 1; // + sink
        let uncompressed_arcs = raw_arcs.len() + reachable.len(); // + loss arcs

        // Compression: relabel each state u to phi(u) = capacity minus the
        // largest residual fill achievable from u (i.e. push every state as
        // far right as its suffix completions allow).  States with equal
        // phi are merged.  phi is computed by a subset-sum DP per level.
        //
        // For our instance sizes a single global subset-sum suffices: any
        // state u maps to the largest reachable total <= capacity that is
        // >= u.  (This is VPSolver's final x-relabelling specialized to
        // one dimension.)
        let sums: BTreeSet<u32> = reachable.iter().copied().collect();
        let phi = |u: u32| -> u32 {
            // Largest reachable sum <= u stays; this collapses unreachable
            // gaps between states.
            *sums.range(..=u).next_back().unwrap_or(&0)
        };

        let mut node_set: BTreeSet<u32> = BTreeSet::new();
        let mut arc_set: BTreeSet<(u32, u32, usize)> = BTreeSet::new();
        node_set.insert(0);
        for a in &raw_arcs {
            let (f, t) = (phi(a.from), phi(a.to));
            if f != t {
                node_set.insert(f);
                node_set.insert(t);
                arc_set.insert((f, t, a.item));
            }
        }
        // Loss arcs: every node flows to the sink (= capacity label).
        // (Iterating node_set directly is fine — the loop only inserts
        // into arc_set, so the former `node_set.clone()` was a needless
        // allocation per graph build.)
        let sink = capacity;
        node_set.insert(sink);
        for &n in node_set.iter() {
            if n != sink {
                arc_set.insert((n, sink, usize::MAX));
            }
        }

        ArcFlowGraph {
            capacity,
            weights: sorted,
            nodes: node_set.into_iter().collect(),
            arcs: arc_set
                .into_iter()
                .map(|(from, to, item)| Arc { from, to, item })
                .collect(),
            uncompressed_nodes,
            uncompressed_arcs,
        }
    }

    /// Compression ratio (< 1.0 means the step shrank the graph).
    pub fn compression_ratio(&self) -> f64 {
        if self.uncompressed_arcs == 0 {
            return 1.0;
        }
        self.arcs.len() as f64 / self.uncompressed_arcs as f64
    }
}

/// Martello-Toth L2 lower bound on the number of unit-cost bins needed
/// for 1-D weights (grid units).  Strictly dominates ceil(sum/cap).
///
/// Evaluated in `O(n log n)` via sorted weights + prefix sums (one
/// binary search per distinct threshold) — this runs on every certified
/// solve, so the naive `O(thresholds x n)` scan would dominate large
/// heuristic solves.
pub fn l2_lower_bound(weights: &[u32], capacity: u32) -> u32 {
    if capacity == 0 {
        return if weights.iter().any(|&w| w > 0) { u32::MAX } else { 0 };
    }
    let mut sorted: Vec<u32> = weights.to_vec();
    sorted.sort_unstable();
    let n = sorted.len();
    let mut prefix = vec![0u64; n + 1];
    for (i, &w) in sorted.iter().enumerate() {
        prefix[i + 1] = prefix[i] + w as u64;
    }
    let mut best = prefix[n].div_ceil(capacity as u64) as u32;
    let half = capacity / 2;
    // First index with weight > x / >= x respectively.
    let above = |x: u32| sorted.partition_point(|&w| w <= x);
    let at_or_above = |x: u32| sorted.partition_point(|&w| w < x);
    let i_half = above(half);
    let mut thresholds = vec![0u32];
    thresholds.extend(sorted.iter().copied().filter(|&w| w <= half));
    thresholds.dedup();
    for k in thresholds {
        // Large items (> cap - k) each need their own bin; medium items
        // (cap/2 < w <= cap - k) pair with at most the small leftovers.
        // k <= cap/2 guarantees cap - k >= cap/2, so i_ck >= i_half.
        let i_ck = above(capacity - k);
        let n1 = (n - i_ck) as u32;
        let n2 = (i_ck - i_half) as u32;
        let s_small = prefix[i_half] - prefix[at_or_above(k)];
        let med_cnt = (i_ck - i_half) as u64;
        let med_sum = prefix[i_ck] - prefix[i_half];
        let cap2 = med_cnt * capacity as u64 - med_sum;
        let extra = s_small.saturating_sub(cap2).div_ceil(capacity as u64) as u32;
        best = best.max(n1 + n2 + extra);
    }
    best
}

/// Exact minimum bin count for 1-D single-type packing via subset DP.
///
/// `O(2^n)` states with an `O(2^n)` precomputed "fits in one bin" table;
/// guarded to `n <= 20`.  Used to cross-validate the B&B solver.
pub fn solve_1d_exact(weights: &[u32], capacity: u32) -> Option<u32> {
    let n = weights.len();
    assert!(n <= 20, "solve_1d_exact is a test oracle; n must be <= 20");
    if weights.iter().any(|&w| w > capacity) {
        return None;
    }
    if n == 0 {
        return Some(0);
    }
    let full = 1usize << n;
    // subset weight sums
    let mut sum = vec![0u64; full];
    for mask in 1..full {
        let lsb = mask.trailing_zeros() as usize;
        sum[mask] = sum[mask & (mask - 1)] + weights[lsb] as u64;
    }
    let mut bins = vec![u32::MAX; full];
    bins[0] = 0;
    for mask in 1..full {
        // Enumerate submasks that fit in one bin and contain the lowest
        // set bit (canonical: the lowest unpacked item goes in this bin).
        let low = mask.trailing_zeros() as usize;
        let rest = mask & !(1 << low);
        let mut sub = rest;
        loop {
            let cand = sub | (1 << low);
            if sum[cand] <= capacity as u64 && bins[mask & !cand] != u32::MAX {
                bins[mask] = bins[mask].min(bins[mask & !cand] + 1);
            }
            if sub == 0 {
                break;
            }
            sub = (sub - 1) & rest;
        }
    }
    Some(bins[full - 1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discretize_rounds_safely() {
        let (w, cap) = discretize(&[0.333, 0.5], 1.0, 100);
        assert_eq!(cap, 100);
        assert_eq!(w, vec![34, 50]); // weights round up
        let (w2, _) = discretize(&[0.5], 1.0, 2);
        assert_eq!(w2, vec![1]); // exact boundary does not over-round
    }

    #[test]
    fn discretize_relaxed_rounds_down_and_clamps() {
        let (w, cap) = discretize_relaxed(&[0.333, 0.5, 1.7], 1.0, 100);
        assert_eq!(cap, 100);
        // Weights floor (33, not 34); over-capacity clamps to the grid.
        assert_eq!(w, vec![33, 50, 100]);
        // Relaxed never exceeds the restriction-direction rounding, so a
        // bound on the relaxed grid is a bound on the original.
        let (up, _) = discretize(&[0.333, 0.5], 1.0, 100);
        let (down, _) = discretize_relaxed(&[0.333, 0.5], 1.0, 100);
        assert!(down.iter().zip(&up).all(|(d, u)| d <= u));
    }

    #[test]
    fn graph_counts_small_example() {
        // weights 3,3,2 cap 5: states {0,3,5(=3+2),2} ...
        let g = ArcFlowGraph::build(&[3, 3, 2], 5);
        assert!(g.nodes.contains(&0));
        assert!(g.nodes.contains(&5));
        // Every non-sink node has a loss arc.
        let loss = g.arcs.iter().filter(|a| a.item == usize::MAX).count();
        assert_eq!(loss, g.nodes.len() - 1);
        // Compression never grows the graph.
        assert!(g.arcs.len() <= g.uncompressed_arcs);
        assert!(g.nodes.len() <= g.uncompressed_nodes);
    }

    #[test]
    fn compression_merges_gap_states() {
        // One item of 7 into cap 10: uncompressed states {0,7}+sink.
        let g = ArcFlowGraph::build(&[7], 10);
        assert!(g.compression_ratio() <= 1.0);
        let item_arcs: Vec<_> = g.arcs.iter().filter(|a| a.item != usize::MAX).collect();
        assert_eq!(item_arcs.len(), 1);
        assert_eq!(item_arcs[0].from, 0);
    }

    #[test]
    fn l2_bound_dominates_naive() {
        // Three items of 6 into cap 10: naive ceil(18/10)=2, L2 = 3.
        assert_eq!(l2_lower_bound(&[6, 6, 6], 10), 3);
        // Perfect fit: 5+5 -> 1 bin.
        assert_eq!(l2_lower_bound(&[5, 5], 10), 1);
        assert_eq!(l2_lower_bound(&[], 10), 0);
    }

    #[test]
    fn l2_zero_capacity() {
        assert_eq!(l2_lower_bound(&[1], 0), u32::MAX);
        assert_eq!(l2_lower_bound(&[], 0), 0);
    }

    /// The prefix-sum evaluation must agree with the definitional
    /// per-threshold scan on random inputs.
    #[test]
    fn l2_prefix_sum_matches_naive_reference() {
        fn naive(weights: &[u32], capacity: u32) -> u32 {
            let total: u64 = weights.iter().map(|&w| w as u64).sum();
            let mut best = total.div_ceil(capacity as u64) as u32;
            let mut thresholds: Vec<u32> =
                weights.iter().copied().filter(|&w| w <= capacity / 2).collect();
            thresholds.push(0);
            thresholds.sort_unstable();
            thresholds.dedup();
            for k in thresholds {
                let n1 = weights.iter().filter(|&&w| w > capacity - k).count() as u32;
                let n2 = weights
                    .iter()
                    .filter(|&&w| w > capacity / 2 && w <= capacity - k)
                    .count() as u32;
                let s_small: u64 = weights
                    .iter()
                    .filter(|&&w| w >= k && w <= capacity / 2)
                    .map(|&w| w as u64)
                    .sum();
                let cap2: u64 = weights
                    .iter()
                    .filter(|&&w| w > capacity / 2 && w <= capacity - k)
                    .map(|&w| (capacity - w) as u64)
                    .sum();
                let extra = s_small.saturating_sub(cap2).div_ceil(capacity as u64) as u32;
                best = best.max(n1 + n2 + extra);
            }
            best
        }
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        for case in 0..300 {
            let cap = 1 + rng.below(64) as u32;
            let n = rng.below(24) as usize;
            let weights: Vec<u32> =
                (0..n).map(|_| 1 + rng.below(cap as u64) as u32).collect();
            assert_eq!(
                l2_lower_bound(&weights, cap),
                naive(&weights, cap),
                "case {case}: weights {weights:?} cap {cap}"
            );
        }
    }

    #[test]
    fn exact_1d_known_instances() {
        assert_eq!(solve_1d_exact(&[], 10), Some(0));
        assert_eq!(solve_1d_exact(&[5, 5, 5], 10), Some(2));
        assert_eq!(solve_1d_exact(&[6, 6, 6], 10), Some(3));
        assert_eq!(solve_1d_exact(&[4, 4, 4, 6, 6], 12), Some(2));
        assert_eq!(solve_1d_exact(&[11], 10), None);
    }

    #[test]
    fn l2_is_a_valid_bound_for_exact() {
        let cases: &[(&[u32], u32)] = &[
            (&[3, 3, 3, 3], 7),
            (&[5, 4, 3, 2, 1], 8),
            (&[9, 1, 9, 1, 9, 1], 10),
        ];
        for (weights, cap) in cases {
            let exact = solve_1d_exact(weights, *cap).unwrap();
            let bound = l2_lower_bound(weights, *cap);
            assert!(bound <= exact, "L2 {bound} > exact {exact} for {weights:?}");
        }
    }
}
