//! Residual index: near-logarithmic open-bin lookup for the placement
//! engine.
//!
//! `pack_into` used to scan every open bin per item — O(items × bins ×
//! choices) — which dominates large solves.  [`ResidualIndex`] is a
//! segment tree over the open-bin list whose internal nodes hold the
//! *element-wise maximum* residual of their subtree.  The pruning rule
//! is a necessary condition: if some dimension's subtree-max is below a
//! requirement, **no** bin in that subtree fits it, so the whole
//! subtree is skipped.  At the leaves the comparison is exactly
//! [`ResourceVec::fits`]'s (same epsilon), so queries return precisely
//! the bins a linear scan would have found, in the same order — the
//! index accelerates first-fit/best-fit without changing either
//! heuristic's result.
//!
//! * [`ResidualIndex::first_fit_any`] descends leftmost-first and
//!   returns the lowest-index bin fitting any choice (with the first
//!   fitting choice), mirroring the first-fit scan.
//! * [`ResidualIndex::may_fit`] collects, in increasing bin order, the
//!   bins fitting at least one choice — the best-fit scorer then ranks
//!   only genuine candidates instead of every open bin.
//!
//! Worst case (every bin fits) degenerates to the linear scan plus an
//! O(log bins) constant; in packing practice most open bins are nearly
//! full and are pruned in bulk near the root.

// FIT_EPS is the shared `fits` tolerance — the index must make
// identical fit decisions to `ResourceVec::fits` or first-fit results
// would drift from the linear scan's.
use crate::types::{FIT_EPS, ResourceVec};

/// Segment tree over open-bin residuals (element-wise max per node).
pub(crate) struct ResidualIndex {
    dims: usize,
    /// Leaves in use (= open bins tracked).
    len: usize,
    /// Power-of-two leaf capacity of the current tree.
    cap: usize,
    /// Flat 1-based heap: node `i` occupies
    /// `nodes[i * dims .. (i + 1) * dims]`; leaves start at `cap`.
    /// Unused leaves hold `-inf` so no requirement ever matches them.
    nodes: Vec<f64>,
}

impl ResidualIndex {
    /// Build over the residuals of `open` (possibly empty).
    pub(crate) fn new(dims: usize, residuals: &[&ResourceVec]) -> ResidualIndex {
        let cap = residuals.len().next_power_of_two().max(1);
        let mut index = ResidualIndex {
            dims,
            len: residuals.len(),
            cap,
            nodes: vec![f64::NEG_INFINITY; 2 * cap * dims.max(1)],
        };
        for (i, r) in residuals.iter().enumerate() {
            index.write_leaf(i, r);
        }
        for node in (1..cap).rev() {
            index.pull(node);
        }
        index
    }

    fn write_leaf(&mut self, i: usize, residual: &ResourceVec) {
        debug_assert_eq!(residual.dims(), self.dims);
        let at = (self.cap + i) * self.dims;
        self.nodes[at..at + self.dims].copy_from_slice(&residual.0);
    }

    /// Recompute one internal node from its children.
    fn pull(&mut self, node: usize) {
        let (l, r) = (2 * node * self.dims, (2 * node + 1) * self.dims);
        for d in 0..self.dims {
            self.nodes[node * self.dims + d] = self.nodes[l + d].max(self.nodes[r + d]);
        }
    }

    /// A subtree can contain a fitting bin only if every dimension's
    /// max residual admits the requirement.
    fn admits(&self, node: usize, req: &ResourceVec) -> bool {
        let at = node * self.dims;
        req.0
            .iter()
            .zip(&self.nodes[at..at + self.dims])
            .all(|(need, max)| *need <= max + FIT_EPS)
    }

    fn admits_any(&self, node: usize, choices: &[ResourceVec]) -> bool {
        choices.iter().any(|req| self.admits(node, req))
    }

    /// Track a newly opened bin.  Amortized O(log bins): capacity
    /// doubles by rebuilding from the stored leaves.
    pub(crate) fn push(&mut self, residual: &ResourceVec) {
        if self.len == self.cap {
            let old_cap = self.cap;
            let old = std::mem::replace(
                &mut self.nodes,
                vec![f64::NEG_INFINITY; 4 * old_cap * self.dims.max(1)],
            );
            self.cap = 2 * old_cap;
            let leaf_base = old_cap * self.dims;
            let dst_base = self.cap * self.dims;
            let live = self.len * self.dims;
            self.nodes[dst_base..dst_base + live]
                .copy_from_slice(&old[leaf_base..leaf_base + live]);
            for node in (1..self.cap).rev() {
                self.pull(node);
            }
        }
        self.write_leaf(self.len, residual);
        let mut node = (self.cap + self.len) / 2;
        while node >= 1 {
            self.pull(node);
            node /= 2;
        }
        self.len += 1;
    }

    /// Refresh bin `i`'s residual after a placement.
    pub(crate) fn update(&mut self, i: usize, residual: &ResourceVec) {
        debug_assert!(i < self.len);
        self.write_leaf(i, residual);
        let mut node = (self.cap + i) / 2;
        while node >= 1 {
            self.pull(node);
            node /= 2;
        }
    }

    /// Lowest-index bin where any choice fits, with the first fitting
    /// choice — exactly the pair the first-fit linear scan selects.
    pub(crate) fn first_fit_any(&self, choices: &[ResourceVec]) -> Option<(usize, usize)> {
        if self.len == 0 || choices.is_empty() {
            return None;
        }
        self.descend_first(1, choices)
    }

    fn descend_first(&self, node: usize, choices: &[ResourceVec]) -> Option<(usize, usize)> {
        if !self.admits_any(node, choices) {
            return None;
        }
        if node >= self.cap {
            let bin = node - self.cap;
            if bin >= self.len {
                return None;
            }
            // Leaf values are the exact residual, so `admits` here *is*
            // the fits test: pick the first passing choice.
            return choices
                .iter()
                .position(|req| self.admits(node, req))
                .map(|c| (bin, c));
        }
        self.descend_first(2 * node, choices)
            .or_else(|| self.descend_first(2 * node + 1, choices))
    }

    /// Collect, in increasing bin order, every bin fitting at least one
    /// choice into `out` (cleared first).
    pub(crate) fn may_fit(&self, choices: &[ResourceVec], out: &mut Vec<usize>) {
        out.clear();
        if self.len == 0 || choices.is_empty() {
            return;
        }
        self.collect(1, choices, out);
    }

    fn collect(&self, node: usize, choices: &[ResourceVec], out: &mut Vec<usize>) {
        if !self.admits_any(node, choices) {
            return;
        }
        if node >= self.cap {
            let bin = node - self.cap;
            if bin < self.len {
                out.push(bin);
            }
            return;
        }
        self.collect(2 * node, choices, out);
        self.collect(2 * node + 1, choices, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rv(v: &[f64]) -> ResourceVec {
        ResourceVec::from_slice(v)
    }

    #[test]
    fn first_fit_matches_linear_scan() {
        let bins = [rv(&[1.0, 1.0]), rv(&[5.0, 0.5]), rv(&[4.0, 4.0]), rv(&[9.0, 9.0])];
        let refs: Vec<&ResourceVec> = bins.iter().collect();
        let index = ResidualIndex::new(2, &refs);
        // Needs (3, 2): bins 0 and 1 fail, bin 2 is the first fit.
        assert_eq!(index.first_fit_any(&[rv(&[3.0, 2.0])]), Some((2, 0)));
        // Choice order: choice 0 fits nothing before bin 3, choice 1
        // fits bin 1 — first *bin* wins, with its first fitting choice.
        assert_eq!(
            index.first_fit_any(&[rv(&[6.0, 6.0]), rv(&[5.0, 0.2])]),
            Some((1, 1))
        );
        assert_eq!(index.first_fit_any(&[rv(&[20.0, 0.0])]), None);
    }

    #[test]
    fn updates_and_pushes_keep_queries_exact() {
        let bins = [rv(&[4.0, 4.0])];
        let refs: Vec<&ResourceVec> = bins.iter().collect();
        let mut index = ResidualIndex::new(2, &refs);
        assert_eq!(index.first_fit_any(&[rv(&[3.0, 3.0])]), Some((0, 0)));
        index.update(0, &rv(&[1.0, 1.0]));
        assert_eq!(index.first_fit_any(&[rv(&[3.0, 3.0])]), None);
        // Grow far past the initial power-of-two capacity.
        for i in 0..20 {
            index.push(&rv(&[i as f64, i as f64]));
        }
        let mut out = Vec::new();
        index.may_fit(&[rv(&[18.5, 18.5])], &mut out);
        assert_eq!(out, vec![20]); // only the residual (19, 19) bin
        assert_eq!(index.first_fit_any(&[rv(&[2.0, 2.0])]), Some((3, 0)));
    }

    #[test]
    fn may_fit_enumerates_in_bin_order() {
        let bins = [rv(&[2.0]), rv(&[8.0]), rv(&[1.0]), rv(&[8.0]), rv(&[3.0])];
        let refs: Vec<&ResourceVec> = bins.iter().collect();
        let index = ResidualIndex::new(1, &refs);
        let mut out = Vec::new();
        index.may_fit(&[rv(&[2.5])], &mut out);
        assert_eq!(out, vec![1, 3, 4]);
        index.may_fit(&[rv(&[2.5]), rv(&[0.5])], &mut out);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn epsilon_matches_fits_semantics() {
        // A requirement equal to the residual up to float error must
        // pass, exactly like ResourceVec::fits.
        let residual = rv(&[0.3]);
        let refs: Vec<&ResourceVec> = vec![&residual];
        let index = ResidualIndex::new(1, &refs);
        let req = rv(&[0.1 + 0.2]); // 0.30000000000000004
        assert!(req.fits(&residual));
        assert_eq!(index.first_fit_any(&[req]), Some((0, 0)));
    }

    #[test]
    fn empty_index_returns_nothing() {
        let index = ResidualIndex::new(2, &[]);
        assert_eq!(index.first_fit_any(&[rv(&[0.0, 0.0])]), None);
        let mut out = vec![7];
        index.may_fit(&[rv(&[0.0, 0.0])], &mut out);
        assert!(out.is_empty());
    }
}
