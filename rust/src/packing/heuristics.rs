//! Greedy MVBP heuristics: first-fit and best-fit over pluggable item
//! orderings.
//!
//! These are the ablation baselines (DESIGN.md, Ablation A), the
//! portfolio solver's racing arms, and the incremental-repack placement
//! engine.  All entry points respect the multiple-choice structure by
//! trying every (bin, choice) / (type, choice) combination and picking
//! greedily.  The core machinery — [`pack_into`] over a pre-seeded set
//! of open bins — is shared with `packing::solver` (sharded arms),
//! `packing::aggregate` (class-aggregated packing), and
//! `manager::realloc` (warm-start delta placement).
//!
//! Placement is driven by the [`super::index::ResidualIndex`]: instead
//! of scanning every open bin per item, first-fit descends the residual
//! segment tree to the lowest-index fitting bin and best-fit scores
//! only the bins the index reports as candidates.  The index makes the
//! *same* fit decisions as a linear scan (same epsilon, same order), so
//! solutions are unchanged — only the scan cost drops.

use super::index::ResidualIndex;
use super::problem::{MvbpProblem, PackedBin, Solution};
use crate::types::ResourceVec;

/// Which greedy placement rule to run (shared by the solo heuristics
/// and the portfolio arms).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Greedy {
    /// Place into the first open bin where any choice fits.
    FirstFit,
    /// Place into the (bin, choice) pair leaving the least headroom.
    BestFit,
}

/// Item preorders the heuristics can run under.  Different orderings
/// find different packings on the same instance, which is exactly what
/// the portfolio solver races.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ItemOrder {
    /// Decreasing best-case fullness (the classic hardest-first order,
    /// same measure as the exact solver's ordering).
    HardestFirst,
    /// Decreasing total normalized demand (big-volume items first);
    /// favors multi-dimension hogs that `HardestFirst`'s max-ratio
    /// measure can underrate.
    SumDecreasing,
    /// Fewest requirement choices first (most constrained items while
    /// bins are still empty), ties broken hardest-first.
    FewestChoices,
}

/// Per-dimension max capacity over bin types — the normalization both
/// ordering measures use.  Shared with `packing::aggregate`, which
/// orders multiplicity *classes* by the same measures.
pub(crate) fn roomiest_capacity(problem: &MvbpProblem) -> ResourceVec {
    ResourceVec(
        (0..problem.dims)
            .map(|d| {
                problem
                    .bin_types
                    .iter()
                    .map(|bt| bt.capacity[d])
                    .fold(0.0, f64::max)
            })
            .collect(),
    )
}

/// Best-case fullness of item `i`: min over choices of the max capacity
/// ratio vs the roomiest bin (the classic hardest-first measure).
pub(crate) fn item_hardness(problem: &MvbpProblem, roomiest: &ResourceVec, i: usize) -> f64 {
    problem.items[i]
        .choices
        .iter()
        .map(|c| c.max_ratio(roomiest))
        .fold(f64::INFINITY, f64::min)
}

/// Total normalized demand of item `i` (min over choices).
pub(crate) fn item_volume(problem: &MvbpProblem, roomiest: &ResourceVec, i: usize) -> f64 {
    problem.items[i]
        .choices
        .iter()
        .map(|c| {
            c.0.iter()
                .zip(&roomiest.0)
                .map(|(v, r)| if *r > 0.0 { v / r } else { 0.0 })
                .sum::<f64>()
        })
        .fold(f64::INFINITY, f64::min)
}

impl ItemOrder {
    pub const ALL: [ItemOrder; 3] = [
        ItemOrder::HardestFirst,
        ItemOrder::SumDecreasing,
        ItemOrder::FewestChoices,
    ];

    /// Item indices of `problem` sorted under this ordering.
    pub fn order(self, problem: &MvbpProblem) -> Vec<usize> {
        let mut order: Vec<usize> = (0..problem.items.len()).collect();
        self.sort_keys(problem, &mut order, |&i| i);
        order
    }

    /// Sort arbitrary keys under this ordering, where `item_of` maps a
    /// key to the item index carrying its measure — `order` sorts items
    /// directly, `packing::aggregate` sorts classes by representative.
    /// The sort is stable, so equal-measure keys keep their given order.
    pub(crate) fn sort_keys<K>(
        self,
        problem: &MvbpProblem,
        keys: &mut [K],
        item_of: impl Fn(&K) -> usize,
    ) {
        let roomiest = roomiest_capacity(problem);
        let hardness = |k: &K| item_hardness(problem, &roomiest, item_of(k));
        let volume = |k: &K| item_volume(problem, &roomiest, item_of(k));
        // total_cmp everywhere: NaN-bearing inputs (caught by `validate`,
        // but this must not panic when called directly) sort
        // deterministically instead of aborting mid-sort.
        match self {
            ItemOrder::HardestFirst => {
                keys.sort_by(|a, b| hardness(b).total_cmp(&hardness(a)));
            }
            ItemOrder::SumDecreasing => {
                keys.sort_by(|a, b| volume(b).total_cmp(&volume(a)));
            }
            ItemOrder::FewestChoices => {
                keys.sort_by(|a, b| {
                    let na = problem.items[item_of(a)].choices.len();
                    let nb = problem.items[item_of(b)].choices.len();
                    na.cmp(&nb)
                        .then_with(|| hardness(b).total_cmp(&hardness(a)))
                });
            }
        }
    }
}

/// The classic hardest-first preorder (kept as the named entry point the
/// ablations and exact solver reference).
pub struct Decreasing;

impl Decreasing {
    /// Items sorted by decreasing best-case fullness (same measure as the
    /// exact solver's ordering, so ablations isolate the *search*, not the
    /// ordering).
    pub fn order(problem: &MvbpProblem) -> Vec<usize> {
        ItemOrder::HardestFirst.order(problem)
    }
}

/// An open bin mid-placement.  `pub(crate)` so the portfolio solver and
/// the warm-start repacker can seed [`pack_into`] with partially filled
/// bins.
pub(crate) struct OpenBin {
    pub(crate) bin_type: usize,
    pub(crate) residual: ResourceVec,
    pub(crate) assignments: Vec<(usize, usize)>,
}

pub(crate) fn finish(open: Vec<OpenBin>) -> Solution {
    Solution {
        bins: open
            .into_iter()
            .map(|b| PackedBin {
                bin_type: b.bin_type,
                assignments: b.assignments,
            })
            .collect(),
    }
}

/// Post-placement headroom `max_d (residual[d] - req[d]) / cap[d]` if
/// `req` fits `residual` (same epsilon as [`ResourceVec::fits`]), else
/// `None` — the best-fit score computed in one pass without
/// materializing the subtracted vector (this used to clone a
/// `ResourceVec` per (bin, choice) probe in the hot loop).
pub(crate) fn slack_after(
    residual: &ResourceVec,
    req: &ResourceVec,
    cap: &ResourceVec,
) -> Option<f64> {
    let mut slack = 0.0f64;
    for ((r, q), c) in residual.0.iter().zip(&req.0).zip(&cap.0) {
        if *q > r + crate::types::FIT_EPS {
            return None;
        }
        let ratio = if *c > 0.0 { (r - q) / c } else { 0.0 };
        if ratio > slack {
            slack = ratio;
        }
    }
    Some(slack)
}

/// Cheapest new-bin `(type, choice)` for `item` on an *empty* bin:
/// minimize cost, break ties by tightest fit.  Shared by the per-item
/// engine and the class-aggregated packer (`packing::aggregate`) so
/// both open identical bins.
pub(crate) fn best_new_bin(problem: &MvbpProblem, item: usize) -> Option<(usize, usize)> {
    let mut best: Option<(usize, usize, f64, f64)> = None; // (type, choice, cost, slack)
    for (t, bt) in problem.bin_types.iter().enumerate() {
        for (c, req) in problem.items[item].choices.iter().enumerate() {
            if req.fits(&bt.capacity) {
                let slack = 1.0 - req.max_ratio(&bt.capacity);
                let cost = bt.cost.as_f64() + problem.choice_cost(item, c).as_f64();
                let better = match &best {
                    None => true,
                    Some((_, _, bc, bs)) => {
                        cost < *bc - 1e-12 || (cost <= *bc + 1e-12 && slack < *bs)
                    }
                };
                if better {
                    best = Some((t, c, cost, slack));
                }
            }
        }
    }
    best.map(|(t, c, _, _)| (t, c))
}

/// Open the cheapest feasible new bin for `item` and place it there.
fn open_new_bin(problem: &MvbpProblem, item: usize, open: &mut Vec<OpenBin>) -> bool {
    let Some((t, c)) = best_new_bin(problem, item) else { return false };
    let mut residual = problem.bin_types[t].capacity.clone();
    residual.sub_assign(&problem.items[item].choices[c]);
    open.push(OpenBin {
        bin_type: t,
        residual,
        assignments: vec![(item, c)],
    });
    true
}

/// Place `items` (indices into `problem.items`, in the order given)
/// into `open` bins under the `greedy` rule, opening the cheapest
/// feasible new bin when nothing fits.  `open` may be pre-seeded with
/// partially filled bins — the warm-start repacker and the portfolio's
/// sharded arms rely on that.  Returns `false` iff some item fits in no
/// open bin and no new bin admits it; `open` then holds a partial
/// placement the caller must discard.
///
/// Bin lookup goes through a [`ResidualIndex`] built over `open`:
/// first-fit descends to the lowest-index fitting bin, best-fit scores
/// only index-reported candidates.  Both produce exactly the solution
/// the former linear scans did (the index's fit test is the same
/// comparison in the same order); only the per-item scan cost changes.
///
/// Does *not* validate `problem` — public wrappers and the portfolio do
/// that once per solve, not once per shard.
pub(crate) fn pack_into(
    problem: &MvbpProblem,
    greedy: Greedy,
    items: &[usize],
    open: &mut Vec<OpenBin>,
) -> bool {
    let residuals: Vec<&ResourceVec> = open.iter().map(|b| &b.residual).collect();
    let mut index = ResidualIndex::new(problem.dims, &residuals);
    drop(residuals);
    let mut candidates: Vec<usize> = Vec::new();
    for &item in items {
        let choices = &problem.items[item].choices;
        let placed = match greedy {
            Greedy::FirstFit => {
                // First open bin where any choice fits (choices tried in
                // order — CPU first, matching the paper's "prefer the
                // cheap path" intuition).
                match index.first_fit_any(choices) {
                    Some((b, c)) => {
                        open[b].residual.sub_assign(&choices[c]);
                        open[b].assignments.push((item, c));
                        index.update(b, &open[b].residual);
                        true
                    }
                    None => false,
                }
            }
            Greedy::BestFit => {
                // (bin, choice) pair leaving the least residual headroom,
                // scored over the index's candidates in bin order (same
                // tie-breaking as the full scan: strictly-better wins).
                index.may_fit(choices, &mut candidates);
                let mut best: Option<(usize, usize, f64)> = None;
                for &b in &candidates {
                    let bin = &open[b];
                    let cap = &problem.bin_types[bin.bin_type].capacity;
                    for (c, req) in choices.iter().enumerate() {
                        if let Some(slack) = slack_after(&bin.residual, req, cap) {
                            if best.map_or(true, |(_, _, bs)| slack < bs) {
                                best = Some((b, c, slack));
                            }
                        }
                    }
                }
                match best {
                    Some((b, c, _)) => {
                        open[b].residual.sub_assign(&choices[c]);
                        open[b].assignments.push((item, c));
                        index.update(b, &open[b].residual);
                        true
                    }
                    None => false,
                }
            }
        };
        if !placed {
            if !open_new_bin(problem, item, open) {
                return false;
            }
            index.push(&open.last().expect("bin just opened").residual);
        }
    }
    true
}

/// One full greedy pass under an explicit rule and ordering.
pub fn solve_greedy(problem: &MvbpProblem, greedy: Greedy, order: ItemOrder) -> Option<Solution> {
    problem.validate().ok()?;
    let items = order.order(problem);
    let mut open: Vec<OpenBin> = Vec::new();
    pack_into(problem, greedy, &items, &mut open).then(|| finish(open))
}

/// First-fit-decreasing: place each item into the first open bin where
/// any choice fits; otherwise open the cheapest feasible new bin.
pub fn solve_first_fit(problem: &MvbpProblem) -> Option<Solution> {
    solve_greedy(problem, Greedy::FirstFit, ItemOrder::HardestFirst)
}

/// Best-fit-decreasing: place each item into the (bin, choice) pair that
/// leaves the least residual headroom; otherwise open the cheapest
/// feasible new bin.
pub fn solve_best_fit(problem: &MvbpProblem) -> Option<Solution> {
    solve_greedy(problem, Greedy::BestFit, ItemOrder::HardestFirst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::problem::test_fixtures::small_problem;
    use crate::packing::problem::{BinType, Item, MvbpProblem};
    use crate::types::Dollars;

    #[test]
    fn ffd_produces_valid_solution() {
        let p = small_problem();
        let s = solve_first_fit(&p).unwrap();
        s.validate(&p).unwrap();
    }

    #[test]
    fn bfd_produces_valid_solution() {
        let p = small_problem();
        let s = solve_best_fit(&p).unwrap();
        s.validate(&p).unwrap();
    }

    #[test]
    fn heuristics_fail_on_infeasible() {
        let mut p = small_problem();
        p.items.push(Item {
            id: "huge".into(),
            choices: vec![ResourceVec::from_slice(&[100.0, 0.0])],
        });
        assert!(solve_first_fit(&p).is_none());
        assert!(solve_best_fit(&p).is_none());
    }

    /// A mixed-choice instance exercising the exact-vs-FFD guarantee.
    ///
    /// One bin type of capacity 10 and cost $1; items `a = [7]`,
    /// `b = [6 | 3]` (multiple-choice), `c = [6]`, `d = [4]`.  The
    /// optimum is 2 bins: `(a, b@3)` and `(c, d)` — reachable only by
    /// taking b's *second* choice.  FFD happens to find it too on this
    /// instance (hardness order a, c, d, b lets b's 3-choice slot into
    /// a's bin), so the assertions are the actual guarantees: both
    /// solutions validate, `exact <= ffd` in cost, and exact attains
    /// the known $2 optimum.
    #[test]
    fn exact_attains_optimum_and_never_trails_ffd() {
        let p = MvbpProblem {
            dims: 1,
            bin_types: vec![BinType {
                name: "b".into(),
                cost: Dollars::from_f64(1.0),
                capacity: ResourceVec::from_slice(&[10.0]),
            }],
            items: vec![
                Item {
                    id: "a".into(),
                    choices: vec![ResourceVec::from_slice(&[7.0])],
                },
                Item {
                    id: "b".into(),
                    choices: vec![
                        ResourceVec::from_slice(&[6.0]),
                        ResourceVec::from_slice(&[3.0]),
                    ],
                },
                Item {
                    id: "c".into(),
                    choices: vec![ResourceVec::from_slice(&[6.0])],
                },
                Item {
                    id: "d".into(),
                    choices: vec![ResourceVec::from_slice(&[4.0])],
                },
            ],
            choice_costs: vec![],
        };
        let ffd = solve_first_fit(&p).unwrap();
        let exact = crate::packing::solve_exact(&p).unwrap();
        ffd.validate(&p).unwrap();
        exact.validate(&p).unwrap();
        assert!(exact.cost(&p) <= ffd.cost(&p));
        // Optimal is 2 bins: (7, 3-choice) and (6, 4).
        assert_eq!(exact.cost(&p), Dollars::from_f64(2.0));
    }

    #[test]
    fn nan_requirements_are_rejected_not_panicked() {
        // Regression: with NaN smuggled into a choice, the heuristics'
        // float sorts used to be one partial_cmp unwrap away from a
        // panic.  validate now rejects the instance up front and the
        // ordering itself is total_cmp, so a direct call cannot abort.
        let mut p = small_problem();
        p.items[0].choices[0] = ResourceVec::from_slice(&[f64::NAN, 1.0]);
        assert!(solve_first_fit(&p).is_none());
        assert!(solve_best_fit(&p).is_none());
        let order = Decreasing::order(&p); // must not panic
        assert_eq!(order.len(), p.items.len());
    }

    /// Seeded randomized cross-check over generated MVBP instances:
    /// FFD, BFD, and the exact solver must all return validate-clean
    /// solutions, and the exact cost can never exceed a heuristic's.
    #[test]
    fn randomized_cross_check_exact_vs_heuristics() {
        use crate::packing::solve_exact;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x5EED_CA5E);
        for case in 0..40 {
            let dims = 2;
            let n_types = 1 + rng.below(2) as usize;
            let bin_types: Vec<BinType> = (0..n_types)
                .map(|t| BinType {
                    name: format!("t{t}"),
                    cost: Dollars::from_f64(rng.range_f64(0.5, 3.0)),
                    // Min capacity 5.0 > max requirement 4.5: every item
                    // fits an empty bin, so all three solvers succeed.
                    capacity: ResourceVec(
                        (0..dims).map(|_| rng.range_f64(5.0, 12.0)).collect(),
                    ),
                })
                .collect();
            let n_items = 2 + rng.below(6) as usize;
            let items: Vec<Item> = (0..n_items)
                .map(|i| {
                    let n_choices = 1 + rng.below(2) as usize;
                    Item {
                        id: format!("i{i}"),
                        choices: (0..n_choices)
                            .map(|_| {
                                ResourceVec(
                                    (0..dims).map(|_| rng.range_f64(0.5, 4.5)).collect(),
                                )
                            })
                            .collect(),
                    }
                })
                .collect();
            let p = MvbpProblem { dims, bin_types, items, choice_costs: vec![] };
            p.validate().unwrap();
            let ffd = solve_first_fit(&p).unwrap();
            let bfd = solve_best_fit(&p).unwrap();
            let exact = solve_exact(&p).unwrap();
            ffd.validate(&p).unwrap_or_else(|e| panic!("case {case}: ffd invalid: {e}"));
            bfd.validate(&p).unwrap_or_else(|e| panic!("case {case}: bfd invalid: {e}"));
            exact
                .validate(&p)
                .unwrap_or_else(|e| panic!("case {case}: exact invalid: {e}"));
            assert!(
                exact.cost(&p) <= ffd.cost(&p),
                "case {case}: exact {} > ffd {}",
                exact.cost(&p),
                ffd.cost(&p)
            );
            assert!(
                exact.cost(&p) <= bfd.cost(&p),
                "case {case}: exact {} > bfd {}",
                exact.cost(&p),
                bfd.cost(&p)
            );
        }
    }

    #[test]
    fn decreasing_order_puts_hardest_first() {
        let p = small_problem();
        let order = Decreasing::order(&p);
        // item "a" needs 3.0 with no alternative; "b" can shrink to 1.0.
        assert!(order.iter().position(|&i| i == 0) < order.iter().position(|&i| i == 1));
    }

    #[test]
    fn every_ordering_is_a_permutation_and_packs_clean() {
        let p = small_problem();
        for order in ItemOrder::ALL {
            let idx = order.order(&p);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2], "{order:?} must permute all items");
            for greedy in [Greedy::FirstFit, Greedy::BestFit] {
                let s = solve_greedy(&p, greedy, order).unwrap();
                s.validate(&p).unwrap_or_else(|e| panic!("{greedy:?}/{order:?}: {e}"));
            }
        }
    }

    #[test]
    fn fewest_choices_orders_constrained_items_first() {
        let p = small_problem();
        let order = ItemOrder::FewestChoices.order(&p);
        // "b" is the only two-choice item; both single-choice items
        // ("a", "c") must precede it.
        assert_eq!(order[2], 1);
    }

    #[test]
    fn pack_into_respects_preseeded_bins() {
        // Seed one small bin holding item 0; packing the rest must not
        // disturb it and must account for its residual.
        let p = small_problem();
        let mut residual = p.bin_types[0].capacity.clone();
        residual.sub_assign(&p.items[0].choices[0]);
        let mut open = vec![OpenBin {
            bin_type: 0,
            residual,
            assignments: vec![(0, 0)],
        }];
        assert!(pack_into(&p, Greedy::BestFit, &[1, 2], &mut open));
        let s = finish(open);
        s.validate(&p).unwrap();
        assert_eq!(s.bins[0].assignments[0], (0, 0));
    }
}
