//! # camcloud — cloud resource management for network-camera analytics
//!
//! Reproduction of *"Analyzing Real-Time Multimedia Content From Network
//! Cameras Using CPUs and GPUs in the Cloud"* (Kaseb et al., 2018).
//!
//! The library implements the paper's resource manager and every substrate
//! it depends on (see `DESIGN.md` for the full inventory):
//!
//! * [`packing`] — multiple-choice vector bin packing: exact
//!   branch-and-bound, an arc-flow (Brandão–Pedroso) bound/1-D solver, and
//!   first/best-fit heuristics.
//! * [`cloud`] — simulated cloud: the Table-1 EC2 catalog, instance
//!   lifecycle + hourly billing, and calibrated CPU/GPU device models.
//! * [`streams`] — simulated network cameras producing frames at desired
//!   rates and sizes.
//! * [`profiler`] — the paper's test-run subsystem: measure a program on
//!   CPU (really, via PJRT) and on GPU (via the calibrated device model),
//!   fit the linear utilization-vs-fps resource model.
//! * [`manager`] — the contribution: formulate allocation as MVBP under
//!   strategies ST1/ST2/ST3 and emit an allocation plan.
//! * [`sched`] — per-instance frame-loop schedulers over a discrete-event
//!   simulation clock (plus a real-time tokio mode used by the examples).
//! * [`runtime`] — PJRT CPU runtime loading the AOT HLO artifacts
//!   produced by `python/compile/aot.py`.
//! * [`coordinator`] — end-to-end orchestration: profile → allocate →
//!   provision → run → report.
//!
//! Python is build-time only; the request path is entirely in this crate.

pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod manager;
pub mod metrics;
pub mod packing;
pub mod util;
pub mod profiler;
pub mod reports;
pub mod runtime;
pub mod sched;
pub mod streams;
pub mod types;

pub use types::{Dollars, FrameSize, ResourceVec};
