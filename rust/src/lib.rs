//! # camcloud — cloud resource management for network-camera analytics
//!
//! Reproduction of *"Analyzing Real-Time Multimedia Content From Network
//! Cameras Using CPUs and GPUs in the Cloud"* (Kaseb et al., 2018).
//!
//! The library implements the paper's resource manager and every substrate
//! it depends on (see `DESIGN.md` for the full inventory):
//!
//! * [`packing`] — multiple-choice vector bin packing behind the
//!   [`packing::Solver`] trait: exact branch-and-bound (deadline- and
//!   node-bounded, seedable), first/best-fit heuristics over pluggable
//!   item orderings on an indexed placement engine (segment tree over
//!   open-bin residuals), a class-aggregation layer
//!   ([`packing::aggregate`]) that packs million-stream fleets by
//!   multiplicity class, a racing [`packing::PortfolioSolver`] on
//!   scoped threads with aggregated or sharded arms at scale, and an
//!   arc-flow (Brandão–Pedroso) machinery whose L2 bound certifies
//!   every solve's optimality gap.
//! * [`cloud`] — simulated cloud: the Table-1 EC2 catalog, instance
//!   lifecycle + hourly billing, and calibrated CPU/GPU device models.
//! * [`streams`] — simulated network cameras producing frames at desired
//!   rates and sizes.
//! * [`workload`] — the first-class [`workload::Workload`] unit the
//!   pipeline consumes (streams + catalog + optional profiles), the
//!   [`workload::FleetSpec`] synthetic-fleet generator that scales the
//!   scenario space beyond the paper's Table 5, and
//!   [`workload::trace`] demand timelines (diurnal curves, emergency
//!   bursts, camera churn) for the autoscaling subsystem.
//! * [`profiler`] — the paper's test-run subsystem: measure a program on
//!   CPU (really, via PJRT) and on GPU (via the calibrated device model),
//!   fit the linear utilization-vs-fps resource model.
//! * [`manager`] — the contribution: formulate allocation as MVBP under
//!   strategies ST1/ST2/ST3 and emit an allocation plan.
//! * [`sched`] — plan execution on a simulated timeline behind the
//!   [`sched::SimEngine`] facade: the default **event-driven**
//!   discrete-event engine and the fixed-step fluid baseline it is
//!   cross-validated against, both executed *sharded* across instance
//!   partitions ([`sched::Parallelism`]) with bit-identical results
//!   for every thread count.
//! * [`runtime`] — PJRT CPU runtime loading the AOT HLO artifacts
//!   produced by `python/compile/aot.py` (behind the `pjrt` feature;
//!   a stub otherwise).
//! * [`coordinator`] — end-to-end orchestration as composable stages:
//!   profile → allocate → provision → simulate → bill; the
//!   [`coordinator::autoscale`] runner repeats those stages per epoch
//!   of a demand trace as an explicit plan/actuate/simulate/bill
//!   pipeline (epoch `i+1`'s solve overlapped with epoch `i`'s
//!   simulation), with hysteresis-gated fleet transitions, warm-start
//!   solves with periodic cold refresh, and a policy comparison
//!   (static-peak / static-mean / oracle / reactive) under
//!   started-hour billing.
//! * [`net`] — coordinator/worker distribution over plain TCP
//!   (`camcloud worker --listen` + `--workers` on the coordinator):
//!   exact-search subtree batches and simulation instance partitions
//!   shipped as length-prefixed JSON frames, raced against local
//!   threads with retire-on-failure degradation and bit-identical
//!   results for any worker count.
//!
//! Python is build-time only; the request path is entirely in this crate.
//!
//! ## Performance model: ticks vs events
//!
//! The fixed-step engine costs `O(duration/dt x (streams + devices))` —
//! at `dt = 10 ms` that is 12,000 full passes over the fleet for a
//! two-minute run whether anything happens or not.  The event engine
//! costs `O(events x streams-per-instance x log events)` where `events
//! ≈ Σ fps x duration` arrivals plus as many completions, and each
//! event touches only the affected instance.  Fleets spread work over
//! many instances, so simulation cost scales with offered load rather
//! than with wall-clock resolution; at 1,000 streams the event engine
//! is well over an order of magnitude faster (see
//! `benches/engine_compare.rs`) while being *exact* instead of
//! tick-quantized.

pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod manager;
pub mod metrics;
pub mod net;
pub mod packing;
pub mod util;
pub mod profiler;
pub mod reports;
pub mod runtime;
pub mod sched;
pub mod streams;
pub mod types;
pub mod workload;

pub use types::{Dollars, FrameSize, ResourceVec};
