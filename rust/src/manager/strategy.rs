//! Resource allocation strategies (the paper's Table 4).

use crate::cloud::Catalog;
use crate::profiler::ExecChoice;

/// The three strategies compared in the paper's evaluation (§4.4).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Strategy {
    /// ST1: always use non-GPU instances (CPU analysis only).
    St1,
    /// ST2: always use GPU instances (GPU analysis only).
    St2,
    /// ST3 (this paper): consider both, minimize overall cost.
    St3,
}

impl Strategy {
    pub const ALL: [Strategy; 3] = [Strategy::St1, Strategy::St2, Strategy::St3];

    /// Restrict the catalog to the instance types this strategy admits.
    pub fn filter_catalog(self, catalog: &Catalog) -> Catalog {
        match self {
            Strategy::St1 => catalog.non_gpu_only(),
            Strategy::St2 => catalog.gpu_only(),
            Strategy::St3 => catalog.clone(),
        }
    }

    /// Whether a stream may be analyzed with `choice` under this
    /// strategy.  Matches the paper: "For ST1 (or ST2), there is a
    /// single choice for the resource requirements of each program".
    pub fn allows_choice(self, choice: ExecChoice) -> bool {
        match self {
            Strategy::St1 => !choice.is_gpu(),
            Strategy::St2 => choice.is_gpu(),
            Strategy::St3 => true,
        }
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Strategy::St1 => "ST1",
            Strategy::St2 => "ST2",
            Strategy::St3 => "ST3",
        })
    }
}

impl std::str::FromStr for Strategy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "st1" | "1" | "cpu" => Ok(Strategy::St1),
            "st2" | "2" | "gpu" => Ok(Strategy::St2),
            "st3" | "3" | "both" => Ok(Strategy::St3),
            other => Err(format!("unknown strategy {other:?} (expected st1/st2/st3)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_filtering() {
        let cat = Catalog::aws_table1();
        assert!(Strategy::St1
            .filter_catalog(&cat)
            .types
            .iter()
            .all(|t| !t.has_gpu()));
        assert!(Strategy::St2
            .filter_catalog(&cat)
            .types
            .iter()
            .all(|t| t.has_gpu()));
        assert_eq!(Strategy::St3.filter_catalog(&cat).types.len(), 4);
    }

    #[test]
    fn choice_rules_match_table4() {
        assert!(Strategy::St1.allows_choice(ExecChoice::Cpu));
        assert!(!Strategy::St1.allows_choice(ExecChoice::Gpu(0)));
        assert!(!Strategy::St2.allows_choice(ExecChoice::Cpu));
        assert!(Strategy::St2.allows_choice(ExecChoice::Gpu(1)));
        assert!(Strategy::St3.allows_choice(ExecChoice::Cpu));
        assert!(Strategy::St3.allows_choice(ExecChoice::Gpu(0)));
    }

    #[test]
    fn parsing_and_display() {
        assert_eq!("st1".parse::<Strategy>().unwrap(), Strategy::St1);
        assert_eq!("GPU".parse::<Strategy>().unwrap(), Strategy::St2);
        assert_eq!("both".parse::<Strategy>().unwrap(), Strategy::St3);
        assert!("st4".parse::<Strategy>().is_err());
        assert_eq!(Strategy::St3.to_string(), "ST3");
    }
}
