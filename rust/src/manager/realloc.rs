//! Reallocation: adapting a running fleet to a changed workload.
//!
//! The paper's motivation (§1) is bursty demand — "the analysis is
//! needed occasionally (e.g., during emergencies)" — which implies the
//! manager re-solves as cameras/rates change.  A fresh MVBP solve gives
//! the cost-optimal *target* fleet; this module computes the cheapest
//! transition from the currently provisioned fleet:
//!
//! * instances whose type still appears in the target plan are
//!   **reused** (streams may be re-assigned — streams are stateless,
//!   so moving one costs nothing);
//! * surplus instances are **terminated**;
//! * missing instances are **provisioned** (paying cloud boot latency
//!   and a fresh billed hour).
//!
//! Because bins of one type are interchangeable, matching by type
//! count is optimal for any transition-cost function that is monotone
//! in the number of provision/terminate actions.
//!
//! Four policy primitives complete the picture for an autoscaler:
//! [`worth_reallocating`] is the hysteresis gate (feasibility first,
//! then horizon savings vs churn waste), [`repack_onto`] answers "can
//! the fleet I already pay for serve the new workload?",
//! [`repack_incremental`] warm-starts the next epoch's packing from the
//! previous plan so only the stream delta is re-packed, and
//! [`assign_best_effort`] degrades gracefully when a fixed fleet is
//! genuinely under-provisioned.

use super::plan::{AllocationPlan, PlannedInstance, StreamAssignment};
use super::{AllocationError, BuiltProblem, ResourceManager, Strategy};
use crate::cloud::Catalog;
use crate::packing::heuristics::{self, Greedy, OpenBin};
use crate::packing::{aggregate, certified_lower_bound, Decreasing, SolveOutcome, SolverKind};
use crate::profiler::{ExecChoice, ResourceProfile};
use crate::streams::StreamSpec;
use crate::types::{Dollars, ResourceVec};
use std::collections::BTreeMap;

/// One step of a fleet transition.
#[derive(Clone, Debug, PartialEq)]
pub enum TransitionAction {
    /// Keep `count` already-running instances of `type_name`.
    Keep { type_name: String, count: u32 },
    /// Provision `count` new instances of `type_name`.
    Provision { type_name: String, count: u32 },
    /// Terminate `count` instances of `type_name`.
    Terminate { type_name: String, count: u32 },
}

/// A reallocation: the target plan plus the cheapest transition to it.
#[derive(Clone, Debug)]
pub struct Reallocation {
    pub actions: Vec<TransitionAction>,
    /// Instances kept running (no churn).
    pub kept: u32,
    pub provisioned: u32,
    pub terminated: u32,
    /// Hourly cost delta (target - current).
    pub hourly_delta: Dollars,
}

/// Compute the transition from `current` to `target` by type matching.
pub fn plan_transition(current: &AllocationPlan, target: &AllocationPlan) -> Reallocation {
    let cur = current.counts_by_type();
    let tgt = target.counts_by_type();
    let mut actions = Vec::new();
    let mut kept = 0;
    let mut provisioned = 0;
    let mut terminated = 0;

    let all_types: std::collections::BTreeSet<&String> = cur.keys().chain(tgt.keys()).collect();
    for type_name in all_types {
        let have = *cur.get(type_name).unwrap_or(&0);
        let want = *tgt.get(type_name).unwrap_or(&0);
        let keep = have.min(want);
        if keep > 0 {
            kept += keep;
            actions.push(TransitionAction::Keep { type_name: type_name.clone(), count: keep });
        }
        if want > have {
            provisioned += want - have;
            actions.push(TransitionAction::Provision {
                type_name: type_name.clone(),
                count: want - have,
            });
        } else if have > want {
            terminated += have - want;
            actions.push(TransitionAction::Terminate {
                type_name: type_name.clone(),
                count: have - want,
            });
        }
    }
    Reallocation {
        actions,
        kept,
        provisioned,
        terminated,
        // Compare full burn rates (instances + cross-region transfer)
        // so the hysteresis gate sees savings a placement achieves by
        // repatriating streams, not just by shrinking the fleet.
        hourly_delta: target.total_rate() - current.total_rate(),
    }
}

/// Hysteresis policy: is a reallocation *worth it*?
///
/// The first question is feasibility, not cost: `current_serves_new`
/// says whether the currently provisioned fleet can still serve the
/// *new* workload (see [`repack_onto`]).  If it cannot, the manager
/// must move regardless of churn cost — performance is at stake.  A
/// cost delta is no proxy for this: a changed workload whose optimal
/// target plan is cost-equal or cheaper can still be unservable by the
/// current fleet (e.g. a rate increase that crosses the CPU latency
/// ceiling while the optimal GPU plan costs less than the old CPU
/// fleet).
///
/// Only when the current fleet *does* serve the new workload is the
/// move discretionary, and then terminating mid-hour wastes the
/// remainder of a billed hour: a cheaper target pays off only if the
/// saving over the planning horizon exceeds the churn waste.
/// `wasted_fraction` is the mean unused fraction of the current billing
/// hour (0.5 if unknown).
pub fn worth_reallocating(
    realloc: &Reallocation,
    current: &AllocationPlan,
    current_serves_new: bool,
    horizon_hours: f64,
    wasted_fraction: f64,
) -> bool {
    if realloc.provisioned == 0 && realloc.terminated == 0 {
        return false; // same fleet, nothing to do
    }
    if !current_serves_new {
        return true; // current fleet cannot serve the new workload
    }
    // Discretionary move: compare horizon savings vs wasted billed time.
    let saving = -realloc.hourly_delta.as_f64() * horizon_hours;
    let mut waste_per_terminated: BTreeMap<&str, f64> = BTreeMap::new();
    for inst in &current.instances {
        waste_per_terminated
            .entry(inst.type_name.as_str())
            .or_insert(inst.hourly_cost.as_f64() * wasted_fraction);
    }
    let waste: f64 = realloc
        .actions
        .iter()
        .filter_map(|a| match a {
            TransitionAction::Terminate { type_name, count } => Some(
                waste_per_terminated.get(type_name.as_str()).unwrap_or(&0.0) * *count as f64,
            ),
            _ => None,
        })
        .sum();
    saving > waste
}

/// Can the currently provisioned fleet serve `streams` *without any
/// provisioning*?  Solves the MVBP restricted to the fleet's instance
/// types and accepts the solution only if its per-type bin counts fit
/// within the fleet — the feasibility signal [`worth_reallocating`]
/// gates on, and the serving plan an autoscaler simulates when
/// hysteresis keeps the fleet.
///
/// `Ok(None)` means the fleet genuinely cannot serve the workload
/// ([`AllocationError::Infeasible`], or more bins needed than are
/// running).  Structural errors (missing profile, solver failure) are
/// *not* infeasibility and propagate — the same distinction the what-if
/// sweeps draw.
pub fn repack_onto(
    manager: &ResourceManager<'_>,
    current: &AllocationPlan,
    streams: &[StreamSpec],
    strategy: Strategy,
) -> Result<Option<AllocationPlan>, AllocationError> {
    let have = current.counts_by_type();
    if have.is_empty() {
        return Ok(None); // an empty fleet serves nothing
    }
    let names: Vec<&str> = have.keys().map(String::as_str).collect();
    let restricted = ResourceManager {
        catalog: manager.catalog.subset(&names),
        profiles: manager.profiles,
        headroom: manager.headroom,
        solver: manager.solver,
        budget: manager.budget,
    };
    let mut plan = match restricted.allocate(streams, strategy) {
        Ok(plan) => plan,
        Err(AllocationError::Infeasible { .. }) => return Ok(None),
        // A fleet of only GPU (or only CPU) types is legitimately
        // unservable under a strategy that excludes them all.
        Err(AllocationError::EmptyCatalog(_)) => return Ok(None),
        Err(other) => return Err(other),
    };
    // The bound was certified against the fleet-restricted catalog; it
    // is NOT a valid certificate vs the full catalog (a subset's
    // cheapest type / best capacity-per-dollar can be worse), so a
    // kept-fleet epoch must not report a spuriously tight gap.
    plan.lower_bound = None;
    let fits = plan
        .counts_by_type()
        .iter()
        .all(|(t, n)| have.get(t).copied().unwrap_or(0) >= *n);
    Ok(fits.then_some(plan))
}

/// Utilization floor below which a seeded bin is dissolved during
/// incremental repacking: bins left mostly empty by departed streams
/// rejoin the delta so scale-down actually shrinks the fleet instead of
/// fossilizing half-empty instances.
const CONSOLIDATE_BELOW: f64 = 0.5;

/// Does choice vector `req` match the plan-recorded requirement `kept`
/// on every *physical* dimension?  Plans never carry region-gate
/// dimensions (they are truncated on the way out of the solver), so a
/// gated problem's choices are compared on their physical prefix only;
/// ungated problems have equal dims and this is exact equality.
fn physical_eq(req: &ResourceVec, kept: &ResourceVec) -> bool {
    req.dims() >= kept.dims() && (0..kept.dims()).all(|d| (req[d] - kept[d]).abs() <= 1e-9)
}

/// Warm-start packing of `built` seeded from `previous`:
///
/// 1. **Keep** — every stream of the previous plan that still exists in
///    the new problem with an identical requirement vector stays in its
///    bin under its old choice;
/// 2. **Consolidate** — kept bins whose remaining load falls below
///    [`CONSOLIDATE_BELOW`] utilization are dissolved, their streams
///    rejoining the delta;
/// 3. **Delta** — remaining items (new streams, changed rates,
///    consolidated strays) are best-fit into the seeded residuals,
///    opening cheapest-feasible new bins only when nothing fits.  The
///    delta placement runs on the indexed engine (`packing::index`
///    via `pack_into`), so a small delta against a large kept fleet
///    costs near-O(delta × log bins), not a scan of every kept bin.
///
/// Returns a certified [`SolveOutcome`] (kind [`SolverKind::WarmStart`])
/// or `None` when the previous plan cannot seed this problem at all
/// (unknown bin types, changed layout, packing failure) — the caller
/// then cold-solves.  The caller also owns the quality gate: accept the
/// warm outcome only if its certified gap has not drifted past the
/// previous plan's (see `ResourceManager::allocate_warm`).
pub(crate) fn repack_incremental(
    built: &BuiltProblem,
    previous: &AllocationPlan,
) -> Option<SolveOutcome> {
    let problem = &built.problem;
    if previous.instances.is_empty() {
        return None;
    }
    let index_of: BTreeMap<&str, usize> = problem
        .items
        .iter()
        .enumerate()
        .map(|(i, it)| (it.id.as_str(), i))
        .collect();
    let type_of: BTreeMap<&str, usize> = problem
        .bin_types
        .iter()
        .enumerate()
        .map(|(t, bt)| (bt.name.as_str(), t))
        .collect();

    // Stage 1: keep surviving streams in their bins.
    let mut placed = vec![false; problem.items.len()];
    let mut seeded: Vec<OpenBin> = Vec::new();
    for inst in previous.instances.iter() {
        let &bin_type = type_of.get(inst.type_name.as_str())?;
        let capacity = &problem.bin_types[bin_type].capacity;
        let mut residual = capacity.clone();
        let mut assignments = Vec::new();
        for s in &inst.streams {
            let Some(&item) = index_of.get(s.stream_id.as_str()) else { continue };
            if placed[item] {
                continue;
            }
            // Fitting is part of choice selection: in a region-gated
            // problem the same physical requirement appears once per
            // region, and only the choice whose gate dimension matches
            // this bin's region fits its residual.
            let Some(choice) = problem.items[item]
                .choices
                .iter()
                .position(|req| physical_eq(req, &s.requirement) && req.fits(&residual))
            else {
                continue; // rate/profile/capacity changed: re-pack as delta
            };
            let req = &problem.items[item].choices[choice];
            residual.sub_assign(req);
            assignments.push((item, choice));
            placed[item] = true;
        }
        if !assignments.is_empty() {
            seeded.push(OpenBin { bin_type, residual, assignments });
        }
    }

    // Stage 2: dissolve bins left under-utilized by departures.
    let mut open: Vec<OpenBin> = Vec::new();
    for bin in seeded {
        let capacity = &problem.bin_types[bin.bin_type].capacity;
        let mut load = capacity.clone();
        load.sub_assign(&bin.residual);
        if load.max_ratio(capacity) < CONSOLIDATE_BELOW {
            for &(item, _) in &bin.assignments {
                placed[item] = false;
            }
        } else {
            open.push(bin);
        }
    }

    // Stage 3: best-fit the delta (hardest first) into the residuals.
    // A churn epoch typically delivers many identical streams at once;
    // when the delta collapses into few multiplicity classes the
    // class-aggregated packer places whole runs per index lookup, and a
    // mostly-distinct delta keeps the per-item path.
    let delta: Vec<usize> = Decreasing::order(problem)
        .into_iter()
        .filter(|&i| !placed[i])
        .collect();
    let classes = aggregate::group_subset(problem, &delta);
    let packed = if aggregate::aggregation_pays(classes.len(), delta.len()) {
        aggregate::pack_delta_classes(problem, &classes, &mut open)
    } else {
        heuristics::pack_into(problem, Greedy::BestFit, &delta, &mut open)
    };
    if !packed {
        return None;
    }
    let solution = heuristics::finish(open);
    solution.validate(problem).ok()?;
    let cost = solution.cost(problem);
    let lower_bound = certified_lower_bound(problem).min(cost);
    Some(SolveOutcome {
        solution,
        solver: SolverKind::WarmStart,
        cost,
        lower_bound,
        proven_optimal: cost == lower_bound,
    })
}

/// Best-effort placement of `streams` onto a *fixed* fleet that a
/// capacity-clean packing cannot serve (an under-provisioned static
/// fleet during a burst): each stream goes to the (instance, device)
/// pair minimizing the post-assignment load ratio, overcommitting the
/// instance if it must — throughput then degrades in simulation rather
/// than the stream being refused outright.  Streams with no
/// latency-sustainable device anywhere in the fleet are returned as
/// unserved indices.
///
/// `profiles[i]` is the resolved profile of `streams[i]`; capacities
/// are rebuilt from `catalog` under its full layout so fleets planned
/// under a strategy-narrowed layout compose with GPU-bearing catalogs.
pub fn assign_best_effort(
    fleet: &AllocationPlan,
    streams: &[StreamSpec],
    profiles: &[ResourceProfile],
    strategy: Strategy,
    catalog: &Catalog,
    headroom: f64,
) -> (AllocationPlan, Vec<usize>) {
    assert_eq!(streams.len(), profiles.len(), "one profile per stream");
    let layout = catalog.layout();
    let capacities: Vec<ResourceVec> = fleet
        .instances
        .iter()
        .map(|inst| {
            catalog
                .resolve(&inst.type_name)
                .expect("fleet types come from the catalog")
                .itype
                .capability(layout)
                .scale(headroom)
        })
        .collect();
    let gpu_counts: Vec<usize> = fleet
        .instances
        .iter()
        .map(|inst| catalog.resolve(&inst.type_name).map_or(0, |off| off.itype.gpus.len()))
        .collect();
    let mut loads: Vec<ResourceVec> = fleet
        .instances
        .iter()
        .map(|_| ResourceVec::zeros(layout.dims()))
        .collect();
    let mut assigned: Vec<Vec<StreamAssignment>> =
        fleet.instances.iter().map(|_| Vec::new()).collect();
    let mut unserved = Vec::new();
    for (s_idx, spec) in streams.iter().enumerate() {
        let profile = &profiles[s_idx];
        let mut best: Option<(usize, ExecChoice, f64)> = None;
        for i_idx in 0..fleet.instances.len() {
            let choices =
                std::iter::once(ExecChoice::Cpu).chain((0..gpu_counts[i_idx]).map(ExecChoice::Gpu));
            for choice in choices {
                if !strategy.allows_choice(choice)
                    || !profile.sustains(choice, spec.desired_fps)
                {
                    continue;
                }
                let req = profile.requirement(spec.desired_fps, choice, layout);
                let ratio = loads[i_idx].add(&req).max_ratio(&capacities[i_idx]);
                if best.map_or(true, |(_, _, r)| ratio < r) {
                    best = Some((i_idx, choice, ratio));
                }
            }
        }
        match best {
            Some((i_idx, choice, _)) => {
                let requirement = profile.requirement(spec.desired_fps, choice, layout);
                loads[i_idx].add_assign(&requirement);
                assigned[i_idx].push(StreamAssignment {
                    stream_index: s_idx,
                    stream_id: spec.id(),
                    choice,
                    requirement,
                });
            }
            None => unserved.push(s_idx),
        }
    }
    let instances: Vec<PlannedInstance> = fleet
        .instances
        .iter()
        .zip(capacities)
        .zip(assigned)
        .map(|((inst, capacity), streams)| PlannedInstance {
            type_name: inst.type_name.clone(),
            hourly_cost: inst.hourly_cost,
            capacity,
            streams,
        })
        .collect();
    let plan = AllocationPlan {
        strategy,
        solver: fleet.solver,
        instances,
        hourly_cost: fleet.hourly_cost,
        // Overflow placement ignores region choice, so it models no
        // transfer charges.
        transfer_rate: Dollars::ZERO,
        // A best-effort overflow placement is not a solve: no bound.
        lower_bound: None,
    };
    (plan, unserved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::config::paper_scenario;
    use crate::coordinator::Coordinator;
    use crate::manager::{ResourceManager, Strategy};
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};

    fn plan_for(streams: &[StreamSpec]) -> AllocationPlan {
        let c = Coordinator::new();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        mgr.allocate(streams, Strategy::St3).unwrap()
    }

    #[test]
    fn identical_plans_need_no_actions() {
        let s = paper_scenario(1).unwrap();
        let plan = plan_for(&s.streams);
        let r = plan_transition(&plan, &plan);
        assert_eq!(r.provisioned, 0);
        assert_eq!(r.terminated, 0);
        assert!(r.kept > 0);
        assert_eq!(r.hourly_delta, Dollars::ZERO);
        assert!(!worth_reallocating(&r, &plan, true, 12.0, 0.5));
    }

    #[test]
    fn scale_up_provisions_and_reuses() {
        // Normal ops (3 ZF @0.2) -> emergency (10 ZF @1.0).
        let small = plan_for(&StreamSpec::replicate(0, 3, VGA, Program::Zf, 0.2));
        let big = plan_for(&StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0));
        let r = plan_transition(&small, &big);
        assert!(r.provisioned > 0 || r.hourly_delta > Dollars::ZERO);
        assert_eq!(r.terminated + r.kept, small.instances.len() as u32);
        // Scale-up is always worth it: the small fleet cannot serve the
        // emergency workload (performance at stake).
        if r.provisioned + r.terminated > 0 {
            assert!(worth_reallocating(&r, &small, false, 1.0, 0.9));
        }
    }

    #[test]
    fn scale_down_terminates_surplus() {
        let big = plan_for(&StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0));
        let small = plan_for(&StreamSpec::replicate(0, 3, VGA, Program::Zf, 0.2));
        let r = plan_transition(&big, &small);
        assert!(r.terminated > 0);
        assert!(r.hourly_delta < Dollars::ZERO);
        // The big fleet still serves the small workload, so the move is
        // discretionary: worth it over a long horizon...
        assert!(worth_reallocating(&r, &big, true, 24.0, 0.5));
        // ...but not for the last sliver of an almost-over emergency.
        assert!(!worth_reallocating(&r, &big, true, 0.01, 0.99));
    }

    #[test]
    fn infeasible_current_fleet_forces_reallocation_even_when_cheaper() {
        let c = Coordinator::new();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        // Current fleet: CPU-only (ST1) for scenario-1-like demand —
        // four c4.2xlarge at $1.676/h.
        let mut old_streams = StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.25);
        old_streams.extend(StreamSpec::replicate(10, 3, VGA, Program::Zf, 0.55));
        let current = mgr.allocate(&old_streams, Strategy::St1).unwrap();
        assert_eq!(current.hourly_cost, Dollars::from_f64(1.676));
        // New workload: ZF at 2 FPS is CPU-unsustainable (max 0.56 FPS),
        // and its optimal plan — one g2.2xlarge at $0.650/h — is
        // *cheaper* than the current fleet.
        let new_streams = StreamSpec::replicate(0, 3, VGA, Program::Zf, 2.0);
        let target = mgr.allocate(&new_streams, Strategy::St3).unwrap();
        assert!(target.hourly_cost < current.hourly_cost);
        let serves = repack_onto(&mgr, &current, &new_streams, Strategy::St3).unwrap();
        assert!(serves.is_none(), "a CPU-only fleet cannot serve ZF at 2 FPS");
        let r = plan_transition(&current, &target);
        // Regression: the pre-fix gate used `hourly_delta > 0` as a
        // proxy for "workload grew"; with a cheaper target it fell into
        // the savings-vs-waste comparison and, over a short horizon,
        // refused to move a fleet that cannot serve the workload at
        // all.  Feasibility decides first now.
        assert!(worth_reallocating(&r, &current, false, 0.01, 0.99));
        // The same transition *is* suppressible when the fleet can
        // still serve (hypothetical flag): short horizon, high waste.
        assert!(!worth_reallocating(&r, &current, true, 0.01, 0.99));
    }

    #[test]
    fn repack_serves_shrunken_workload_without_churn() {
        let c = Coordinator::new();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        // Emergency fleet: 10 ZF @ 1.0 FPS -> two g2.2xlarge.
        let big = mgr
            .allocate(
                &StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0),
                Strategy::St3,
            )
            .unwrap();
        // Back to normal ops: the GPU fleet serves it on its own CPUs.
        let small_streams = StreamSpec::replicate(0, 3, VGA, Program::Zf, 0.2);
        let serving = repack_onto(&mgr, &big, &small_streams, Strategy::St3)
            .unwrap()
            .unwrap();
        let have = big.counts_by_type();
        for (t, n) in serving.counts_by_type() {
            assert!(have.get(&t).copied().unwrap_or(0) >= n, "{t}: {n}");
        }
        let placed: usize = serving.instances.iter().map(|i| i.streams.len()).sum();
        assert_eq!(placed, 3);
        // And the reverse direction is impossible without provisioning.
        let small = mgr.allocate(&small_streams, Strategy::St3).unwrap();
        let burst = StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0);
        assert!(repack_onto(&mgr, &small, &burst, Strategy::St3)
            .unwrap()
            .is_none());
    }

    #[test]
    fn repack_propagates_structural_errors() {
        // MissingProfile is a configuration error, not "cannot serve":
        // it must not silently force a reallocation.
        struct NoProfiles;
        impl crate::manager::ProfileSource for NoProfiles {
            fn profile_for(&self, _: &StreamSpec) -> Option<ResourceProfile> {
                None
            }
        }
        let c = Coordinator::new();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let streams = StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.2);
        let fleet = mgr.allocate(&streams, Strategy::St3).unwrap();
        let bad = ResourceManager::new(Catalog::paper_experiments(), &NoProfiles);
        assert!(matches!(
            repack_onto(&bad, &fleet, &streams, Strategy::St3),
            Err(AllocationError::MissingProfile(_))
        ));
    }

    #[test]
    fn best_effort_overcommits_rather_than_refusing() {
        let c = Coordinator::new();
        let catalog = Catalog::paper_experiments();
        let mgr = ResourceManager::new(catalog.clone(), &c);
        // Fleet: one c4.2xlarge (planned for a single light stream).
        let fleet = mgr
            .allocate(
                &StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.5),
                Strategy::St1,
            )
            .unwrap();
        assert_eq!(fleet.instances.len(), 1);
        // Burst: six such streams need 6 x 3.56 = 21.4 cores vs 7.2
        // usable — a clean packing refuses, best-effort overcommits.
        let streams = StreamSpec::replicate(0, 6, VGA, Program::Zf, 0.5);
        let profiles: Vec<ResourceProfile> =
            streams.iter().map(|s| c.profile_for(s)).collect();
        assert!(repack_onto(&mgr, &fleet, &streams, Strategy::St3)
            .unwrap()
            .is_none());
        let (plan, unserved) =
            assign_best_effort(&fleet, &streams, &profiles, Strategy::St3, &catalog, 0.9);
        assert!(unserved.is_empty());
        let placed: usize = plan.instances.iter().map(|i| i.streams.len()).sum();
        assert_eq!(placed, 6);
        let max_util = plan.instances[0]
            .utilization()
            .0
            .iter()
            .fold(0.0f64, |a, &b| a.max(b));
        assert!(max_util > 1.0, "overcommit expected, got {max_util}");
        // A stream with no latency-sustainable device anywhere in the
        // fleet is unserved: ZF at 2 FPS needs a GPU, the fleet has none.
        let fast = StreamSpec::replicate(0, 1, VGA, Program::Zf, 2.0);
        let fast_profiles: Vec<ResourceProfile> =
            fast.iter().map(|s| c.profile_for(s)).collect();
        let (plan2, unserved2) =
            assign_best_effort(&fleet, &fast, &fast_profiles, Strategy::St3, &catalog, 0.9);
        assert_eq!(unserved2, vec![0]);
        assert!(plan2.instances.iter().all(|i| i.streams.is_empty()));
    }

    /// A CPU-only workload whose certified bound is tight (two items of
    /// 3.56 cores per 7.2-core bin), so warm acceptance is exercised
    /// deterministically.
    fn tight_streams(n: u32) -> Vec<StreamSpec> {
        StreamSpec::replicate(0, n, VGA, Program::Zf, 0.5)
    }

    fn tight_manager(c: &Coordinator) -> ResourceManager<'_> {
        ResourceManager::new(Catalog::paper_experiments(), c)
    }

    #[test]
    fn incremental_repack_keeps_surviving_streams_in_place() {
        let c = Coordinator::new();
        let mgr = tight_manager(&c);
        let streams = tight_streams(4);
        let cold = mgr.allocate(&streams, Strategy::St1).unwrap();
        let built = mgr.build_problem(&streams, Strategy::St1).unwrap();
        let warm = repack_incremental(&built, &cold).expect("previous plan seeds itself");
        warm.solution.validate(&built.problem).unwrap();
        assert_eq!(warm.cost, cold.hourly_cost);
        assert_eq!(warm.solver, crate::packing::SolverKind::WarmStart);
        assert!(warm.lower_bound <= warm.cost);
        assert!(warm.gap().is_finite());
    }

    #[test]
    fn incremental_repack_consolidates_on_scale_down() {
        // Emergency fleet (2 x g2.2xlarge) shrinking to 3 quiet streams:
        // the GPU bins fall under the consolidation floor, dissolve, and
        // the delta reopens the cheapest feasible instance instead of
        // fossilizing the GPU fleet.
        let c = Coordinator::new();
        let mgr = tight_manager(&c);
        let big = mgr
            .allocate(&StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0), Strategy::St3)
            .unwrap();
        assert!(big.hourly_cost >= Dollars::from_f64(1.300));
        let quiet = StreamSpec::replicate(100, 3, VGA, Program::Zf, 0.2);
        let built = mgr.build_problem(&quiet, Strategy::St3).unwrap();
        let warm = repack_incremental(&built, &big).unwrap();
        warm.solution.validate(&built.problem).unwrap();
        // One c4.2xlarge serves the quiet workload: the warm plan must
        // shrink to it, not hold two GPU instances.
        assert_eq!(warm.cost, Dollars::from_f64(0.419));
    }

    #[test]
    fn incremental_repack_aggregates_high_multiplicity_deltas() {
        // 4 surviving streams plus a 16-stream burst of the same class:
        // the delta collapses to one multiplicity class (the aggregated
        // packer runs) and still lands on the cold-optimal fleet.
        let c = Coordinator::new();
        let mgr = tight_manager(&c);
        let cold_small = mgr.allocate(&tight_streams(4), Strategy::St1).unwrap();
        let burst = tight_streams(20);
        let built = mgr.build_problem(&burst, Strategy::St1).unwrap();
        let warm = repack_incremental(&built, &cold_small).unwrap();
        warm.solution.validate(&built.problem).unwrap();
        let cold_big = mgr.allocate(&burst, Strategy::St1).unwrap();
        assert_eq!(warm.cost, cold_big.hourly_cost);
        assert_eq!(warm.solver, crate::packing::SolverKind::WarmStart);
        assert!(warm.lower_bound <= warm.cost);
    }

    #[test]
    fn incremental_repack_rejects_unknown_bin_types() {
        let c = Coordinator::new();
        let mgr = tight_manager(&c);
        let streams = tight_streams(2);
        let mut plan = mgr.allocate(&streams, Strategy::St1).unwrap();
        plan.instances[0].type_name = "decommissioned.4xlarge".into();
        let built = mgr.build_problem(&streams, Strategy::St1).unwrap();
        assert!(repack_incremental(&built, &plan).is_none());
    }

    #[test]
    fn type_change_counts_both_actions() {
        // CPU-heavy plan -> GPU-heavy plan swaps instance types.
        let cpu_plan = plan_for(&StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.3));
        let gpu_plan = plan_for(&StreamSpec::replicate(0, 6, VGA, Program::Zf, 3.0));
        let r = plan_transition(&cpu_plan, &gpu_plan);
        let kinds: Vec<_> = r.actions.iter().collect();
        assert!(!kinds.is_empty());
        // Every current instance is either kept or terminated.
        assert_eq!(r.kept + r.terminated, cpu_plan.instances.len() as u32);
    }
}
