//! Reallocation: adapting a running fleet to a changed workload.
//!
//! The paper's motivation (§1) is bursty demand — "the analysis is
//! needed occasionally (e.g., during emergencies)" — which implies the
//! manager re-solves as cameras/rates change.  A fresh MVBP solve gives
//! the cost-optimal *target* fleet; this module computes the cheapest
//! transition from the currently provisioned fleet:
//!
//! * instances whose type still appears in the target plan are
//!   **reused** (streams may be re-assigned — streams are stateless,
//!   so moving one costs nothing);
//! * surplus instances are **terminated**;
//! * missing instances are **provisioned** (paying cloud boot latency
//!   and a fresh billed hour).
//!
//! Because bins of one type are interchangeable, matching by type
//! count is optimal for any transition-cost function that is monotone
//! in the number of provision/terminate actions.

use super::plan::AllocationPlan;
use crate::types::Dollars;
use std::collections::BTreeMap;

/// One step of a fleet transition.
#[derive(Clone, Debug, PartialEq)]
pub enum TransitionAction {
    /// Keep `count` already-running instances of `type_name`.
    Keep { type_name: String, count: u32 },
    /// Provision `count` new instances of `type_name`.
    Provision { type_name: String, count: u32 },
    /// Terminate `count` instances of `type_name`.
    Terminate { type_name: String, count: u32 },
}

/// A reallocation: the target plan plus the cheapest transition to it.
#[derive(Clone, Debug)]
pub struct Reallocation {
    pub actions: Vec<TransitionAction>,
    /// Instances kept running (no churn).
    pub kept: u32,
    pub provisioned: u32,
    pub terminated: u32,
    /// Hourly cost delta (target - current).
    pub hourly_delta: Dollars,
}

/// Compute the transition from `current` to `target` by type matching.
pub fn plan_transition(current: &AllocationPlan, target: &AllocationPlan) -> Reallocation {
    let cur = current.counts_by_type();
    let tgt = target.counts_by_type();
    let mut actions = Vec::new();
    let mut kept = 0;
    let mut provisioned = 0;
    let mut terminated = 0;

    let all_types: std::collections::BTreeSet<&String> = cur.keys().chain(tgt.keys()).collect();
    for type_name in all_types {
        let have = *cur.get(type_name).unwrap_or(&0);
        let want = *tgt.get(type_name).unwrap_or(&0);
        let keep = have.min(want);
        if keep > 0 {
            kept += keep;
            actions.push(TransitionAction::Keep { type_name: type_name.clone(), count: keep });
        }
        if want > have {
            provisioned += want - have;
            actions.push(TransitionAction::Provision {
                type_name: type_name.clone(),
                count: want - have,
            });
        } else if have > want {
            terminated += have - want;
            actions.push(TransitionAction::Terminate {
                type_name: type_name.clone(),
                count: have - want,
            });
        }
    }
    Reallocation {
        actions,
        kept,
        provisioned,
        terminated,
        hourly_delta: target.hourly_cost - current.hourly_cost,
    }
}

/// Hysteresis policy: is a reallocation *worth it*?
///
/// Terminating mid-hour wastes the remainder of a billed hour, so a
/// cheaper target plan only pays off if the saving over the planning
/// horizon exceeds the churn waste.  `wasted_fraction` is the mean
/// unused fraction of the current billing hour (0.5 if unknown).
pub fn worth_reallocating(
    realloc: &Reallocation,
    current: &AllocationPlan,
    horizon_hours: f64,
    wasted_fraction: f64,
) -> bool {
    if realloc.provisioned == 0 && realloc.terminated == 0 {
        return false; // same fleet, nothing to do
    }
    if realloc.hourly_delta > Dollars::ZERO {
        return true; // workload grew: must scale up regardless of cost
    }
    // Scale-down: compare horizon savings vs wasted billed time.
    let saving = -realloc.hourly_delta.as_f64() * horizon_hours;
    let mut waste_per_terminated: BTreeMap<&str, f64> = BTreeMap::new();
    for inst in &current.instances {
        waste_per_terminated
            .entry(inst.type_name.as_str())
            .or_insert(inst.hourly_cost.as_f64() * wasted_fraction);
    }
    let waste: f64 = realloc
        .actions
        .iter()
        .filter_map(|a| match a {
            TransitionAction::Terminate { type_name, count } => Some(
                waste_per_terminated.get(type_name.as_str()).unwrap_or(&0.0) * *count as f64,
            ),
            _ => None,
        })
        .sum();
    saving > waste
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::config::paper_scenario;
    use crate::coordinator::Coordinator;
    use crate::manager::{ResourceManager, Strategy};
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};

    fn plan_for(streams: &[StreamSpec]) -> AllocationPlan {
        let c = Coordinator::new();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        mgr.allocate(streams, Strategy::St3).unwrap()
    }

    #[test]
    fn identical_plans_need_no_actions() {
        let s = paper_scenario(1).unwrap();
        let plan = plan_for(&s.streams);
        let r = plan_transition(&plan, &plan);
        assert_eq!(r.provisioned, 0);
        assert_eq!(r.terminated, 0);
        assert!(r.kept > 0);
        assert_eq!(r.hourly_delta, Dollars::ZERO);
        assert!(!worth_reallocating(&r, &plan, 12.0, 0.5));
    }

    #[test]
    fn scale_up_provisions_and_reuses() {
        // Normal ops (3 ZF @0.2) -> emergency (10 ZF @1.0).
        let small = plan_for(&StreamSpec::replicate(0, 3, VGA, Program::Zf, 0.2));
        let big = plan_for(&StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0));
        let r = plan_transition(&small, &big);
        assert!(r.provisioned > 0 || r.hourly_delta > Dollars::ZERO);
        assert_eq!(r.terminated + r.kept, small.instances.len() as u32);
        // Scale-up is always worth it (performance at stake).
        if r.provisioned + r.terminated > 0 {
            assert!(worth_reallocating(&r, &small, 1.0, 0.9));
        }
    }

    #[test]
    fn scale_down_terminates_surplus() {
        let big = plan_for(&StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0));
        let small = plan_for(&StreamSpec::replicate(0, 3, VGA, Program::Zf, 0.2));
        let r = plan_transition(&big, &small);
        assert!(r.terminated > 0);
        assert!(r.hourly_delta < Dollars::ZERO);
        // Worth it over a long horizon...
        assert!(worth_reallocating(&r, &big, 24.0, 0.5));
        // ...but not for the last sliver of an almost-over emergency.
        assert!(!worth_reallocating(&r, &big, 0.01, 0.99));
    }

    #[test]
    fn type_change_counts_both_actions() {
        // CPU-heavy plan -> GPU-heavy plan swaps instance types.
        let cpu_plan = plan_for(&StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.3));
        let gpu_plan = plan_for(&StreamSpec::replicate(0, 6, VGA, Program::Zf, 3.0));
        let r = plan_transition(&cpu_plan, &gpu_plan);
        let kinds: Vec<_> = r.actions.iter().collect();
        assert!(!kinds.is_empty());
        // Every current instance is either kept or terminated.
        assert_eq!(r.kept + r.terminated, cpu_plan.instances.len() as u32);
    }
}
