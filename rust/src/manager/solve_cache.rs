//! Cross-epoch solve memoization for trace-driven autoscaling.
//!
//! The built-in traces (diurnal above all) replay a small set of load
//! levels over and over: hour 3 of day 2 poses the *same* MVBP
//! instance as hour 3 of day 1, yet the reactive policy's periodic
//! cold refresh re-solves it from scratch.  The [`SolveCache`] closes
//! that loop: each cold solve's plan is stored under an
//! order-independent fingerprint of the aggregated problem
//! ([`crate::packing::problem_fingerprint`]) plus the solve
//! configuration ([`solve_key`]), and a later epoch whose problem
//! fingerprints identically replays the cached plan instead of
//! searching again.
//!
//! A replay is **validated structurally before it is trusted**: every
//! cached instance must resolve to a current bin type (same name,
//! price, and physical capacity), every cached assignment to a current
//! stream (by id) and a current requirement choice (same device, same
//! bit-identical requirement vector), no stream may appear twice, and
//! the reconstructed packing must pass `Solution::validate` against
//! the *current* problem with its total rate equal to the cached
//! plan's.  Anything less — a stale catalog, churned stream ids, a
//! fingerprint collision — rejects the entry (evicting it) and falls
//! back to the cold solve, so a hit can only ever reproduce what the
//! cold solve would have produced.  Multi-region gated catalogs
//! usually fail the gate-dimension validation and simply run cold:
//! the cache targets the flat-pricing traces where epochs genuinely
//! repeat.
//!
//! Hit/miss/reject counts live on the cache (surfaced in the epochs
//! table) and in the `profiling` registry as `cache:hit` /
//! `cache:miss` / `cache:reject` event counters.

use super::plan::{plan_from_json, plan_to_json, truncated};
use super::{AllocationPlan, BuiltProblem, Strategy};
use crate::packing::{
    problem_fingerprint, MvbpProblem, PackedBin, Solution, SolveBudget, SolverChoice,
};
use crate::streams::StreamSpec;
use crate::util::error::{anyhow, ensure, Result};
use crate::util::json::Json;
use crate::util::profiling;
use std::collections::HashMap;

/// `--solve-cache-file` format version.
const FILE_VERSION: u64 = 1;

/// Cache key: the problem fingerprint (two independent 64-bit digests)
/// plus a digest of the solve configuration, so runs with different
/// strategies, solver routings, or budgets never share entries.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct SolveKey(u64, u64, u64);

/// Build the cache key for one solve of `problem` under the given
/// strategy, solver routing, and the budget fields that change which
/// solution a solve returns (`exact_cutoff` routes, `node_budget` caps
/// the proof; wall-clock fields are excluded — they only matter on
/// runs that were never deterministic to begin with).
pub fn solve_key(
    problem: &MvbpProblem,
    strategy: Strategy,
    solver: SolverChoice,
    budget: &SolveBudget,
) -> SolveKey {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut tag: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            tag ^= b as u64;
            tag = tag.wrapping_mul(FNV_PRIME);
        }
    };
    eat(strategy.to_string().as_bytes());
    eat(solver.to_string().as_bytes());
    eat(&(budget.exact_cutoff as u64).to_le_bytes());
    eat(&budget.node_budget.to_le_bytes());
    let (a, b) = problem_fingerprint(problem);
    SolveKey(a, b, tag)
}

/// Bounded LRU of cold-solve plans, keyed by [`SolveKey`].
pub struct SolveCache {
    /// Most-recently-used first.
    entries: Vec<(SolveKey, AllocationPlan)>,
    cap: usize,
    pub hits: u64,
    pub misses: u64,
    /// Lookups whose entry failed replay validation (stale catalog,
    /// churned ids, fingerprint collision) — evicted, solved cold.
    pub rejects: u64,
}

impl SolveCache {
    pub fn new(cap: usize) -> SolveCache {
        SolveCache { entries: Vec::new(), cap: cap.max(1), hits: 0, misses: 0, rejects: 0 }
    }

    /// Look up `key` and replay its plan against the *current* epoch's
    /// built problem.  `None` means miss or failed validation (the
    /// entry is evicted in the latter case): run the cold solve.
    pub fn replay(
        &mut self,
        key: SolveKey,
        built: &BuiltProblem,
        streams: &[StreamSpec],
        strategy: Strategy,
    ) -> Option<AllocationPlan> {
        let pos = match self.entries.iter().position(|(k, _)| *k == key) {
            Some(pos) => pos,
            None => {
                self.misses += 1;
                profiling::bump("cache:miss");
                return None;
            }
        };
        let (key, cached) = self.entries.remove(pos);
        match rebuild(&cached, built, streams, strategy) {
            Some(plan) => {
                // Validated: move to front and replay.
                self.entries.insert(0, (key, cached));
                self.hits += 1;
                profiling::bump("cache:hit");
                Some(plan)
            }
            None => {
                // Poisoned (stale catalog / churned ids / collision):
                // the entry stays evicted and the epoch solves cold.
                self.rejects += 1;
                profiling::bump("cache:reject");
                None
            }
        }
    }

    /// Store a cold solve's plan under `key`, replacing any existing
    /// entry and evicting the least-recently-used past the cap.
    pub fn insert(&mut self, key: SolveKey, plan: AllocationPlan) {
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(pos);
        }
        self.entries.insert(0, (key, plan));
        self.entries.truncate(self.cap);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize the cache for `--solve-cache-file`, entries MRU-first.
    /// Key digests travel as 16-hex-digit strings — they are full u64s,
    /// which a JSON f64 number cannot carry exactly past 2^53.  The
    /// runtime hit/miss/reject counters are not persisted.
    pub fn to_json(&self) -> Json {
        let entries = self.entries.iter().map(|(key, plan)| {
            let digests = [key.0, key.1, key.2]
                .iter()
                .map(|d| Json::Str(format!("{d:016x}")))
                .collect::<Vec<_>>();
            Json::obj(vec![
                ("key".to_string(), Json::arr(digests)),
                ("plan".to_string(), plan_to_json(plan)),
            ])
        });
        Json::obj(vec![
            ("version".to_string(), Json::Num(FILE_VERSION as f64)),
            ("entries".to_string(), Json::arr(entries)),
        ])
    }

    /// Load entries serialized by [`SolveCache::to_json`], preserving
    /// their MRU order (subject to this cache's cap).  Returns the
    /// number of entries loaded.  Loaded plans get no trust beyond
    /// in-memory ones: a hit still passes the full structural replay
    /// validation before it is used, so a corrupted or stale file can
    /// at worst cause cold solves, never a wrong plan.
    pub fn load_json(&mut self, j: &Json) -> Result<usize> {
        let version = j.u64_field("version")?;
        ensure!(version == FILE_VERSION, "unsupported solve-cache file version {version}");
        let entries = j.arr_field("entries")?;
        let mut loaded = Vec::with_capacity(entries.len());
        for entry in entries {
            let digests = entry.arr_field("key")?;
            ensure!(digests.len() == 3, "solve key must carry 3 digests");
            let mut parts = [0u64; 3];
            for (slot, d) in parts.iter_mut().zip(digests) {
                let hex = d.as_str().ok_or_else(|| anyhow!("solve key digest is not a string"))?;
                *slot = u64::from_str_radix(hex, 16)
                    .map_err(|e| anyhow!("bad solve key digest {hex:?}: {e}"))?;
            }
            let plan = plan_from_json(entry.field("plan")?)?;
            loaded.push((SolveKey(parts[0], parts[1], parts[2]), plan));
        }
        let count = loaded.len();
        // Inserting in reverse replays the file's MRU order: the
        // file's first (most recent) entry is inserted last and ends
        // up at the front.
        for (key, plan) in loaded.into_iter().rev() {
            self.insert(key, plan);
        }
        Ok(count)
    }
}

/// Re-express `cached` in terms of the current epoch's problem and
/// stream list, validating every structural assumption along the way
/// (see the module docs for the full checklist).  `None` = reject.
fn rebuild(
    cached: &AllocationPlan,
    built: &BuiltProblem,
    streams: &[StreamSpec],
    strategy: Strategy,
) -> Option<AllocationPlan> {
    let problem = &built.problem;
    let dims = built.layout.dims();
    let index_of: HashMap<String, usize> = streams
        .iter()
        .enumerate()
        .map(|(i, s)| (s.id(), i))
        .collect();
    let mut used = vec![false; problem.items.len()];
    let mut bins = Vec::with_capacity(cached.instances.len());
    for inst in &cached.instances {
        // Same catalog entry: name, price, and physical capacity must
        // all still match.
        let bin_type = problem.bin_types.iter().position(|bt| {
            bt.name == inst.type_name
                && bt.cost == inst.hourly_cost
                && truncated(&bt.capacity, dims) == inst.capacity
        })?;
        let mut assignments = Vec::with_capacity(inst.streams.len());
        for a in &inst.streams {
            let item = *index_of.get(&a.stream_id)?;
            if used[item] {
                return None;
            }
            used[item] = true;
            // Same requirement choice: device and bit-identical
            // physical requirement vector.
            let choice = (0..problem.items[item].choices.len()).find(|&c| {
                built.choice_map[item][c] == a.choice
                    && truncated(&problem.items[item].choices[c], dims) == a.requirement
            })?;
            assignments.push((item, choice));
        }
        bins.push(PackedBin { bin_type, assignments });
    }
    if !used.iter().all(|u| *u) {
        return None; // cached plan does not cover this epoch's fleet
    }
    let solution = Solution { bins };
    solution.validate(problem).ok()?;
    let mut plan = AllocationPlan::from_solution(built, &solution, streams, strategy, cached.solver);
    if plan.total_rate() != cached.total_rate() {
        return None; // choice resolution drifted (e.g. region transfer)
    }
    // The problems fingerprint identically, so the cached certificate
    // transfers; the clamp keeps the gap in [0, 1] even under an
    // (astronomically unlikely) fingerprint collision.
    plan.lower_bound = cached.lower_bound.map(|lb| lb.min(plan.total_rate()));
    Some(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::manager::ResourceManager;
    use crate::profiler::calibration::Calibration;
    use crate::types::{Program, VGA};

    fn fleet() -> Vec<StreamSpec> {
        let mut v = StreamSpec::replicate(0, 2, VGA, Program::Vgg16, 0.20);
        v.extend(StreamSpec::replicate(10, 2, VGA, Program::Zf, 0.50));
        v
    }

    #[test]
    fn hit_replays_a_cost_equal_plan_and_miss_precedes_it() {
        let cal = Calibration::paper();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &cal);
        let streams = fleet();
        let strategy = Strategy::St3;
        let built = mgr.build_problem(&streams, strategy).unwrap();
        let plan = mgr.allocate(&streams, strategy).unwrap();
        let key = solve_key(&built.problem, strategy, mgr.solver, &mgr.budget);

        let mut cache = SolveCache::new(8);
        assert!(cache.replay(key, &built, &streams, strategy).is_none());
        assert_eq!((cache.hits, cache.misses), (0, 1));
        cache.insert(key, plan.clone());

        // The identical epoch replays the identical plan.
        let replayed = cache.replay(key, &built, &streams, strategy).expect("cache hit");
        assert_eq!(replayed, plan);
        assert_eq!(cache.hits, 1);

        // A later epoch enumerating the same fleet in reverse order
        // fingerprints identically and replays a cost-equal plan with
        // correctly remapped stream indices.
        let mut reversed = streams.clone();
        reversed.reverse();
        let built2 = mgr.build_problem(&reversed, strategy).unwrap();
        let key2 = solve_key(&built2.problem, strategy, mgr.solver, &mgr.budget);
        assert_eq!(key, key2, "fingerprint must be item-order independent");
        let remapped = cache.replay(key2, &built2, &reversed, strategy).expect("cache hit");
        assert_eq!(remapped.total_rate(), plan.total_rate());
        assert_eq!(remapped.lower_bound, plan.lower_bound);
        for inst in &remapped.instances {
            for a in &inst.streams {
                assert_eq!(reversed[a.stream_index].id(), a.stream_id);
            }
        }
    }

    #[test]
    fn poisoned_entry_is_rejected_and_evicted() {
        let cal = Calibration::paper();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &cal);
        let streams = fleet();
        let strategy = Strategy::St3;
        let built = mgr.build_problem(&streams, strategy).unwrap();
        let plan = mgr.allocate(&streams, strategy).unwrap();
        let key = solve_key(&built.problem, strategy, mgr.solver, &mgr.budget);

        // A stale-catalog entry: the cached plan references an instance
        // type that no longer exists.
        let mut poisoned = plan.clone();
        poisoned.instances[0].type_name = "retired-type".into();
        let mut cache = SolveCache::new(8);
        cache.insert(key, poisoned);
        assert!(cache.replay(key, &built, &streams, strategy).is_none());
        assert_eq!(cache.rejects, 1);
        assert!(cache.is_empty(), "a rejected entry must be evicted");

        // A plan that no longer covers the fleet (stream id churn) is
        // rejected the same way.
        let mut stale = plan.clone();
        stale.instances[0].streams[0].stream_id = "cam-gone".into();
        cache.insert(key, stale);
        assert!(cache.replay(key, &built, &streams, strategy).is_none());
        assert_eq!(cache.rejects, 2);
    }

    #[test]
    fn cache_round_trips_through_json_and_replayed_hits_match() {
        let cal = Calibration::paper();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &cal);
        let streams = fleet();
        let strategy = Strategy::St3;
        let built = mgr.build_problem(&streams, strategy).unwrap();
        let plan = mgr.allocate(&streams, strategy).unwrap();
        let key = solve_key(&built.problem, strategy, mgr.solver, &mgr.budget);

        let mut cache = SolveCache::new(8);
        cache.insert(key, plan.clone());

        // Through text and back (exactly what --solve-cache-file does).
        let text = cache.to_json().to_compact();
        let mut restored = SolveCache::new(8);
        let loaded = restored.load_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(loaded, 1);
        assert_eq!(restored.len(), 1);

        // A hit from the restored cache replays the identical plan —
        // and went through the same structural validation as any
        // in-memory hit.
        let replayed = restored.replay(key, &built, &streams, strategy).expect("cache hit");
        assert_eq!(replayed, plan);

        // A stale loaded entry is still subject to replay validation:
        // poison the plan in the serialized form and the hit degrades
        // to a reject, never a wrong plan.
        let mut j = cache.to_json();
        if let Json::Obj(map) = &mut j {
            if let Some(Json::Arr(entries)) = map.get_mut("entries") {
                if let Json::Obj(entry) = &mut entries[0] {
                    if let Some(Json::Obj(p)) = entry.get_mut("plan") {
                        if let Some(Json::Arr(insts)) = p.get_mut("instances") {
                            if let Json::Obj(inst) = &mut insts[0] {
                                inst.insert(
                                    "type_name".to_string(),
                                    Json::Str("retired-type".to_string()),
                                );
                            }
                        }
                    }
                }
            }
        }
        let mut poisoned = SolveCache::new(8);
        assert_eq!(poisoned.load_json(&j).unwrap(), 1);
        assert!(poisoned.replay(key, &built, &streams, strategy).is_none());
        assert_eq!(poisoned.rejects, 1);
        assert!(poisoned.is_empty(), "rejected loaded entries are evicted");

        // Unsupported versions and malformed keys fail loudly.
        let stale = Json::parse("{\"version\":99,\"entries\":[]}").unwrap();
        assert!(SolveCache::new(8).load_json(&stale).is_err());
    }

    #[test]
    fn mru_order_survives_persistence() {
        let cal = Calibration::paper();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &cal);
        let streams = fleet();
        let strategy = Strategy::St3;
        let built = mgr.build_problem(&streams, strategy).unwrap();
        let plan = mgr.allocate(&streams, strategy).unwrap();
        let key_a = solve_key(&built.problem, strategy, mgr.solver, &mgr.budget);
        let mut tight = mgr.budget;
        tight.node_budget /= 2;
        let key_b = solve_key(&built.problem, strategy, mgr.solver, &tight);

        let mut cache = SolveCache::new(8);
        cache.insert(key_a, plan.clone());
        cache.insert(key_b, plan.clone()); // b is now most recent

        // Restore into a cap-1 cache: only the file's MRU entry fits.
        let mut small = SolveCache::new(1);
        small.load_json(&cache.to_json()).unwrap();
        assert_eq!(small.len(), 1);
        assert!(small.replay(key_b, &built, &streams, strategy).is_some());
        assert!(small.replay(key_a, &built, &streams, strategy).is_none());
    }

    #[test]
    fn lru_evicts_past_the_cap_and_different_budgets_never_share_keys() {
        let cal = Calibration::paper();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &cal);
        let streams = fleet();
        let strategy = Strategy::St3;
        let built = mgr.build_problem(&streams, strategy).unwrap();
        let plan = mgr.allocate(&streams, strategy).unwrap();
        let key = solve_key(&built.problem, strategy, mgr.solver, &mgr.budget);

        let mut tight = mgr.budget;
        tight.node_budget /= 2;
        let other = solve_key(&built.problem, strategy, mgr.solver, &tight);
        assert_ne!(key, other, "budget class must be part of the key");

        let mut cache = SolveCache::new(1);
        cache.insert(key, plan.clone());
        cache.insert(other, plan);
        assert_eq!(cache.len(), 1, "cap must bound the cache");
        // The older entry was evicted.
        assert!(cache.replay(key, &built, &streams, strategy).is_none());
    }
}
