//! What-if cost exploration: the paper's trade-off (§3, Fig. 2 goal II)
//! quantified — how does the hourly cost move with desired frame rate,
//! stream count, or strategy?
//!
//! Used by the `camcloud whatif` CLI and the ablation analysis; also a
//! practical operator tool ("what does doubling the rate cost me?").

use super::{AllocationError, ResourceManager, Strategy};
use crate::streams::StreamSpec;
use crate::types::Dollars;

/// One point of a cost curve.
#[derive(Clone, Debug)]
pub struct CostPoint {
    /// The swept parameter value (fps multiplier or stream count).
    pub x: f64,
    /// Hourly cost, or None where allocation fails.
    pub cost: Option<Dollars>,
    pub instances: usize,
}

/// Classify one allocation attempt for a sweep: a plan, a genuine
/// rate-infeasibility, or a *structural* error (missing profile, empty
/// catalog, solver failure) that must abort the sweep instead of being
/// misreported as an infeasible point.
fn sweep_point(
    manager: &ResourceManager<'_>,
    streams: &[StreamSpec],
    strategy: Strategy,
    x: f64,
) -> Result<CostPoint, AllocationError> {
    match manager.allocate(streams, strategy) {
        Ok(plan) => Ok(CostPoint {
            x,
            cost: Some(plan.hourly_cost),
            instances: plan.instances.len(),
        }),
        Err(AllocationError::Infeasible { .. }) => Ok(CostPoint { x, cost: None, instances: 0 }),
        Err(other) => Err(other),
    }
}

fn scaled(base: &[StreamSpec], mult: f64) -> Vec<StreamSpec> {
    base.iter()
        .map(|s| {
            let mut s2 = s.clone();
            s2.desired_fps *= mult;
            s2
        })
        .collect()
}

/// Sweep a frame-rate multiplier over a base workload.
///
/// Every stream's desired fps is scaled by each multiplier; the curve
/// shows where rates become infeasible for a strategy (e.g. ST1 hits
/// the CPU's max achievable rate — the paper's scenario 3 cliff).
/// Only [`AllocationError::Infeasible`] becomes a `cost: None` point;
/// any other error propagates.
pub fn sweep_rate_multiplier(
    manager: &ResourceManager<'_>,
    base: &[StreamSpec],
    strategy: Strategy,
    multipliers: &[f64],
) -> Result<Vec<CostPoint>, AllocationError> {
    multipliers
        .iter()
        .map(|&mult| sweep_point(manager, &scaled(base, mult), strategy, mult))
        .collect()
}

/// Sweep the number of identical streams (camera-count scaling).
pub fn sweep_stream_count(
    manager: &ResourceManager<'_>,
    template: &StreamSpec,
    strategy: Strategy,
    counts: &[u32],
) -> Result<Vec<CostPoint>, AllocationError> {
    counts
        .iter()
        .map(|&n| {
            let streams = StreamSpec::replicate(
                0,
                n,
                template.camera.frame_size,
                template.program,
                template.desired_fps,
            );
            sweep_point(manager, &streams, strategy, n as f64)
        })
        .collect()
}

/// The rate multiplier at which a strategy first fails (binary search
/// over a bracket), or `Ok(None)` if it never fails in the bracket.
///
/// Only [`AllocationError::Infeasible`] counts as the cliff; structural
/// errors (missing profile, empty catalog) propagate instead of being
/// reported as a bogus cliff at `lo`.
pub fn feasibility_cliff(
    manager: &ResourceManager<'_>,
    base: &[StreamSpec],
    strategy: Strategy,
    lo: f64,
    hi: f64,
) -> Result<Option<f64>, AllocationError> {
    let feasible = |mult: f64| -> Result<bool, AllocationError> {
        match manager.allocate(&scaled(base, mult), strategy) {
            Ok(_) => Ok(true),
            Err(AllocationError::Infeasible { .. }) => Ok(false),
            Err(other) => Err(other),
        }
    };
    if feasible(hi)? {
        return Ok(None);
    }
    if !feasible(lo)? {
        return Ok(Some(lo));
    }
    let (mut lo, mut hi) = (lo, hi);
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if feasible(mid)? {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(Some(hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::coordinator::Coordinator;
    use crate::streams::Camera;
    use crate::types::{Program, VGA};

    fn fixture() -> (Coordinator, Vec<StreamSpec>) {
        let c = Coordinator::new();
        let base = vec![StreamSpec::new(Camera::new(0, VGA), Program::Zf, 0.2)];
        (c, base)
    }

    #[test]
    fn cost_is_monotone_in_rate() {
        let (c, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let curve = sweep_rate_multiplier(&mgr, &base, Strategy::St3, &[1.0, 5.0, 20.0, 40.0])
            .unwrap();
        let costs: Vec<f64> = curve.iter().map(|p| p.cost.unwrap().as_f64()).collect();
        for w in costs.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "costs {costs:?}");
        }
    }

    #[test]
    fn st1_cliff_is_the_cpu_max_rate() {
        // ZF base at 0.2 fps; CPU max is 0.56 -> cliff multiplier ~2.8.
        let (c, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let cliff = feasibility_cliff(&mgr, &base, Strategy::St1, 1.0, 10.0)
            .unwrap()
            .unwrap();
        assert!((cliff - 2.8).abs() < 0.05, "cliff {cliff}");
        // ST3 survives the same bracket (GPU path).
        assert!(feasibility_cliff(&mgr, &base, Strategy::St3, 1.0, 10.0)
            .unwrap()
            .is_none());
    }

    #[test]
    fn stream_count_sweep_scales_instances() {
        let (c, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let curve = sweep_stream_count(&mgr, &base[0], Strategy::St1, &[1, 4, 16]).unwrap();
        assert!(curve.iter().all(|p| p.cost.is_some()));
        assert!(curve[2].instances >= curve[0].instances);
    }

    #[test]
    fn infeasible_points_reported_not_panicked() {
        let (c, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let curve = sweep_rate_multiplier(&mgr, &base, Strategy::St1, &[1.0, 100.0]).unwrap();
        assert!(curve[0].cost.is_some());
        assert!(curve[1].cost.is_none());
    }

    #[test]
    fn structural_errors_propagate_instead_of_reporting_a_cliff() {
        // Regression: a profile-less manager fails every allocation with
        // MissingProfile.  Pre-fix, feasibility_cliff conflated that with
        // rate-infeasibility and reported a bogus cliff at `lo`, and the
        // sweeps silently rendered every point as infeasible.
        struct NoProfiles;
        impl crate::manager::ProfileSource for NoProfiles {
            fn profile_for(&self, _: &StreamSpec) -> Option<crate::profiler::ResourceProfile> {
                None
            }
        }
        let (_, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &NoProfiles);
        assert!(matches!(
            feasibility_cliff(&mgr, &base, Strategy::St1, 1.0, 10.0),
            Err(AllocationError::MissingProfile(_))
        ));
        assert!(matches!(
            sweep_rate_multiplier(&mgr, &base, Strategy::St1, &[1.0, 2.0]),
            Err(AllocationError::MissingProfile(_))
        ));
        assert!(matches!(
            sweep_stream_count(&mgr, &base[0], Strategy::St1, &[1, 2]),
            Err(AllocationError::MissingProfile(_))
        ));
        // An empty catalog for the strategy is structural too.
        let c = Coordinator::new();
        let gpu_only = ResourceManager::new(Catalog::paper_experiments().gpu_only(), &c);
        assert!(matches!(
            feasibility_cliff(&gpu_only, &base, Strategy::St1, 1.0, 10.0),
            Err(AllocationError::EmptyCatalog(Strategy::St1))
        ));
    }
}
