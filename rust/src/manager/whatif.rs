//! What-if cost exploration: the paper's trade-off (§3, Fig. 2 goal II)
//! quantified — how does the hourly cost move with desired frame rate,
//! stream count, or strategy?
//!
//! Used by the `camcloud whatif` CLI and the ablation analysis; also a
//! practical operator tool ("what does doubling the rate cost me?").

use super::{AllocationError, ResourceManager, Strategy};
use crate::streams::StreamSpec;
use crate::types::Dollars;

/// One point of a cost curve.
#[derive(Clone, Debug)]
pub struct CostPoint {
    /// The swept parameter value (fps multiplier or stream count).
    pub x: f64,
    /// Hourly cost, or None where allocation fails.
    pub cost: Option<Dollars>,
    pub instances: usize,
}

/// Sweep a frame-rate multiplier over a base workload.
///
/// Every stream's desired fps is scaled by each multiplier; the curve
/// shows where rates become infeasible for a strategy (e.g. ST1 hits
/// the CPU's max achievable rate — the paper's scenario 3 cliff).
pub fn sweep_rate_multiplier(
    manager: &ResourceManager<'_>,
    base: &[StreamSpec],
    strategy: Strategy,
    multipliers: &[f64],
) -> Vec<CostPoint> {
    multipliers
        .iter()
        .map(|&mult| {
            let streams: Vec<StreamSpec> = base
                .iter()
                .map(|s| {
                    let mut s2 = s.clone();
                    s2.desired_fps *= mult;
                    s2
                })
                .collect();
            match manager.allocate(&streams, strategy) {
                Ok(plan) => CostPoint {
                    x: mult,
                    cost: Some(plan.hourly_cost),
                    instances: plan.instances.len(),
                },
                Err(AllocationError::Infeasible { .. }) => {
                    CostPoint { x: mult, cost: None, instances: 0 }
                }
                Err(_) => CostPoint { x: mult, cost: None, instances: 0 },
            }
        })
        .collect()
}

/// Sweep the number of identical streams (camera-count scaling).
pub fn sweep_stream_count(
    manager: &ResourceManager<'_>,
    template: &StreamSpec,
    strategy: Strategy,
    counts: &[u32],
) -> Vec<CostPoint> {
    counts
        .iter()
        .map(|&n| {
            let streams = StreamSpec::replicate(
                0,
                n,
                template.camera.frame_size,
                template.program,
                template.desired_fps,
            );
            match manager.allocate(&streams, strategy) {
                Ok(plan) => CostPoint {
                    x: n as f64,
                    cost: Some(plan.hourly_cost),
                    instances: plan.instances.len(),
                },
                Err(_) => CostPoint { x: n as f64, cost: None, instances: 0 },
            }
        })
        .collect()
}

/// The rate multiplier at which a strategy first fails (binary search
/// over a bracket), or None if it never fails in the bracket.
pub fn feasibility_cliff(
    manager: &ResourceManager<'_>,
    base: &[StreamSpec],
    strategy: Strategy,
    lo: f64,
    hi: f64,
) -> Option<f64> {
    let feasible = |mult: f64| {
        let streams: Vec<StreamSpec> = base
            .iter()
            .map(|s| {
                let mut s2 = s.clone();
                s2.desired_fps *= mult;
                s2
            })
            .collect();
        manager.allocate(&streams, strategy).is_ok()
    };
    if feasible(hi) {
        return None;
    }
    if !feasible(lo) {
        return Some(lo);
    }
    let (mut lo, mut hi) = (lo, hi);
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if feasible(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::coordinator::Coordinator;
    use crate::streams::Camera;
    use crate::types::{Program, VGA};

    fn fixture() -> (Coordinator, Vec<StreamSpec>) {
        let c = Coordinator::new();
        let base = vec![StreamSpec::new(Camera::new(0, VGA), Program::Zf, 0.2)];
        (c, base)
    }

    #[test]
    fn cost_is_monotone_in_rate() {
        let (c, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let curve = sweep_rate_multiplier(&mgr, &base, Strategy::St3, &[1.0, 5.0, 20.0, 40.0]);
        let costs: Vec<f64> = curve.iter().map(|p| p.cost.unwrap().as_f64()).collect();
        for w in costs.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "costs {costs:?}");
        }
    }

    #[test]
    fn st1_cliff_is_the_cpu_max_rate() {
        // ZF base at 0.2 fps; CPU max is 0.56 -> cliff multiplier ~2.8.
        let (c, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let cliff = feasibility_cliff(&mgr, &base, Strategy::St1, 1.0, 10.0).unwrap();
        assert!((cliff - 2.8).abs() < 0.05, "cliff {cliff}");
        // ST3 survives the same bracket (GPU path).
        assert!(feasibility_cliff(&mgr, &base, Strategy::St3, 1.0, 10.0).is_none());
    }

    #[test]
    fn stream_count_sweep_scales_instances() {
        let (c, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let curve = sweep_stream_count(&mgr, &base[0], Strategy::St1, &[1, 4, 16]);
        assert!(curve.iter().all(|p| p.cost.is_some()));
        assert!(curve[2].instances >= curve[0].instances);
    }

    #[test]
    fn infeasible_points_reported_not_panicked() {
        let (c, base) = fixture();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &c);
        let curve = sweep_rate_multiplier(&mgr, &base, Strategy::St1, &[1.0, 100.0]);
        assert!(curve[0].cost.is_some());
        assert!(curve[1].cost.is_none());
    }
}
