//! Allocation plans: the manager's output (Figure 2's decisions A–D).

use super::strategy::Strategy;
use super::BuiltProblem;
use crate::net::proto::{dollars_from_json, dollars_to_json};
use crate::packing::{Solution, SolveOutcome, SolverKind};
use crate::profiler::ExecChoice;
use crate::streams::StreamSpec;
use crate::types::{Dollars, ResourceVec};
use crate::util::error::{anyhow, ensure, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One stream placed on an instance.
#[derive(Clone, PartialEq, Debug)]
pub struct StreamAssignment {
    /// Index into the workload's stream list.
    pub stream_index: usize,
    pub stream_id: String,
    /// Which device analyzes the stream (decision D).
    pub choice: ExecChoice,
    /// The requirement vector the packing used.
    pub requirement: ResourceVec,
}

/// One instance to provision, with its assigned streams.
#[derive(Clone, PartialEq, Debug)]
pub struct PlannedInstance {
    /// Catalog type name (decision A).
    pub type_name: String,
    pub hourly_cost: Dollars,
    /// Usable (headroom-scaled) capacity the packing respected.
    pub capacity: ResourceVec,
    /// Streams analyzed by this instance (decision C).
    pub streams: Vec<StreamAssignment>,
}

impl PlannedInstance {
    /// Total requirement over assigned streams.
    pub fn load(&self) -> ResourceVec {
        let dims = self.capacity.dims();
        let mut load = ResourceVec::zeros(dims);
        for s in &self.streams {
            load.add_assign(&s.requirement);
        }
        load
    }

    /// Utilization per dimension against the *full* (unscaled) capacity
    /// would require the catalog; this reports against usable capacity.
    pub fn utilization(&self) -> ResourceVec {
        let load = self.load();
        ResourceVec(
            load.0
                .iter()
                .zip(&self.capacity.0)
                .map(|(l, c)| if *c > 0.0 { l / c } else { 0.0 })
                .collect(),
        )
    }
}

/// The manager's full output.  `PartialEq` is a full structural
/// comparison (assignments included) — the autoscale pipeline's
/// speculation-invalidation check relies on it detecting *any*
/// incumbent change, not just a shape change.
#[derive(Clone, PartialEq, Debug)]
pub struct AllocationPlan {
    pub strategy: Strategy,
    pub solver: SolverKind,
    pub instances: Vec<PlannedInstance>,
    pub hourly_cost: Dollars,
    /// Cross-region data-transfer rate ($/h) this placement incurs —
    /// the sum of per-assignment choice costs from the solve.  Zero
    /// under flat pricing or single-region catalogs.
    pub transfer_rate: Dollars,
    /// Certified cost lower bound from the solve that produced this
    /// plan (`None` for hand-built placements such as best-effort
    /// overflow or single-instance characterization runs).
    pub lower_bound: Option<Dollars>,
}

/// Drop trailing gate dimensions (region encoding) so plan vectors are
/// always in the catalog's physical resource layout.
pub(crate) fn truncated(v: &ResourceVec, dims: usize) -> ResourceVec {
    if v.dims() == dims {
        return v.clone();
    }
    let mut out = ResourceVec::zeros(dims);
    for d in 0..dims {
        out[d] = v[d];
    }
    out
}

impl AllocationPlan {
    /// Certified optimality gap `(hourly_cost - lower_bound) /
    /// hourly_cost`, finite and in `[0, 1]` whenever the plan carries a
    /// bound (same formula as [`SolveOutcome::gap`]).
    pub fn gap(&self) -> Option<f64> {
        let lb = self.lower_bound?;
        Some(crate::packing::solver::certified_gap(self.total_rate(), lb))
    }

    /// Full hourly burn rate: instance-hours plus cross-region
    /// transfer.  This is the quantity the solver's objective (and its
    /// certificate) covers, so gap/comparison logic uses it.
    pub fn total_rate(&self) -> Dollars {
        self.hourly_cost + self.transfer_rate
    }

    /// Map a certified solve outcome back into provisioning decisions.
    pub fn from_outcome(
        built: &BuiltProblem,
        outcome: &SolveOutcome,
        streams: &[StreamSpec],
        strategy: Strategy,
    ) -> AllocationPlan {
        let mut plan =
            AllocationPlan::from_solution(built, &outcome.solution, streams, strategy, outcome.solver);
        plan.lower_bound = Some(outcome.lower_bound.min(plan.total_rate()));
        plan
    }

    /// Map a bare packing solution back into provisioning decisions
    /// (no certificate attached — prefer [`AllocationPlan::from_outcome`]).
    pub fn from_solution(
        built: &BuiltProblem,
        solution: &Solution,
        streams: &[StreamSpec],
        strategy: Strategy,
        solver: SolverKind,
    ) -> AllocationPlan {
        let dims = built.layout.dims();
        let mut instances = Vec::with_capacity(solution.bins.len());
        let mut transfer_rate = Dollars::ZERO;
        for bin in &solution.bins {
            let bt = &built.problem.bin_types[bin.bin_type];
            let mut assignments = Vec::with_capacity(bin.assignments.len());
            for &(item, dense_choice) in &bin.assignments {
                transfer_rate = transfer_rate + built.problem.choice_cost(item, dense_choice);
                assignments.push(StreamAssignment {
                    stream_index: item,
                    stream_id: streams[item].id(),
                    choice: built.choice_map[item][dense_choice],
                    requirement: truncated(&built.problem.items[item].choices[dense_choice], dims),
                });
            }
            instances.push(PlannedInstance {
                type_name: bt.name.clone(),
                hourly_cost: bt.cost,
                capacity: truncated(&bt.capacity, dims),
                streams: assignments,
            });
        }
        let hourly_cost = instances.iter().map(|i| i.hourly_cost).sum();
        AllocationPlan { strategy, solver, instances, hourly_cost, transfer_rate, lower_bound: None }
    }

    /// `(non_gpu, gpu)` instance counts — Table 6's "Instances" columns.
    pub fn instance_counts(&self, catalog: &crate::cloud::Catalog) -> (u32, u32) {
        let mut non_gpu = 0;
        let mut gpu = 0;
        for inst in &self.instances {
            match catalog.resolve(&inst.type_name) {
                Some(off) if off.itype.has_gpu() => gpu += 1,
                Some(_) => non_gpu += 1,
                None => {}
            }
        }
        (non_gpu, gpu)
    }

    /// Instance counts per type name.
    pub fn counts_by_type(&self) -> BTreeMap<String, u32> {
        let mut counts = BTreeMap::new();
        for inst in &self.instances {
            *counts.entry(inst.type_name.clone()).or_insert(0) += 1;
        }
        counts
    }

    /// Instances listed in full before the summary elides the rest —
    /// fleet-scale plans (the solver stack packs million-stream fleets)
    /// must not render millions of report lines.
    const SUMMARY_MAX_INSTANCES: usize = 64;
    /// Streams listed per instance before eliding.
    const SUMMARY_MAX_STREAMS: usize = 16;

    /// Human-readable summary for CLI output.  Paper-scale plans print
    /// in full; fleet-scale plans elide past
    /// [`Self::SUMMARY_MAX_INSTANCES`] instances /
    /// [`Self::SUMMARY_MAX_STREAMS`] streams each with `(+N more)`
    /// markers instead of dumping the whole fleet.
    pub fn summary(&self) -> String {
        let gap = match self.gap() {
            Some(g) => format!("{:.1}%", g * 100.0),
            None => "-".to_string(),
        };
        let mut out = format!(
            "strategy {} | solver {} | gap {} | {} instance(s) | hourly cost {}\n",
            self.strategy,
            self.solver,
            gap,
            self.instances.len(),
            self.hourly_cost
        );
        for (i, inst) in self.instances.iter().enumerate() {
            if i == Self::SUMMARY_MAX_INSTANCES {
                out.push_str(&format!(
                    "  ... (+{} more instances)\n",
                    self.instances.len() - i
                ));
                break;
            }
            let util = inst.utilization();
            out.push_str(&format!(
                "  [{i}] {} ({}): {} stream(s), max util {:.1}%\n",
                inst.type_name,
                inst.hourly_cost,
                inst.streams.len(),
                util.0.iter().fold(0.0f64, |a, &b| a.max(b)) * 100.0
            ));
            for (j, s) in inst.streams.iter().enumerate() {
                if j == Self::SUMMARY_MAX_STREAMS {
                    out.push_str(&format!(
                        "      ... (+{} more streams)\n",
                        inst.streams.len() - j
                    ));
                    break;
                }
                out.push_str(&format!("      {} -> {}\n", s.stream_id, s.choice));
            }
        }
        out
    }
}

fn vec_to_json(v: &ResourceVec) -> Json {
    Json::arr(v.0.iter().map(|&x| Json::Num(x)))
}

fn vec_from_json(j: &Json) -> Result<ResourceVec> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("resource vector is not an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for x in arr {
        out.push(x.as_f64().ok_or_else(|| anyhow!("resource component is not a number"))?);
    }
    Ok(ResourceVec::from_slice(&out))
}

/// Serialize a plan for persistence (solve-cache files).  Costs travel
/// as exact micro-dollar integers and requirement vectors as plain f64
/// arrays (`util::json` prints finite floats shortest-round-trip), so
/// decode is bit-identical — [`plan_from_json`] is the exact inverse.
pub fn plan_to_json(plan: &AllocationPlan) -> Json {
    let instances = plan.instances.iter().map(|inst| {
        let streams = inst.streams.iter().map(|s| {
            Json::obj(vec![
                ("stream_index".to_string(), Json::Num(s.stream_index as f64)),
                ("stream_id".to_string(), Json::Str(s.stream_id.clone())),
                ("choice".to_string(), Json::Num(s.choice.to_index() as f64)),
                ("requirement".to_string(), vec_to_json(&s.requirement)),
            ])
        });
        Json::obj(vec![
            ("type_name".to_string(), Json::Str(inst.type_name.clone())),
            ("hourly_cost".to_string(), dollars_to_json(inst.hourly_cost)),
            ("capacity".to_string(), vec_to_json(&inst.capacity)),
            ("streams".to_string(), Json::arr(streams)),
        ])
    });
    Json::obj(vec![
        ("strategy".to_string(), Json::Str(plan.strategy.to_string())),
        ("solver".to_string(), Json::Str(plan.solver.to_string())),
        ("instances".to_string(), Json::arr(instances)),
        ("hourly_cost".to_string(), dollars_to_json(plan.hourly_cost)),
        ("transfer_rate".to_string(), dollars_to_json(plan.transfer_rate)),
        (
            "lower_bound".to_string(),
            match plan.lower_bound {
                Some(lb) => dollars_to_json(lb),
                None => Json::Null,
            },
        ),
    ])
}

/// Decode a plan serialized by [`plan_to_json`], checking the
/// structural invariants construction guarantees (consistent vector
/// dimensions per instance, instance costs summing to the plan's).
/// Semantic validity against the *current* catalog and fleet is NOT
/// checked here — that is the solve cache's replay validation.
pub fn plan_from_json(j: &Json) -> Result<AllocationPlan> {
    let strategy = j
        .str_field("strategy")?
        .parse::<Strategy>()
        .map_err(|e| anyhow!("{e}"))?;
    let solver = j
        .str_field("solver")?
        .parse::<SolverKind>()
        .map_err(|e| anyhow!("{e}"))?;
    let mut instances = Vec::new();
    for inst in j.arr_field("instances")? {
        let capacity = vec_from_json(inst.field("capacity")?)?;
        let mut streams = Vec::new();
        for s in inst.arr_field("streams")? {
            let requirement = vec_from_json(s.field("requirement")?)?;
            ensure!(
                requirement.dims() == capacity.dims(),
                "requirement dims {} != capacity dims {}",
                requirement.dims(),
                capacity.dims()
            );
            streams.push(StreamAssignment {
                stream_index: usize::try_from(s.u64_field("stream_index")?)?,
                stream_id: s.str_field("stream_id")?.to_string(),
                choice: ExecChoice::from_index(usize::try_from(s.u64_field("choice")?)?),
                requirement,
            });
        }
        instances.push(PlannedInstance {
            type_name: inst.str_field("type_name")?.to_string(),
            hourly_cost: dollars_from_json(inst.field("hourly_cost")?)?,
            capacity,
            streams,
        });
    }
    let hourly_cost = dollars_from_json(j.field("hourly_cost")?)?;
    let from_instances: Dollars = instances.iter().map(|i| i.hourly_cost).sum();
    ensure!(
        hourly_cost == from_instances,
        "plan hourly cost {hourly_cost} != sum of instance costs {from_instances}"
    );
    let lower_bound = match j.field("lower_bound")? {
        Json::Null => None,
        lb => Some(dollars_from_json(lb)?),
    };
    Ok(AllocationPlan {
        strategy,
        solver,
        instances,
        hourly_cost,
        transfer_rate: dollars_from_json(j.field("transfer_rate")?)?,
        lower_bound,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::manager::ResourceManager;
    use crate::profiler::calibration::Calibration;
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};

    fn plan_scenario2() -> AllocationPlan {
        // Scenario 2: VGG @0.20 x1 + ZF @0.50 x1 -> one c4.2xlarge.
        let cal = Calibration::paper();
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &cal);
        let mut streams = StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.20);
        streams.extend(StreamSpec::replicate(10, 1, VGA, Program::Zf, 0.50));
        mgr.allocate(&streams, Strategy::St3).unwrap()
    }

    #[test]
    fn scenario2_plan_shape() {
        let plan = plan_scenario2();
        assert_eq!(plan.instances.len(), 1);
        assert_eq!(plan.instances[0].type_name, "c4.2xlarge");
        assert_eq!(plan.hourly_cost, Dollars::from_f64(0.419));
        let (non_gpu, gpu) = plan.instance_counts(&Catalog::paper_experiments());
        assert_eq!((non_gpu, gpu), (1, 0));
        assert_eq!(plan.counts_by_type().get("c4.2xlarge"), Some(&1));
    }

    #[test]
    fn load_and_utilization_consistent() {
        let plan = plan_scenario2();
        let inst = &plan.instances[0];
        let load = inst.load();
        // VGG 0.2*15.76 + ZF 0.5*7.12 = 3.152 + 3.56 = 6.712 cores.
        assert!((load[0] - 6.712).abs() < 1e-9);
        let util = inst.utilization();
        // Against usable capacity 7.2 cores: 93.2%.
        assert!((util[0] - 6.712 / 7.2).abs() < 1e-9);
        assert!(util[0] <= 1.0 + 1e-9);
    }

    #[test]
    fn summary_mentions_devices() {
        let plan = plan_scenario2();
        let s = plan.summary();
        assert!(s.contains("c4.2xlarge"));
        assert!(s.contains("CPU"));
        assert!(s.contains("ST3"));
    }

    #[test]
    fn summary_elides_fleet_scale_plans() {
        // 70 instances x 20 streams: the summary must stay bounded and
        // say what it elided, not render 1400 stream lines.
        let instances: Vec<PlannedInstance> = (0..70)
            .map(|i| PlannedInstance {
                type_name: "c4.2xlarge".into(),
                hourly_cost: Dollars::from_f64(0.419),
                capacity: ResourceVec::from_slice(&[7.2, 13.5]),
                streams: (0..20)
                    .map(|j| StreamAssignment {
                        stream_index: i * 20 + j,
                        stream_id: format!("cam-{i}-{j}"),
                        choice: ExecChoice::Cpu,
                        requirement: ResourceVec::from_slice(&[0.1, 0.1]),
                    })
                    .collect(),
            })
            .collect();
        let hourly_cost = instances.iter().map(|i| i.hourly_cost).sum();
        let plan = AllocationPlan {
            strategy: Strategy::St1,
            solver: SolverKind::Portfolio,
            instances,
            hourly_cost,
            transfer_rate: Dollars::ZERO,
            lower_bound: None,
        };
        let s = plan.summary();
        assert!(s.contains("(+6 more instances)"), "{s}");
        assert!(s.contains("(+4 more streams)"), "{s}");
        assert!(s.lines().count() < 64 * 18 + 10, "summary must be bounded");
        // Small plans still print in full.
        assert!(!plan_scenario2().summary().contains("more"));
    }

    #[test]
    fn plans_round_trip_through_json_bit_identically() {
        // A solved plan (carries a lower bound and real requirement
        // vectors) must survive encode/decode unchanged — the solve
        // cache file trusts this to reproduce in-memory entries.
        let plan = plan_scenario2();
        let decoded = plan_from_json(&plan_to_json(&plan)).unwrap();
        assert_eq!(decoded, plan);

        // GPU choices, no certificate, and a transfer rate all encode.
        let hand_built = AllocationPlan {
            strategy: Strategy::St2,
            solver: SolverKind::WarmStart,
            instances: vec![PlannedInstance {
                type_name: "g2.8xlarge".into(),
                hourly_cost: Dollars::from_f64(2.6),
                capacity: ResourceVec::from_slice(&[28.8, 54.0, 1.0, 1.0, 1.0, 1.0]),
                streams: vec![StreamAssignment {
                    stream_index: 3,
                    stream_id: "cam-3".into(),
                    choice: ExecChoice::Gpu(2),
                    requirement: ResourceVec::from_slice(&[0.1, 0.2, 0.0, 0.0, 0.3, 0.0]),
                }],
            }],
            hourly_cost: Dollars::from_f64(2.6),
            transfer_rate: Dollars::from_f64(0.017),
            lower_bound: None,
        };
        let decoded = plan_from_json(&plan_to_json(&hand_built)).unwrap();
        assert_eq!(decoded, hand_built);
        assert_eq!(decoded.instances[0].streams[0].choice, ExecChoice::Gpu(2));

        // Tampered plans are rejected, not silently accepted.
        let mut j = plan_to_json(&hand_built);
        if let Json::Obj(map) = &mut j {
            map.insert("hourly_cost".to_string(), Json::Num(1.0));
        }
        assert!(plan_from_json(&j).is_err(), "cost mismatch must fail decode");
    }

    #[test]
    fn solved_plans_carry_a_finite_certified_gap() {
        let plan = plan_scenario2();
        let lb = plan.lower_bound.expect("manager solves carry a bound");
        assert!(lb <= plan.hourly_cost);
        let gap = plan.gap().unwrap();
        assert!(gap.is_finite() && (0.0..=1.0).contains(&gap));
        assert!(plan.summary().contains("gap"));
    }
}
