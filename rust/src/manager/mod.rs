//! The cloud resource manager — the paper's contribution (§3).
//!
//! Given a workload (streams: program + desired fps + frame size), the
//! profiles from the test runs, and an instance catalog, the manager:
//!
//! 1. builds the requirement choices of every stream at its desired rate
//!    from the linear resource models (§3.1);
//! 2. formulates a multiple-choice vector bin packing problem whose bins
//!    are instance types with 90%-headroom capacities (§3.2);
//! 3. solves it through the pluggable [`packing::Solver`] stack
//!    (routed by [`SolverChoice`] under a [`SolveBudget`]) and maps
//!    the certified outcome back to an [`AllocationPlan`]: which
//!    instances to provision, which streams on which instance, which
//!    device (CPU or GPU *g*) analyzes each stream — plus the solve's
//!    certified cost lower bound and optimality gap.
//!
//! [`ResourceManager::allocate_warm`] adds warm-start incremental
//! repacking on top: given the previous epoch's plan, only the delta of
//! added/removed streams is re-packed, with a certified-gap drift check
//! that falls back to a cold solve when warm quality decays.

pub mod plan;
pub mod realloc;
pub mod solve_cache;
pub mod strategy;
pub mod whatif;

pub use plan::{AllocationPlan, PlannedInstance, StreamAssignment};
pub use solve_cache::{solve_key, SolveCache, SolveKey};
pub use realloc::{
    assign_best_effort, plan_transition, repack_onto, worth_reallocating, Reallocation,
    TransitionAction,
};
pub use strategy::Strategy;

use crate::cloud::Catalog;
use crate::packing::problem::GATE_DIM_CAP;
use crate::packing::{BinType, Item, MvbpProblem, SolveBudget, SolverChoice};
use crate::profiler::{ExecChoice, ResourceProfile};
use crate::streams::StreamSpec;
use crate::types::{DimLayout, Dollars, ResourceVec};
use crate::util::profiling;

/// Allocation failure modes.
#[derive(Debug)]
pub enum AllocationError {
    /// Some streams cannot be analyzed at their desired rate under this
    /// strategy at all (Table 6's "Fail" row: ZF at 8 FPS under ST1).
    Infeasible {
        strategy: Strategy,
        stream_ids: Vec<String>,
    },
    /// No profile available for (program, frame size).
    MissingProfile(String),
    /// The catalog for this strategy is empty.
    EmptyCatalog(Strategy),
    /// The solver could not pack the items (should not happen once
    /// per-item feasibility holds, but surfaced rather than panicking).
    SolverFailed(String),
}

impl std::fmt::Display for AllocationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocationError::Infeasible { strategy, stream_ids } => {
                write!(f, "streams not satisfiable under {strategy}: {stream_ids:?}")
            }
            AllocationError::MissingProfile(variant) => {
                write!(f, "no resource profile for {variant}")
            }
            AllocationError::EmptyCatalog(strategy) => {
                write!(f, "strategy {strategy} leaves no instance types in the catalog")
            }
            AllocationError::SolverFailed(reason) => write!(f, "packing failed: {reason}"),
        }
    }
}

impl std::error::Error for AllocationError {}

/// Source of resource profiles for the manager.
pub trait ProfileSource {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile>;
}

impl ProfileSource for crate::profiler::store::ProfileStore {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile> {
        self.get(spec.program, spec.camera.frame_size).cloned()
    }
}

impl ProfileSource for crate::profiler::calibration::Calibration {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile> {
        Some(self.profile(spec.program, spec.camera.frame_size))
    }
}

/// The resource manager.
pub struct ResourceManager<'p> {
    pub catalog: Catalog,
    pub profiles: &'p dyn ProfileSource,
    /// The paper's 90% utilization ceiling.
    pub headroom: f64,
    /// Which solving strategy allocations route through.
    pub solver: SolverChoice,
    /// Time/size budget handed to the solver stack (exact cutoff,
    /// deadline, node budget, warm-start drift margin).
    pub budget: SolveBudget,
}

/// Warm-start acceptance floor: a warm plan whose certified gap stays
/// within `max(previous_gap, FLOOR) + budget.warm_gap_margin` is
/// accepted without a cold solve.  The floor keeps near-optimal fleets
/// from thrashing into cold solves over bound noise; the margin bounds
/// per-epoch quality drift.
const WARM_GAP_FLOOR: f64 = 0.10;

/// Deterministic home region of a stream: FNV-1a over its id, mod the
/// region count.  Streams keep their home across epochs (same id →
/// same region), so cross-region transfer charges reflect genuine
/// remote placement, never hash churn between solves.
pub(crate) fn home_region(id: &str, n_regions: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in id.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n_regions.max(1) as u64) as usize
}

/// A built MVBP instance plus the mapping back to streams/choices.
pub struct BuiltProblem {
    pub problem: MvbpProblem,
    /// `choice_map[item][dense_choice]` = the ExecChoice it encodes.
    pub choice_map: Vec<Vec<ExecChoice>>,
    pub layout: DimLayout,
}

impl<'p> ResourceManager<'p> {
    pub fn new(catalog: Catalog, profiles: &'p dyn ProfileSource) -> ResourceManager<'p> {
        ResourceManager::with_routing(catalog, profiles, SolverChoice::Auto, SolveBudget::default())
    }

    /// Construct with explicit solver routing — the single place the
    /// coordinator/CLI propagate their `--solver`/budget configuration
    /// through, so new routing fields cannot silently default on one
    /// construction path.
    pub fn with_routing(
        catalog: Catalog,
        profiles: &'p dyn ProfileSource,
        solver: SolverChoice,
        budget: SolveBudget,
    ) -> ResourceManager<'p> {
        ResourceManager { catalog, profiles, headroom: 0.9, solver, budget }
    }

    /// Formulate the MVBP instance for `streams` under `strategy`.
    pub fn build_problem(
        &self,
        streams: &[StreamSpec],
        strategy: Strategy,
    ) -> Result<BuiltProblem, AllocationError> {
        let catalog = strategy.filter_catalog(&self.catalog);
        if catalog.types.is_empty() {
            return Err(AllocationError::EmptyCatalog(strategy));
        }
        let layout = catalog.layout();
        let flat = catalog.pricing.is_flat();
        let n_regions = catalog.pricing.regions.len().max(1);
        // Region placement is encoded as extra "gate" dimensions: one
        // per region, each bin capacious only in its own region's gate
        // dim, each expanded choice demanding 1.0 in the gate dim of
        // the region it runs in.  Flat or single-region pricing skips
        // the machinery entirely, so those problems stay byte-identical
        // to the pre-tier formulation.
        let gated = !flat && n_regions > 1;
        let dims = if gated { layout.dims() + n_regions } else { layout.dims() };

        let bin_types: Vec<BinType> = if flat {
            catalog
                .types
                .iter()
                .map(|t| BinType {
                    name: t.name.clone(),
                    cost: t.hourly_cost,
                    capacity: t.capability(layout).scale(self.headroom),
                })
                .collect()
        } else {
            catalog
                .offerings()
                .into_iter()
                .map(|off| {
                    let base = off.itype.capability(layout).scale(self.headroom);
                    let capacity = if gated {
                        let mut v = ResourceVec::zeros(dims);
                        for d in 0..layout.dims() {
                            v[d] = base[d];
                        }
                        v[layout.dims() + off.region] = GATE_DIM_CAP;
                        v
                    } else {
                        base
                    };
                    BinType { name: off.itype.name.clone(), cost: off.itype.hourly_cost, capacity }
                })
                .collect()
        };

        let mut items = Vec::with_capacity(streams.len());
        let mut choice_map = Vec::with_capacity(streams.len());
        let mut choice_costs: Vec<Vec<Dollars>> = Vec::new();
        let mut infeasible = Vec::new();
        for spec in streams {
            let profile = self
                .profiles
                .profile_for(spec)
                .ok_or_else(|| {
                    AllocationError::MissingProfile(spec.program.variant(spec.camera.frame_size))
                })?;
            let mut choices = Vec::new();
            let mut map = Vec::new();
            for (idx, req) in profile.choices(spec.desired_fps, layout).into_iter().enumerate() {
                let exec = ExecChoice::from_index(idx);
                if !strategy.allows_choice(exec) {
                    continue;
                }
                if let Some(req) = req {
                    choices.push(req);
                    map.push(exec);
                }
            }
            if choices.is_empty() {
                infeasible.push(spec.id());
            }
            if gated {
                // Expand each device choice across regions, home region
                // first so first-fit keeps its device-order preference
                // and only pays transfer when the home region cannot
                // host the stream.
                let home = home_region(&spec.id(), n_regions);
                let mut ex_choices = Vec::with_capacity(choices.len() * n_regions);
                let mut ex_map = Vec::with_capacity(map.len() * n_regions);
                let mut ex_costs = Vec::with_capacity(choices.len() * n_regions);
                for r in std::iter::once(home).chain((0..n_regions).filter(|r| *r != home)) {
                    let transfer = if r == home {
                        Dollars::ZERO
                    } else {
                        catalog.pricing.regions[r].transfer_hourly
                    };
                    for (req, exec) in choices.iter().zip(&map) {
                        let mut v = ResourceVec::zeros(dims);
                        for d in 0..layout.dims() {
                            v[d] = req[d];
                        }
                        v[layout.dims() + r] = 1.0;
                        ex_choices.push(v);
                        ex_map.push(*exec);
                        ex_costs.push(transfer);
                    }
                }
                items.push(Item { id: spec.id(), choices: ex_choices });
                choice_map.push(ex_map);
                choice_costs.push(ex_costs);
            } else {
                items.push(Item { id: spec.id(), choices });
                choice_map.push(map);
            }
        }
        if !infeasible.is_empty() {
            return Err(AllocationError::Infeasible { strategy, stream_ids: infeasible });
        }

        let problem = MvbpProblem { dims, bin_types, items, choice_costs };
        // Latency-feasible choices can still exceed every instance
        // (e.g. desired rate needing 12 cores).  Report those too.
        let unpackable = problem.infeasible_items();
        if !unpackable.is_empty() {
            return Err(AllocationError::Infeasible {
                strategy,
                stream_ids: unpackable
                    .into_iter()
                    .map(|i| streams[i].id())
                    .collect(),
            });
        }
        Ok(BuiltProblem { problem, choice_map, layout })
    }

    /// Solve an already-built problem through the configured solver and
    /// map the certified outcome back to a plan.  `bound_hint` is a
    /// certified lower bound the caller already computed for this exact
    /// problem (the declined warm outcome's), forwarded so the solver
    /// does not recompute it.  Crate-visible so the autoscaler's
    /// memoized cold path can solve the problem it just fingerprinted
    /// without building it twice.
    pub(crate) fn solve_built(
        &self,
        built: &BuiltProblem,
        streams: &[StreamSpec],
        strategy: Strategy,
        bound_hint: Option<Dollars>,
    ) -> Result<AllocationPlan, AllocationError> {
        let outcome = self
            .solver
            .solve_with(&built.problem, &self.budget, bound_hint)
            .ok_or_else(|| AllocationError::SolverFailed("no packing found".into()))?;
        outcome
            .solution
            .validate(&built.problem)
            .map_err(AllocationError::SolverFailed)?;
        Ok(AllocationPlan::from_outcome(built, &outcome, streams, strategy))
    }

    /// Full allocation: formulate, solve, and map back to a plan.
    pub fn allocate(
        &self,
        streams: &[StreamSpec],
        strategy: Strategy,
    ) -> Result<AllocationPlan, AllocationError> {
        let built = self.build_problem(streams, strategy)?;
        self.solve_built(&built, streams, strategy, None)
    }

    /// Warm-start allocation: seed the packing with `previous` (the
    /// fleet already provisioned) so only the delta of added/removed
    /// streams is re-packed — see [`realloc::repack_incremental`] for
    /// the keep/consolidate/delta mechanics.  The warm plan is accepted
    /// only while its certified gap stays within the drift threshold of
    /// the previous plan's; otherwise (or when the incumbent cannot
    /// seed this problem at all) the manager falls back to a full cold
    /// solve.
    pub fn allocate_warm(
        &self,
        streams: &[StreamSpec],
        strategy: Strategy,
        previous: &AllocationPlan,
    ) -> Result<AllocationPlan, AllocationError> {
        let built = self.build_problem(streams, strategy)?;
        let mut bound_hint = None;
        if let Some(outcome) =
            profiling::time_phase("warm:repack-delta", || realloc::repack_incremental(&built, previous))
        {
            let threshold =
                previous.gap().unwrap_or(0.0).max(WARM_GAP_FLOOR) + self.budget.warm_gap_margin;
            if outcome.gap() <= threshold {
                return Ok(AllocationPlan::from_outcome(&built, &outcome, streams, strategy));
            }
            // The declined warm outcome already paid for this problem's
            // certified bound (its cost can only clamp the bound up to
            // itself when the bound is exact); hand it to the cold solve
            // so the bound is not recomputed.
            bound_hint = Some(outcome.lower_bound);
        }
        self.solve_built(&built, streams, strategy, bound_hint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::calibration::Calibration;
    use crate::streams::{Camera, StreamSpec};
    use crate::types::{Dollars, Program, VGA};

    fn streams_scenario1() -> Vec<StreamSpec> {
        // Table 5, scenario 1: VGG-16 @0.25 x1, ZF @0.55 x3.
        let mut v = StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.25);
        v.extend(StreamSpec::replicate(10, 3, VGA, Program::Zf, 0.55));
        v
    }

    fn manager(cal: &Calibration) -> ResourceManager<'_> {
        ResourceManager::new(Catalog::paper_experiments(), cal)
    }

    #[test]
    fn scenario1_st3_uses_one_gpu_instance() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let plan = mgr.allocate(&streams_scenario1(), Strategy::St3).unwrap();
        assert_eq!(plan.instances.len(), 1);
        assert_eq!(plan.instances[0].type_name, "g2.2xlarge");
        assert_eq!(plan.hourly_cost, Dollars::from_f64(0.650));
        // The paper's outcome: one GPU instance hosts all four streams.
        // At least some must offload to the GPU (pure-CPU would not fit:
        // 3.94 + 3 x 3.92 cores > 7.2 usable), though the solver may
        // keep one stream on the instance's CPU at identical cost.
        assert_eq!(plan.instances[0].streams.len(), 4);
        assert!(plan.instances[0]
            .streams
            .iter()
            .any(|a| a.choice.is_gpu()));
    }

    #[test]
    fn scenario1_st1_needs_four_cpu_instances() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let plan = mgr.allocate(&streams_scenario1(), Strategy::St1).unwrap();
        assert_eq!(plan.instances.len(), 4);
        assert!(plan
            .instances
            .iter()
            .all(|i| i.type_name == "c4.2xlarge"));
        assert_eq!(plan.hourly_cost, Dollars::from_f64(1.676));
    }

    #[test]
    fn scenario3_st1_fails_zf_at_8fps() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let mut streams = StreamSpec::replicate(0, 2, VGA, Program::Vgg16, 0.20);
        streams.extend(StreamSpec::replicate(10, 10, VGA, Program::Zf, 8.0));
        let err = mgr.allocate(&streams, Strategy::St1).unwrap_err();
        match err {
            AllocationError::Infeasible { stream_ids, .. } => {
                assert_eq!(stream_ids.len(), 10); // all ten ZF streams
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    #[test]
    fn st2_forbids_cpu_choice() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let built = mgr
            .build_problem(&streams_scenario1(), Strategy::St2)
            .unwrap();
        for map in &built.choice_map {
            assert!(map.iter().all(|c| c.is_gpu()));
        }
    }

    #[test]
    fn missing_profile_errors() {
        struct NoProfiles;
        impl ProfileSource for NoProfiles {
            fn profile_for(&self, _: &StreamSpec) -> Option<ResourceProfile> {
                None
            }
        }
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &NoProfiles);
        let streams = vec![StreamSpec::new(Camera::new(0, VGA), Program::Zf, 0.5)];
        assert!(matches!(
            mgr.allocate(&streams, Strategy::St3),
            Err(AllocationError::MissingProfile(_))
        ));
    }

    #[test]
    fn warm_allocation_matches_cold_on_unchanged_workload() {
        // Tight-bound CPU workload: the certified gap is 0, so the warm
        // path is accepted and must reproduce the cold cost exactly.
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let streams = StreamSpec::replicate(0, 4, VGA, crate::types::Program::Zf, 0.5);
        let cold = mgr.allocate(&streams, Strategy::St1).unwrap();
        assert_eq!(cold.hourly_cost, Dollars::from_f64(0.838));
        let warm = mgr.allocate_warm(&streams, Strategy::St1, &cold).unwrap();
        assert_eq!(warm.hourly_cost, cold.hourly_cost);
        assert_eq!(warm.counts_by_type(), cold.counts_by_type());
        assert_eq!(warm.solver, crate::packing::SolverKind::WarmStart);
        assert_eq!(warm.gap(), Some(0.0));
    }

    #[test]
    fn warm_allocation_packs_only_the_delta_on_growth() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let four = StreamSpec::replicate(0, 4, VGA, crate::types::Program::Zf, 0.5);
        let previous = mgr.allocate(&four, Strategy::St1).unwrap();
        let mut six = four.clone();
        six.extend(StreamSpec::replicate(100, 2, VGA, crate::types::Program::Zf, 0.5));
        let warm = mgr.allocate_warm(&six, Strategy::St1, &previous).unwrap();
        let cold = mgr.allocate(&six, Strategy::St1).unwrap();
        // Three bins either way (the instance is gap-0), and the warm
        // result never trails the cold one on this tight instance.
        assert_eq!(warm.hourly_cost, cold.hourly_cost);
        assert_eq!(warm.instances.len(), 3);
        assert!(warm.gap().unwrap().is_finite());
    }

    #[test]
    fn warm_allocation_recovers_the_optimum_after_total_churn() {
        // Previous fleet: two GPU instances for a burst.  New workload:
        // three quiet streams with entirely new ids — consolidation
        // dissolves the stale GPU bins and the result must match the
        // cold optimum (one CPU instance), not fossilize the old fleet.
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let burst = StreamSpec::replicate(0, 10, VGA, crate::types::Program::Zf, 1.0);
        let previous = mgr.allocate(&burst, Strategy::St3).unwrap();
        let quiet = StreamSpec::replicate(100, 3, VGA, crate::types::Program::Zf, 0.2);
        let warm = mgr.allocate_warm(&quiet, Strategy::St3, &previous).unwrap();
        let cold = mgr.allocate(&quiet, Strategy::St3).unwrap();
        assert_eq!(warm.hourly_cost, cold.hourly_cost);
        assert_eq!(warm.hourly_cost, Dollars::from_f64(0.419));
    }

    #[test]
    fn warm_allocation_falls_back_when_the_certified_gap_drifts() {
        // Mixed CPU/GPU demand (scenario 1): whether the warm incumbent
        // survives the drift gate depends on how tight the certified
        // bound is on this catalog (the DFF family closed most of the
        // historical looseness here).  Compute the warm outcome's gap
        // directly and assert the manager routes on it exactly: past
        // the threshold it re-solves cold, within it the warm plan is
        // kept — either way the unchanged workload must land on the
        // cold-optimal cost.
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let streams = streams_scenario1();
        let cold = mgr.allocate(&streams, Strategy::St3).unwrap();
        assert_eq!(cold.gap(), Some(0.0), "paper-scale solve is proven optimal");
        let built = mgr.build_problem(&streams, Strategy::St3).unwrap();
        let outcome =
            realloc::repack_incremental(&built, &cold).expect("previous plan seeds itself");
        let threshold =
            cold.gap().unwrap().max(WARM_GAP_FLOOR) + mgr.budget.warm_gap_margin;
        let warm = mgr.allocate_warm(&streams, Strategy::St3, &cold).unwrap();
        if outcome.gap() > threshold {
            assert_eq!(warm.solver, crate::packing::SolverKind::Exact);
        } else {
            assert_eq!(warm.solver, crate::packing::SolverKind::WarmStart);
        }
        assert_eq!(warm.hourly_cost, cold.hourly_cost);
    }

    #[test]
    fn empty_catalog_for_strategy_errors() {
        let cal = Calibration::paper();
        let mgr = ResourceManager::new(
            Catalog::paper_experiments().gpu_only(),
            &cal,
        );
        let streams = vec![StreamSpec::new(Camera::new(0, VGA), Program::Zf, 0.5)];
        assert!(matches!(
            mgr.allocate(&streams, Strategy::St1),
            Err(AllocationError::EmptyCatalog(Strategy::St1))
        ));
    }
}
