//! The cloud resource manager — the paper's contribution (§3).
//!
//! Given a workload (streams: program + desired fps + frame size), the
//! profiles from the test runs, and an instance catalog, the manager:
//!
//! 1. builds the requirement choices of every stream at its desired rate
//!    from the linear resource models (§3.1);
//! 2. formulates a multiple-choice vector bin packing problem whose bins
//!    are instance types with 90%-headroom capacities (§3.2);
//! 3. solves it (exact branch-and-bound, BFD fallback at scale) and maps
//!    the packing back to an [`AllocationPlan`]: which instances to
//!    provision, which streams on which instance, and which device (CPU
//!    or GPU *g*) analyzes each stream.

pub mod plan;
pub mod realloc;
pub mod strategy;
pub mod whatif;

pub use plan::{AllocationPlan, PlannedInstance, StreamAssignment};
pub use realloc::{
    assign_best_effort, plan_transition, repack_onto, worth_reallocating, Reallocation,
    TransitionAction,
};
pub use strategy::Strategy;

use crate::cloud::Catalog;
use crate::packing::{self, BinType, Item, MvbpProblem};
use crate::profiler::{ExecChoice, ResourceProfile};
use crate::streams::StreamSpec;
use crate::types::DimLayout;

/// Allocation failure modes.
#[derive(Debug)]
pub enum AllocationError {
    /// Some streams cannot be analyzed at their desired rate under this
    /// strategy at all (Table 6's "Fail" row: ZF at 8 FPS under ST1).
    Infeasible {
        strategy: Strategy,
        stream_ids: Vec<String>,
    },
    /// No profile available for (program, frame size).
    MissingProfile(String),
    /// The catalog for this strategy is empty.
    EmptyCatalog(Strategy),
    /// The solver could not pack the items (should not happen once
    /// per-item feasibility holds, but surfaced rather than panicking).
    SolverFailed(String),
}

impl std::fmt::Display for AllocationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocationError::Infeasible { strategy, stream_ids } => {
                write!(f, "streams not satisfiable under {strategy}: {stream_ids:?}")
            }
            AllocationError::MissingProfile(variant) => {
                write!(f, "no resource profile for {variant}")
            }
            AllocationError::EmptyCatalog(strategy) => {
                write!(f, "strategy {strategy} leaves no instance types in the catalog")
            }
            AllocationError::SolverFailed(reason) => write!(f, "packing failed: {reason}"),
        }
    }
}

impl std::error::Error for AllocationError {}

/// Source of resource profiles for the manager.
pub trait ProfileSource {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile>;
}

impl ProfileSource for crate::profiler::store::ProfileStore {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile> {
        self.get(spec.program, spec.camera.frame_size).cloned()
    }
}

impl ProfileSource for crate::profiler::calibration::Calibration {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile> {
        Some(self.profile(spec.program, spec.camera.frame_size))
    }
}

/// The resource manager.
pub struct ResourceManager<'p> {
    pub catalog: Catalog,
    pub profiles: &'p dyn ProfileSource,
    /// The paper's 90% utilization ceiling.
    pub headroom: f64,
    /// Max items for the exact solver before falling back to BFD.
    pub exact_cutoff: usize,
}

/// A built MVBP instance plus the mapping back to streams/choices.
pub struct BuiltProblem {
    pub problem: MvbpProblem,
    /// `choice_map[item][dense_choice]` = the ExecChoice it encodes.
    pub choice_map: Vec<Vec<ExecChoice>>,
    pub layout: DimLayout,
}

impl<'p> ResourceManager<'p> {
    pub fn new(catalog: Catalog, profiles: &'p dyn ProfileSource) -> ResourceManager<'p> {
        ResourceManager {
            catalog,
            profiles,
            headroom: 0.9,
            exact_cutoff: 24,
        }
    }

    /// Formulate the MVBP instance for `streams` under `strategy`.
    pub fn build_problem(
        &self,
        streams: &[StreamSpec],
        strategy: Strategy,
    ) -> Result<BuiltProblem, AllocationError> {
        let catalog = strategy.filter_catalog(&self.catalog);
        if catalog.types.is_empty() {
            return Err(AllocationError::EmptyCatalog(strategy));
        }
        let layout = catalog.layout();

        let bin_types: Vec<BinType> = catalog
            .types
            .iter()
            .map(|t| BinType {
                name: t.name.clone(),
                cost: t.hourly_cost,
                capacity: t.capability(layout).scale(self.headroom),
            })
            .collect();

        let mut items = Vec::with_capacity(streams.len());
        let mut choice_map = Vec::with_capacity(streams.len());
        let mut infeasible = Vec::new();
        for spec in streams {
            let profile = self
                .profiles
                .profile_for(spec)
                .ok_or_else(|| {
                    AllocationError::MissingProfile(spec.program.variant(spec.camera.frame_size))
                })?;
            let mut choices = Vec::new();
            let mut map = Vec::new();
            for (idx, req) in profile.choices(spec.desired_fps, layout).into_iter().enumerate() {
                let exec = ExecChoice::from_index(idx);
                if !strategy.allows_choice(exec) {
                    continue;
                }
                if let Some(req) = req {
                    choices.push(req);
                    map.push(exec);
                }
            }
            if choices.is_empty() {
                infeasible.push(spec.id());
            }
            items.push(Item { id: spec.id(), choices });
            choice_map.push(map);
        }
        if !infeasible.is_empty() {
            return Err(AllocationError::Infeasible { strategy, stream_ids: infeasible });
        }

        let problem = MvbpProblem { dims: layout.dims(), bin_types, items };
        // Latency-feasible choices can still exceed every instance
        // (e.g. desired rate needing 12 cores).  Report those too.
        let unpackable = problem.infeasible_items();
        if !unpackable.is_empty() {
            return Err(AllocationError::Infeasible {
                strategy,
                stream_ids: unpackable
                    .into_iter()
                    .map(|i| streams[i].id())
                    .collect(),
            });
        }
        Ok(BuiltProblem { problem, choice_map, layout })
    }

    /// Full allocation: formulate, solve, and map back to a plan.
    pub fn allocate(
        &self,
        streams: &[StreamSpec],
        strategy: Strategy,
    ) -> Result<AllocationPlan, AllocationError> {
        let built = self.build_problem(streams, strategy)?;
        let (solution, solver) = packing::solve_auto(&built.problem, self.exact_cutoff)
            .ok_or_else(|| AllocationError::SolverFailed("no packing found".into()))?;
        solution
            .validate(&built.problem)
            .map_err(AllocationError::SolverFailed)?;
        Ok(AllocationPlan::from_solution(
            &built, &solution, streams, strategy, solver,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::calibration::Calibration;
    use crate::streams::{Camera, StreamSpec};
    use crate::types::{Dollars, Program, VGA};

    fn streams_scenario1() -> Vec<StreamSpec> {
        // Table 5, scenario 1: VGG-16 @0.25 x1, ZF @0.55 x3.
        let mut v = StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.25);
        v.extend(StreamSpec::replicate(10, 3, VGA, Program::Zf, 0.55));
        v
    }

    fn manager(cal: &Calibration) -> ResourceManager<'_> {
        ResourceManager::new(Catalog::paper_experiments(), cal)
    }

    #[test]
    fn scenario1_st3_uses_one_gpu_instance() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let plan = mgr.allocate(&streams_scenario1(), Strategy::St3).unwrap();
        assert_eq!(plan.instances.len(), 1);
        assert_eq!(plan.instances[0].type_name, "g2.2xlarge");
        assert_eq!(plan.hourly_cost, Dollars::from_f64(0.650));
        // The paper's outcome: one GPU instance hosts all four streams.
        // At least some must offload to the GPU (pure-CPU would not fit:
        // 3.94 + 3 x 3.92 cores > 7.2 usable), though the solver may
        // keep one stream on the instance's CPU at identical cost.
        assert_eq!(plan.instances[0].streams.len(), 4);
        assert!(plan.instances[0]
            .streams
            .iter()
            .any(|a| a.choice.is_gpu()));
    }

    #[test]
    fn scenario1_st1_needs_four_cpu_instances() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let plan = mgr.allocate(&streams_scenario1(), Strategy::St1).unwrap();
        assert_eq!(plan.instances.len(), 4);
        assert!(plan
            .instances
            .iter()
            .all(|i| i.type_name == "c4.2xlarge"));
        assert_eq!(plan.hourly_cost, Dollars::from_f64(1.676));
    }

    #[test]
    fn scenario3_st1_fails_zf_at_8fps() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let mut streams = StreamSpec::replicate(0, 2, VGA, Program::Vgg16, 0.20);
        streams.extend(StreamSpec::replicate(10, 10, VGA, Program::Zf, 8.0));
        let err = mgr.allocate(&streams, Strategy::St1).unwrap_err();
        match err {
            AllocationError::Infeasible { stream_ids, .. } => {
                assert_eq!(stream_ids.len(), 10); // all ten ZF streams
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
    }

    #[test]
    fn st2_forbids_cpu_choice() {
        let cal = Calibration::paper();
        let mgr = manager(&cal);
        let built = mgr
            .build_problem(&streams_scenario1(), Strategy::St2)
            .unwrap();
        for map in &built.choice_map {
            assert!(map.iter().all(|c| c.is_gpu()));
        }
    }

    #[test]
    fn missing_profile_errors() {
        struct NoProfiles;
        impl ProfileSource for NoProfiles {
            fn profile_for(&self, _: &StreamSpec) -> Option<ResourceProfile> {
                None
            }
        }
        let mgr = ResourceManager::new(Catalog::paper_experiments(), &NoProfiles);
        let streams = vec![StreamSpec::new(Camera::new(0, VGA), Program::Zf, 0.5)];
        assert!(matches!(
            mgr.allocate(&streams, Strategy::St3),
            Err(AllocationError::MissingProfile(_))
        ));
    }

    #[test]
    fn empty_catalog_for_strategy_errors() {
        let cal = Calibration::paper();
        let mgr = ResourceManager::new(
            Catalog::paper_experiments().gpu_only(),
            &cal,
        );
        let streams = vec![StreamSpec::new(Camera::new(0, VGA), Program::Zf, 0.5)];
        assert!(matches!(
            mgr.allocate(&streams, Strategy::St1),
            Err(AllocationError::EmptyCatalog(Strategy::St1))
        ));
    }
}
