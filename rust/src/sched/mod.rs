//! Per-instance frame-loop scheduling over a simulated timeline.
//!
//! Executes an [`AllocationPlan`](crate::manager::AllocationPlan):
//! each stream emits frames at its desired rate; each frame is a job
//! consuming CPU core-seconds (and GPU core-seconds for GPU-mode
//! streams) on its instance's devices.  Devices are fluid-capacity
//! servers with per-job parallelism caps, so an idle instance serves a
//! frame in exactly the profile's latency while an overloaded one
//! degrades throughput gracefully — reproducing the performance
//! behaviour of the paper's Figs. 5–6.
//!
//! The engine is a deterministic fixed-step simulation (`dt` default
//! 10 ms).  Real inference (PJRT) is exercised by the coordinator's
//! live mode instead; here the latencies come from the profiles, which
//! the live test runs calibrate.

pub mod sim;

pub use sim::{SimConfig, SimReport, Simulation};
