//! Per-instance frame-loop scheduling over a simulated timeline.
//!
//! Executes an [`AllocationPlan`](crate::manager::AllocationPlan):
//! each stream emits frames at its desired rate; each frame is a job
//! consuming CPU core-seconds (and GPU core-seconds for GPU-mode
//! streams) on its instance's devices.  Devices are fluid-capacity
//! servers with per-job parallelism caps, so an idle instance serves a
//! frame in exactly the profile's latency while an overloaded one
//! degrades throughput gracefully — reproducing the performance
//! behaviour of the paper's Figs. 5–6.
//!
//! Two engines execute that model behind the [`SimConfig`] /
//! [`SimReport`] facade, selected by [`SimEngine`]:
//!
//! * [`event`] — the default **event-driven discrete-event engine**:
//!   a priority queue of frame-arrival and service-completion events,
//!   processor-sharing rates re-solved only when an instance's state
//!   changes, utilization meters integrated over exact event spans.
//!   Cost scales with how much *happens* (arrivals + completions), not
//!   with the simulated duration — the fleet-scale path.
//! * [`sim`]'s fixed-step engine — the original fluid engine advancing
//!   a global `dt` clock (10 ms default).  O(duration/dt x streams),
//!   kept as the independently-simple baseline; the two engines agree
//!   within 1% on the paper scenarios (see `tests/engine_equivalence`).
//!
//! Either engine executes **sharded** (the `shard` submodule):
//! instances are independent given the assignments, so
//! [`Simulation::run`] partitions
//! them across [`Parallelism::sim_threads`] scoped workers and merges
//! the per-shard reports in instance-id order.  The merge is
//! bit-identical to a single-threaded run for every thread count —
//! each instance's event sequence is a pure function of its own
//! streams — and the single-worker fallback runs the identical
//! partition/merge code path, so `--sim-threads 1` is the equivalence
//! reference, not a separate implementation.
//!
//! Real inference (PJRT) is exercised by the coordinator's live mode
//! instead; here the latencies come from the profiles, which the live
//! test runs calibrate.

pub mod event;
mod shard;
pub mod sim;

pub use sim::{Parallelism, SimConfig, SimEngine, SimReport, Simulation};
