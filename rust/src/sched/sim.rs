//! Simulation facade ([`SimConfig`] / [`SimReport`] / [`Simulation`])
//! plus the fixed-step *fluid* reference engine.
//!
//! Two engines execute the same model (see [`super::event`] for the
//! default event-driven one); [`SimEngine`] selects between them and
//! [`Simulation::run`] dispatches.  The fixed-step engine advances a
//! global clock in `dt` increments and re-solves the processor-sharing
//! allocation every tick, but only for instances with queued or
//! arriving work — idle instances are skipped wholesale (their meters
//! are credited the idle span in one batched record), so the per-tick
//! cost scales with *active* instances rather than fleet size.  It is
//! kept as the independently-simple cross-validation baseline for the
//! event engine.
//!
//! Both engines run *sharded* (see the `shard` submodule): instances
//! are independent given the assignments — per-instance queues never
//! interact — so [`Simulation::run`] partitions them across
//! [`Parallelism::sim_threads`] workers and merges the per-shard
//! reports in instance-id order.  The merged result is bit-identical
//! to the single-threaded run for any thread count (the single-thread
//! fallback exercises the same partition/merge code path with one
//! shard).

use crate::manager::AllocationPlan;
use crate::metrics::{overall_performance, StreamPerf, UtilizationMeter};
use crate::profiler::{ExecChoice, ResourceProfile};
use crate::streams::StreamSpec;
use crate::types::DimLayout;
use std::collections::BTreeMap;

/// Which simulation engine executes the run.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum SimEngine {
    /// Event-driven discrete-event engine (the default): work only at
    /// frame arrivals, service completions, and queue drops.
    #[default]
    Event,
    /// Fixed-step fluid engine (`dt` ticks) — the reference baseline.
    FixedStep,
}

impl std::str::FromStr for SimEngine {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "event" | "event-driven" => Ok(SimEngine::Event),
            "fixed" | "fixed-step" | "step" => Ok(SimEngine::FixedStep),
            other => Err(format!("unknown engine {other:?} (expected event or fixed)")),
        }
    }
}

impl std::fmt::Display for SimEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimEngine::Event => "event",
            SimEngine::FixedStep => "fixed",
        })
    }
}

/// Execution-parallelism knobs, threaded from the CLI
/// (`--sim-threads N --pipeline on|off`) through
/// [`SimConfig`]/`AutoscaleConfig` to the engines and the epoch
/// pipeline.  Parallelism does not change results: sharded simulation
/// is bit-identical across thread counts unconditionally, and the
/// epoch pipeline yields identical outcomes whenever the solver stack
/// is deterministic (its documented precondition: solves finish within
/// the node budget before the `--solve-budget-ms` deadline fires —
/// true by a wide margin at every scale this repo runs).  The third
/// parallelism knob, `--exact-threads` (`SolveBudget::exact_threads`),
/// lives on the solve budget rather than here because it parallelizes
/// *within* one solve; it carries the same contract — completed
/// branch-and-bound proofs are bit-identical for any thread count.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Parallelism {
    /// Worker threads for sharded simulation; `0` (the default) means
    /// "use available parallelism".  The shard count never exceeds the
    /// instance count.
    pub sim_threads: usize,
    /// Overlap epoch `i+1`'s solve with epoch `i`'s simulation in the
    /// autoscale runner (`coordinator::pipeline`).
    pub pipeline: bool,
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism { sim_threads: 0, pipeline: true }
    }
}

impl Parallelism {
    /// Fully sequential execution: one simulation worker, no epoch
    /// pipelining — the reference the equivalence tests compare against.
    pub fn sequential() -> Parallelism {
        Parallelism { sim_threads: 1, pipeline: false }
    }

    /// Resolved simulation worker count (`sim_threads`, or the
    /// machine's available parallelism when 0).
    pub fn effective_sim_threads(&self) -> usize {
        if self.sim_threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.sim_threads
        }
    }
}

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Simulated duration in seconds.
    pub duration_s: f64,
    /// Time step (seconds) of the fixed-step engine.  10 ms resolves the
    /// fastest latencies the calibrated profiles produce.  The event
    /// engine ignores it.
    pub dt: f64,
    /// Per-stream job-queue cap; frames arriving beyond it are dropped
    /// (a real ingest pipeline drops frames under backpressure too).
    pub queue_cap: usize,
    /// Engine selection (default: event-driven).
    pub engine: SimEngine,
    /// Sharded-execution knobs (default: available parallelism).
    pub parallelism: Parallelism,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            duration_s: 120.0,
            dt: 0.01,
            queue_cap: 32,
            engine: SimEngine::default(),
            parallelism: Parallelism::default(),
        }
    }
}

impl SimConfig {
    /// Default config over a custom duration.
    pub fn for_duration(duration_s: f64) -> SimConfig {
        SimConfig { duration_s, ..SimConfig::default() }
    }

    /// Same config under a different engine.
    pub fn with_engine(self, engine: SimEngine) -> SimConfig {
        SimConfig { engine, ..self }
    }

    /// Same config under different parallelism knobs.
    pub fn with_parallelism(self, parallelism: Parallelism) -> SimConfig {
        SimConfig { parallelism, ..self }
    }
}

/// One frame in flight.
#[derive(Clone, Debug)]
pub(crate) struct Job {
    pub(crate) stream: usize,
    /// Remaining work per device slot (same indexing as `DeviceSlot`).
    pub(crate) remaining_cpu: f64,
    pub(crate) remaining_gpu: f64,
}

/// A fluid-capacity device on an instance.
#[derive(Clone, Debug)]
pub(crate) struct Device {
    /// Capacity in core-seconds per second.
    pub(crate) capacity: f64,
    pub(crate) meter: UtilizationMeter,
}

/// Per-stream static execution parameters derived from profile+choice.
#[derive(Clone, Debug)]
pub(crate) struct StreamExec {
    pub(crate) instance: usize,
    /// Device index of the GPU used (instance-local), if GPU mode.
    pub(crate) gpu_index: Option<usize>,
    pub(crate) desired_fps: f64,
    pub(crate) cpu_work: f64,
    pub(crate) gpu_work: f64,
    /// Max draw rates (cores) reproducing the solo latency.
    pub(crate) cpu_parallelism: f64,
    pub(crate) gpu_parallelism: f64,
    pub(crate) id: String,
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub streams: Vec<StreamPerf>,
    /// `(instance_index, device_name) -> (mean, peak)` utilization.
    pub device_utilization: BTreeMap<(usize, String), (f64, f64)>,
    pub frames_completed: u64,
    pub frames_dropped: u64,
    pub duration_s: f64,
}

impl SimReport {
    /// The paper's overall performance (average of per-stream ratios).
    pub fn overall_performance(&self) -> f64 {
        overall_performance(&self.streams)
    }

    /// Highest mean utilization across devices of one instance.
    pub fn max_mean_utilization(&self) -> f64 {
        self.device_utilization
            .values()
            .map(|(mean, _)| *mean)
            .fold(0.0, f64::max)
    }
}

/// The simulation: instances with devices, streams with assignments.
pub struct Simulation {
    pub(crate) devices: Vec<Device>,
    /// `(instance, slot)` -> device index in `devices`; slot 0 = CPU,
    /// slot 1+g = GPU g.
    pub(crate) device_index: BTreeMap<(usize, usize), usize>,
    pub(crate) device_names: Vec<(usize, String)>,
    pub(crate) streams: Vec<StreamExec>,
}

impl Simulation {
    /// Build a simulation from an allocation plan.
    ///
    /// `profiles[i]` is the resource profile of stream `i` (the same
    /// source the manager used) — the pipeline resolves profiles once
    /// and hands the slice through rather than threading closures.
    pub fn from_plan(
        plan: &AllocationPlan,
        specs: &[StreamSpec],
        layout: DimLayout,
        profiles: &[ResourceProfile],
        catalog: &crate::cloud::Catalog,
    ) -> Simulation {
        assert_eq!(
            specs.len(),
            profiles.len(),
            "one profile per stream spec"
        );
        let mut sim = Simulation {
            devices: Vec::new(),
            device_index: BTreeMap::new(),
            device_names: Vec::new(),
            streams: Vec::new(),
        };
        for (inst_idx, inst) in plan.instances.iter().enumerate() {
            let itype = catalog
                .resolve(&inst.type_name)
                .unwrap_or_else(|| panic!("unknown instance type {}", inst.type_name))
                .itype;
            sim.add_device(inst_idx, 0, "cpu", itype.cpu_cores);
            for (g, gpu) in itype.gpus.iter().enumerate() {
                sim.add_device(inst_idx, 1 + g, &format!("gpu{g}"), gpu.cores);
            }
            for assign in &inst.streams {
                let profile = &profiles[assign.stream_index];
                let spec = &specs[assign.stream_index];
                sim.add_stream(inst_idx, spec, profile, assign.choice, layout);
            }
        }
        sim
    }

    pub(crate) fn add_device(&mut self, instance: usize, slot: usize, name: &str, capacity: f64) {
        let idx = self.devices.len();
        self.devices.push(Device { capacity, meter: UtilizationMeter::new() });
        self.device_index.insert((instance, slot), idx);
        self.device_names.push((instance, name.to_string()));
    }

    pub(crate) fn add_stream(
        &mut self,
        instance: usize,
        spec: &StreamSpec,
        profile: &ResourceProfile,
        choice: ExecChoice,
        _layout: DimLayout,
    ) {
        let exec = match choice {
            ExecChoice::Cpu => StreamExec {
                instance,
                gpu_index: None,
                desired_fps: spec.desired_fps,
                cpu_work: profile.cpu_work_cpu_mode,
                gpu_work: 0.0,
                cpu_parallelism: (profile.cpu_work_cpu_mode * profile.max_fps_cpu).max(1e-9),
                gpu_parallelism: 0.0,
                id: spec.id(),
            },
            ExecChoice::Gpu(g) => StreamExec {
                instance,
                gpu_index: Some(g),
                desired_fps: spec.desired_fps,
                cpu_work: profile.cpu_work_gpu_mode,
                gpu_work: profile.gpu_work,
                // Solo latency = 1/max_fps_gpu on both device legs.
                cpu_parallelism: (profile.cpu_work_gpu_mode * profile.max_fps_gpu).max(1e-9),
                gpu_parallelism: (profile.gpu_work * profile.max_fps_gpu).max(1e-9),
                id: spec.id(),
            },
        };
        self.streams.push(exec);
    }

    /// Run the simulation with the engine selected by `config.engine`,
    /// sharded across `config.parallelism.sim_threads` workers (see the
    /// `shard` submodule).  Results are bit-identical for every thread
    /// count: instances are independent, shards are merged in
    /// instance-id order, and a single worker exercises the identical
    /// partition/merge code path.
    pub fn run(&mut self, config: SimConfig) -> SimReport {
        super::shard::run_sharded(self, config)
    }

    /// Run directly on the selected engine, unsharded — the per-shard
    /// entry point.
    pub(crate) fn run_engine(&mut self, config: SimConfig) -> SimReport {
        match config.engine {
            SimEngine::Event => super::event::run_event(self, config),
            SimEngine::FixedStep => self.run_fixed(config),
        }
    }

    /// The fixed-step fluid engine.
    ///
    /// Advances only instances with queued or arriving work per tick:
    /// each instance tracks its earliest pending arrival and queued-job
    /// count, and a tick touches an instance only when one of them is
    /// due (the ROADMAP's "stop ticking idle instances").  Instances
    /// are independent — per-instance queues never interact — so
    /// skipping an idle instance cannot change any other's dynamics,
    /// and the skipped spans are credited to the utilization meters as
    /// batched zero-utilization time (identical integral, so reported
    /// means match the always-ticking engine to float rounding).
    pub fn run_fixed(&mut self, config: SimConfig) -> SimReport {
        let steps = (config.duration_s / config.dt).round() as u64;
        let n_streams = self.streams.len();
        let mut queues: Vec<Vec<Job>> = vec![Vec::new(); n_streams];
        let mut next_arrival: Vec<f64> = self
            .streams
            .iter()
            .map(|s| if s.desired_fps > 0.0 { 0.0 } else { f64::INFINITY })
            .collect();
        let mut completed = vec![0u64; n_streams];
        let mut dropped = 0u64;

        // Group streams and devices per instance so idle instances are
        // skipped wholesale instead of re-scanned every tick.
        let mut instances: Vec<usize> = self.device_names.iter().map(|(i, _)| *i).collect();
        instances.sort_unstable();
        instances.dedup();
        let inst_pos: BTreeMap<usize, usize> =
            instances.iter().enumerate().map(|(p, &i)| (i, p)).collect();
        let mut inst_streams: Vec<Vec<usize>> = vec![Vec::new(); instances.len()];
        for (s, exec) in self.streams.iter().enumerate() {
            inst_streams[inst_pos[&exec.instance]].push(s);
        }
        let mut inst_devices: Vec<Vec<usize>> = vec![Vec::new(); instances.len()];
        for (&(inst, _slot), &dev) in self.device_index.iter() {
            inst_devices[inst_pos[&inst]].push(dev);
        }

        // Per-instance activity state: earliest pending arrival, queued
        // jobs, and how much simulated time its meters already cover.
        let mut wake: Vec<f64> = inst_streams
            .iter()
            .map(|streams| {
                streams
                    .iter()
                    .map(|&s| next_arrival[s])
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let mut queued: Vec<usize> = vec![0; instances.len()];
        let mut metered: Vec<f64> = vec![0.0; instances.len()];

        // The stream → device mapping is immutable for the whole run:
        // resolve it once so the hot loop never touches the BTreeMap.
        let cpu_dev: Vec<usize> = self
            .streams
            .iter()
            .map(|e| self.device_index[&(e.instance, 0)])
            .collect();
        let gpu_dev: Vec<Option<usize>> = self
            .streams
            .iter()
            .map(|e| e.gpu_index.map(|g| self.device_index[&(e.instance, 1 + g)]))
            .collect();

        // Scratch reused across ticks — the per-tick allocations of the
        // old engine are gone along with the idle scans.  Demand lists
        // are bucketed per device and cleared after each device's fill,
        // so gathering is one pass over the instance's streams.
        let mut dev_demands: Vec<Vec<(usize, f64)>> = vec![Vec::new(); self.devices.len()];
        let mut rates: Vec<f64> = Vec::new();
        let mut fill_scratch: Vec<usize> = Vec::new();

        for step in 0..steps {
            let now = step as f64 * config.dt;
            for ip in 0..instances.len() {
                if queued[ip] == 0 && wake[ip] > now {
                    continue; // idle: nothing queued, no arrival due
                }
                // Credit the skipped idle span before resuming metering.
                if metered[ip] < now {
                    let gap = now - metered[ip];
                    for &dev in &inst_devices[ip] {
                        self.devices[dev].meter.record(0.0, gap);
                    }
                    metered[ip] = now;
                }

                // 1. Frame arrivals.
                for &s in &inst_streams[ip] {
                    while next_arrival[s] <= now {
                        next_arrival[s] += 1.0 / self.streams[s].desired_fps;
                        if queues[s].len() >= config.queue_cap {
                            dropped += 1;
                            continue;
                        }
                        queues[s].push(Job {
                            stream: s,
                            remaining_cpu: self.streams[s].cpu_work,
                            remaining_gpu: self.streams[s].gpu_work,
                        });
                        queued[ip] += 1;
                    }
                }

                // 2. Capacity allocation per device (water-filling over
                //    the *oldest active job of each stream* — frames of
                //    one stream are processed in order, streams share
                //    fairly), then utilization accounting.  One pass
                //    over the instance's streams buckets demands by
                //    device (stream order preserved per device, so
                //    rates are identical to the former global scan).
                for &s in &inst_streams[ip] {
                    let Some(job) = queues[s].first() else { continue };
                    let exec = &self.streams[s];
                    if job.remaining_cpu > 0.0 {
                        dev_demands[cpu_dev[s]].push((s, exec.cpu_parallelism));
                    }
                    if job.remaining_gpu > 0.0 {
                        if let Some(gd) = gpu_dev[s] {
                            dev_demands[gd].push((s, exec.gpu_parallelism));
                        }
                    }
                }
                for &dev in &inst_devices[ip] {
                    let mut used = 0.0f64;
                    if !dev_demands[dev].is_empty() {
                        water_fill_into(
                            self.devices[dev].capacity,
                            &dev_demands[dev],
                            &mut rates,
                            &mut fill_scratch,
                        );
                        for ((s, _cap), rate) in dev_demands[dev].iter().zip(&rates) {
                            let job = &mut queues[*s][0];
                            if cpu_dev[*s] == dev {
                                job.remaining_cpu -= rate * config.dt;
                            } else {
                                job.remaining_gpu -= rate * config.dt;
                            }
                            used += rate;
                        }
                        dev_demands[dev].clear();
                    }
                    let device = &mut self.devices[dev];
                    let util = if device.capacity > 0.0 { used / device.capacity } else { 0.0 };
                    device.meter.record(util, config.dt);
                }
                metered[ip] = now + config.dt;

                // 3. Completions.
                for &s in &inst_streams[ip] {
                    if let Some(job) = queues[s].first() {
                        if job.remaining_cpu <= 1e-12 && job.remaining_gpu <= 1e-12 {
                            completed[job.stream] += 1;
                            queues[s].remove(0);
                            queued[ip] -= 1;
                        }
                    }
                }

                // 4. Next wake-up: the earliest pending arrival (queued
                //    work keeps the instance active regardless).
                wake[ip] = inst_streams[ip]
                    .iter()
                    .map(|&s| next_arrival[s])
                    .fold(f64::INFINITY, f64::min);
            }
        }

        // Flush trailing idle time so every meter covers the full run.
        let end = steps as f64 * config.dt;
        for ip in 0..instances.len() {
            if metered[ip] < end {
                let gap = end - metered[ip];
                for &dev in &inst_devices[ip] {
                    self.devices[dev].meter.record(0.0, gap);
                }
            }
        }

        self.report(&completed, dropped, config.duration_s)
    }

    /// Assemble the [`SimReport`] from final engine state (shared by
    /// both engines so the facade stays identical).
    pub(crate) fn report(&self, completed: &[u64], dropped: u64, duration_s: f64) -> SimReport {
        let streams = self
            .streams
            .iter()
            .enumerate()
            .map(|(s, exec)| StreamPerf {
                stream_id: exec.id.clone(),
                desired_fps: exec.desired_fps,
                achieved_fps: completed[s] as f64 / duration_s,
            })
            .collect();
        let device_utilization = self
            .devices
            .iter()
            .enumerate()
            .map(|(i, d)| {
                (
                    self.device_names[i].clone(),
                    (d.meter.mean(), d.meter.peak()),
                )
            })
            .collect();
        SimReport {
            streams,
            device_utilization,
            frames_completed: completed.iter().sum(),
            frames_dropped: dropped,
            duration_s,
        }
    }
}

/// Water-filling: split `capacity` among demands with per-demand caps.
/// Returns the rate granted to each demand.  (Reference wrapper kept
/// for the unit tests; both engines run the allocation-free
/// [`water_fill_into`] in their hot loops.)
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn water_fill(capacity: f64, demands: &[(usize, f64)]) -> Vec<f64> {
    let mut rates = Vec::new();
    let mut open = Vec::new();
    water_fill_into(capacity, demands, &mut rates, &mut open);
    rates
}

/// Allocation-free [`water_fill`]: writes the granted rates into
/// `rates` using `open` as scratch — the event engine calls this on
/// every rate re-solve, so the hot path must not allocate.
pub(crate) fn water_fill_into(
    capacity: f64,
    demands: &[(usize, f64)],
    rates: &mut Vec<f64>,
    open: &mut Vec<usize>,
) {
    rates.clear();
    rates.resize(demands.len(), 0.0);
    open.clear();
    open.extend(0..demands.len());
    let mut remaining = capacity;
    // Iteratively give each open demand an equal share, capping at its
    // parallelism; repeat with the leftover.
    while !open.is_empty() && remaining > 1e-12 {
        let share = remaining / open.len() as f64;
        let mut kept = 0;
        let mut leftover = 0.0;
        let mut idx = 0;
        while idx < open.len() {
            let i = open[idx];
            idx += 1;
            let cap = demands[i].1;
            let want = cap - rates[i];
            if want <= share {
                rates[i] = cap;
                leftover += share - want;
            } else {
                rates[i] += share;
                open[kept] = i;
                kept += 1;
            }
        }
        if kept == open.len() {
            // Nobody hit their cap: allocation is final.
            break;
        }
        open.truncate(kept);
        remaining = leftover;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::manager::{ResourceManager, Strategy};
    use crate::profiler::calibration::Calibration;
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};

    const BOTH_ENGINES: [SimEngine; 2] = [SimEngine::Event, SimEngine::FixedStep];

    fn simulate(
        streams: Vec<StreamSpec>,
        strategy: Strategy,
        duration: f64,
        engine: SimEngine,
    ) -> (SimReport, crate::manager::AllocationPlan) {
        let cal = Calibration::paper();
        let catalog = Catalog::paper_experiments();
        let mgr = ResourceManager::new(catalog.clone(), &cal);
        let plan = mgr.allocate(&streams, strategy).unwrap();
        let layout = catalog.layout();
        let profiles: Vec<_> = streams
            .iter()
            .map(|s| cal.profile(s.program, s.camera.frame_size))
            .collect();
        let mut sim = Simulation::from_plan(&plan, &streams, layout, &profiles, &catalog);
        let report = sim.run(SimConfig::for_duration(duration).with_engine(engine));
        (report, plan)
    }

    #[test]
    fn water_fill_respects_caps_and_capacity() {
        let rates = water_fill(10.0, &[(0, 2.0), (1, 100.0)]);
        assert!((rates[0] - 2.0).abs() < 1e-9);
        assert!((rates[1] - 8.0).abs() < 1e-9);
        let rates = water_fill(4.0, &[(0, 3.0), (1, 3.0)]);
        assert!((rates[0] - 2.0).abs() < 1e-9 && (rates[1] - 2.0).abs() < 1e-9);
        let total: f64 = water_fill(1.0, &[(0, 0.4), (1, 0.4)]).iter().sum();
        assert!(total <= 1.0 + 1e-9);
    }

    #[test]
    fn engine_strings_round_trip() {
        assert_eq!("event".parse::<SimEngine>().unwrap(), SimEngine::Event);
        assert_eq!("fixed".parse::<SimEngine>().unwrap(), SimEngine::FixedStep);
        assert_eq!("fixed-step".parse::<SimEngine>().unwrap(), SimEngine::FixedStep);
        assert!("fluid".parse::<SimEngine>().is_err());
        assert_eq!(SimEngine::Event.to_string(), "event");
        assert_eq!(SimEngine::default(), SimEngine::Event);
    }

    #[test]
    fn underloaded_instance_meets_rates() {
        // Scenario 2 on one c4.2xlarge: must hit ~100% performance.
        for engine in BOTH_ENGINES {
            let mut streams = StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.20);
            streams.extend(StreamSpec::replicate(10, 1, VGA, Program::Zf, 0.50));
            let (report, _) = simulate(streams, Strategy::St3, 120.0, engine);
            assert!(
                report.overall_performance() > 0.9,
                "{engine}: performance {}",
                report.overall_performance()
            );
            assert_eq!(report.frames_dropped, 0, "{engine}");
            // CPU utilization ~ 6.712/8 = 84%.
            let (mean, _) = report.device_utilization[&(0, "cpu".to_string())];
            assert!((mean - 0.839).abs() < 0.05, "{engine}: cpu util {mean}");
        }
    }

    #[test]
    fn gpu_mode_uses_both_devices() {
        for engine in BOTH_ENGINES {
            let streams = StreamSpec::replicate(0, 4, VGA, Program::Zf, 2.0);
            let (report, plan) = simulate(streams, Strategy::St2, 60.0, engine);
            assert_eq!(plan.instances[0].type_name, "g2.2xlarge");
            let cpu = report.device_utilization[&(0, "cpu".to_string())];
            let gpu = report.device_utilization[&(0, "gpu0".to_string())];
            // 4 streams x 2 fps x 0.88 core-s = 7.04 of 8 cores.
            assert!(cpu.0 > 0.5, "{engine}: cpu util {}", cpu.0);
            assert!(gpu.0 > 0.2, "{engine}: gpu util {}", gpu.0);
            assert!(report.overall_performance() > 0.9, "{engine}");
        }
    }

    #[test]
    fn overload_degrades_performance() {
        // Force overload by simulating a plan, then doubling rates via a
        // hand-built over-subscribed workload on ST2 GPU instance:
        // 3 VGG streams at 3 FPS each = 9 fps total vs max 3.61 per GPU
        // — but the manager would refuse; build sim manually instead.
        for engine in BOTH_ENGINES {
            let cal = Calibration::paper();
            let catalog = Catalog::paper_experiments();
            let streams = StreamSpec::replicate(0, 3, VGA, Program::Vgg16, 3.0);
            // Manager would give 3 instances; cram them onto one by hand.
            let mut sim = Simulation {
                devices: Vec::new(),
                device_index: BTreeMap::new(),
                device_names: Vec::new(),
                streams: Vec::new(),
            };
            sim.add_device(0, 0, "cpu", 8.0);
            sim.add_device(0, 1, "gpu0", 1536.0);
            let layout = catalog.layout();
            for spec in &streams {
                let p = cal.profile(spec.program, spec.camera.frame_size);
                sim.add_stream(0, spec, &p, ExecChoice::Gpu(0), layout);
            }
            let config = SimConfig {
                duration_s: 60.0,
                queue_cap: 8,
                engine,
                ..SimConfig::default()
            };
            let report = sim.run(config);
            // Offered load: GPU 3 x 3 x 353.28 = 3179 > 1536 gpu-cores AND
            // CPU residual 3 x 3 x 2.12 = 19.1 > 8 cores.  The CPU residual
            // is the binding leg (paper Fig. 5: "performance starts to drop
            // ... after the CPU resources get overutilized").
            assert!(report.overall_performance() < 0.7, "{engine}");
            assert!(report.frames_dropped > 0, "{engine}");
            let cpu = report.device_utilization[&(0, "cpu".to_string())];
            assert!(cpu.0 > 0.95, "{engine}: cpu should saturate, got {}", cpu.0);
            let gpu = report.device_utilization[&(0, "gpu0".to_string())];
            assert!(gpu.0 > 0.7, "{engine}: gpu should be busy, got {}", gpu.0);
        }
    }

    #[test]
    fn solo_latency_matches_profile() {
        // One ZF stream on CPU at a low rate: every frame must complete
        // within ~1/0.56 s, performance 100%.
        for engine in BOTH_ENGINES {
            let streams = StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.25);
            let (report, _) = simulate(streams, Strategy::St1, 120.0, engine);
            assert!(report.overall_performance() > 0.95, "{engine}");
            // Utilization: 0.25 * 7.12 / 8 = 22.25%.
            let (mean, _) = report.device_utilization[&(0, "cpu".to_string())];
            assert!((mean - 0.2225).abs() < 0.03, "{engine}: cpu util {mean}");
        }
    }

    #[test]
    fn utilization_linear_in_stream_count() {
        // Fig. 6 shape: utilization grows ~linearly with cameras.
        for engine in BOTH_ENGINES {
            let mut utils = Vec::new();
            for n in [1u32, 2, 3] {
                let streams = StreamSpec::replicate(0, n, VGA, Program::Vgg16, 1.0);
                let (report, _) = simulate(streams, Strategy::St2, 60.0, engine);
                utils.push(report.device_utilization[&(0, "cpu".to_string())].0);
            }
            let r21 = utils[1] / utils[0];
            let r32 = utils[2] / utils[1];
            assert!((r21 - 2.0).abs() < 0.2, "{engine}: ratio {r21}");
            assert!((r32 - 1.5).abs() < 0.15, "{engine}: ratio {r32}");
        }
    }
}
