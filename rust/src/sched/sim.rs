//! The fixed-step fluid simulation engine.

use crate::manager::AllocationPlan;
use crate::metrics::{overall_performance, StreamPerf, UtilizationMeter};
use crate::profiler::{ExecChoice, ResourceProfile};
use crate::streams::StreamSpec;
use crate::types::DimLayout;
use std::collections::BTreeMap;

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Simulated duration in seconds.
    pub duration_s: f64,
    /// Time step (seconds).  10 ms resolves the fastest latencies the
    /// calibrated profiles produce.
    pub dt: f64,
    /// Per-stream job-queue cap; frames arriving beyond it are dropped
    /// (a real ingest pipeline drops frames under backpressure too).
    pub queue_cap: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { duration_s: 120.0, dt: 0.01, queue_cap: 32 }
    }
}

/// One frame in flight.
#[derive(Clone, Debug)]
struct Job {
    stream: usize,
    /// Remaining work per device slot (same indexing as `DeviceSlot`).
    remaining_cpu: f64,
    remaining_gpu: f64,
}

/// A fluid-capacity device on an instance.
#[derive(Clone, Debug)]
struct Device {
    /// Capacity in core-seconds per second.
    capacity: f64,
    meter: UtilizationMeter,
}

/// Per-stream static execution parameters derived from profile+choice.
#[derive(Clone, Debug)]
struct StreamExec {
    instance: usize,
    /// Device index of the GPU used (instance-local), if GPU mode.
    gpu_index: Option<usize>,
    desired_fps: f64,
    cpu_work: f64,
    gpu_work: f64,
    /// Max draw rates (cores) reproducing the solo latency.
    cpu_parallelism: f64,
    gpu_parallelism: f64,
    id: String,
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub streams: Vec<StreamPerf>,
    /// `(instance_index, device_name) -> (mean, peak)` utilization.
    pub device_utilization: BTreeMap<(usize, String), (f64, f64)>,
    pub frames_completed: u64,
    pub frames_dropped: u64,
    pub duration_s: f64,
}

impl SimReport {
    /// The paper's overall performance (average of per-stream ratios).
    pub fn overall_performance(&self) -> f64 {
        overall_performance(&self.streams)
    }

    /// Highest mean utilization across devices of one instance.
    pub fn max_mean_utilization(&self) -> f64 {
        self.device_utilization
            .values()
            .map(|(mean, _)| *mean)
            .fold(0.0, f64::max)
    }
}

/// The simulation: instances with devices, streams with assignments.
pub struct Simulation {
    devices: Vec<Device>,
    /// `(instance, slot)` -> device index in `devices`; slot 0 = CPU,
    /// slot 1+g = GPU g.
    device_index: BTreeMap<(usize, usize), usize>,
    device_names: Vec<(usize, String)>,
    streams: Vec<StreamExec>,
}

impl Simulation {
    /// Build a simulation from an allocation plan.
    ///
    /// `resolve_profile` maps a stream index to its resource profile
    /// (the same source the manager used).
    pub fn from_plan(
        plan: &AllocationPlan,
        specs: &[StreamSpec],
        layout: DimLayout,
        resolve_profile: impl Fn(usize) -> ResourceProfile,
        catalog: &crate::cloud::Catalog,
    ) -> Simulation {
        let mut sim = Simulation {
            devices: Vec::new(),
            device_index: BTreeMap::new(),
            device_names: Vec::new(),
            streams: Vec::new(),
        };
        for (inst_idx, inst) in plan.instances.iter().enumerate() {
            let itype = catalog
                .get(&inst.type_name)
                .unwrap_or_else(|| panic!("unknown instance type {}", inst.type_name));
            sim.add_device(inst_idx, 0, "cpu", itype.cpu_cores);
            for (g, gpu) in itype.gpus.iter().enumerate() {
                sim.add_device(inst_idx, 1 + g, &format!("gpu{g}"), gpu.cores);
            }
            for assign in &inst.streams {
                let profile = resolve_profile(assign.stream_index);
                let spec = &specs[assign.stream_index];
                sim.add_stream(inst_idx, spec, &profile, assign.choice, layout);
            }
        }
        sim
    }

    fn add_device(&mut self, instance: usize, slot: usize, name: &str, capacity: f64) {
        let idx = self.devices.len();
        self.devices.push(Device { capacity, meter: UtilizationMeter::new() });
        self.device_index.insert((instance, slot), idx);
        self.device_names.push((instance, name.to_string()));
    }

    fn add_stream(
        &mut self,
        instance: usize,
        spec: &StreamSpec,
        profile: &ResourceProfile,
        choice: ExecChoice,
        _layout: DimLayout,
    ) {
        let exec = match choice {
            ExecChoice::Cpu => StreamExec {
                instance,
                gpu_index: None,
                desired_fps: spec.desired_fps,
                cpu_work: profile.cpu_work_cpu_mode,
                gpu_work: 0.0,
                cpu_parallelism: (profile.cpu_work_cpu_mode * profile.max_fps_cpu).max(1e-9),
                gpu_parallelism: 0.0,
                id: spec.id(),
            },
            ExecChoice::Gpu(g) => StreamExec {
                instance,
                gpu_index: Some(g),
                desired_fps: spec.desired_fps,
                cpu_work: profile.cpu_work_gpu_mode,
                gpu_work: profile.gpu_work,
                // Solo latency = 1/max_fps_gpu on both device legs.
                cpu_parallelism: (profile.cpu_work_gpu_mode * profile.max_fps_gpu).max(1e-9),
                gpu_parallelism: (profile.gpu_work * profile.max_fps_gpu).max(1e-9),
                id: spec.id(),
            },
        };
        self.streams.push(exec);
    }

    /// Run the simulation.
    pub fn run(&mut self, config: SimConfig) -> SimReport {
        let steps = (config.duration_s / config.dt).round() as u64;
        let mut queues: Vec<Vec<Job>> = vec![Vec::new(); self.streams.len()];
        let mut next_arrival: Vec<f64> = self
            .streams
            .iter()
            .map(|s| if s.desired_fps > 0.0 { 0.0 } else { f64::INFINITY })
            .collect();
        let mut completed = vec![0u64; self.streams.len()];
        let mut dropped = 0u64;

        for step in 0..steps {
            let now = step as f64 * config.dt;

            // 1. Frame arrivals.
            for (s, exec) in self.streams.iter().enumerate() {
                while next_arrival[s] <= now {
                    next_arrival[s] += 1.0 / exec.desired_fps;
                    if queues[s].len() >= config.queue_cap {
                        dropped += 1;
                        continue;
                    }
                    queues[s].push(Job {
                        stream: s,
                        remaining_cpu: exec.cpu_work,
                        remaining_gpu: exec.gpu_work,
                    });
                }
            }

            // 2. Capacity allocation per device (water-filling over the
            //    *oldest active job of each stream* — frames of one
            //    stream are processed in order, streams share fairly).
            // Gather demands: (device, job pointer, parallelism cap).
            let mut used = vec![0.0f64; self.devices.len()];
            // Collect per-device active lists.
            let mut active: Vec<Vec<(usize, f64)>> = vec![Vec::new(); self.devices.len()];
            for (s, exec) in self.streams.iter().enumerate() {
                if let Some(job) = queues[s].first() {
                    if job.remaining_cpu > 0.0 {
                        let dev = self.device_index[&(exec.instance, 0)];
                        active[dev].push((s, exec.cpu_parallelism));
                    }
                    if job.remaining_gpu > 0.0 {
                        if let Some(g) = exec.gpu_index {
                            let dev = self.device_index[&(exec.instance, 1 + g)];
                            active[dev].push((s, exec.gpu_parallelism));
                        }
                    }
                }
            }
            // Water-fill each device and apply work.
            for (dev_idx, demands) in active.iter().enumerate() {
                if demands.is_empty() {
                    continue;
                }
                let rates = water_fill(self.devices[dev_idx].capacity, demands);
                for ((s, _cap), rate) in demands.iter().zip(&rates) {
                    let job = &mut queues[*s][0];
                    let is_cpu_leg = {
                        let exec = &self.streams[*s];
                        self.device_index[&(exec.instance, 0)] == dev_idx
                    };
                    if is_cpu_leg {
                        job.remaining_cpu -= rate * config.dt;
                    } else {
                        job.remaining_gpu -= rate * config.dt;
                    }
                    used[dev_idx] += rate;
                }
            }

            // 3. Completions.
            for queue in queues.iter_mut() {
                if let Some(job) = queue.first() {
                    if job.remaining_cpu <= 1e-12 && job.remaining_gpu <= 1e-12 {
                        completed[job.stream] += 1;
                        queue.remove(0);
                    }
                }
            }

            // 4. Utilization accounting.
            for (dev_idx, device) in self.devices.iter_mut().enumerate() {
                let util = if device.capacity > 0.0 {
                    used[dev_idx] / device.capacity
                } else {
                    0.0
                };
                device.meter.record(util, config.dt);
            }
        }

        let streams = self
            .streams
            .iter()
            .enumerate()
            .map(|(s, exec)| StreamPerf {
                stream_id: exec.id.clone(),
                desired_fps: exec.desired_fps,
                achieved_fps: completed[s] as f64 / config.duration_s,
            })
            .collect();
        let device_utilization = self
            .devices
            .iter()
            .enumerate()
            .map(|(i, d)| {
                (
                    self.device_names[i].clone(),
                    (d.meter.mean(), d.meter.peak()),
                )
            })
            .collect();
        SimReport {
            streams,
            device_utilization,
            frames_completed: completed.iter().sum(),
            frames_dropped: dropped,
            duration_s: config.duration_s,
        }
    }
}

/// Water-filling: split `capacity` among demands with per-demand caps.
/// Returns the rate granted to each demand.
fn water_fill(capacity: f64, demands: &[(usize, f64)]) -> Vec<f64> {
    let mut rates = vec![0.0f64; demands.len()];
    let mut remaining = capacity;
    let mut open: Vec<usize> = (0..demands.len()).collect();
    // Iteratively give each open demand an equal share, capping at its
    // parallelism; repeat with the leftover.
    while !open.is_empty() && remaining > 1e-12 {
        let share = remaining / open.len() as f64;
        let mut next_open = Vec::with_capacity(open.len());
        let mut leftover = 0.0;
        for &i in &open {
            let cap = demands[i].1;
            let want = cap - rates[i];
            if want <= share {
                rates[i] = cap;
                leftover += share - want;
            } else {
                rates[i] += share;
                next_open.push(i);
            }
        }
        if next_open.len() == open.len() {
            // Nobody hit their cap: allocation is final.
            break;
        }
        open = next_open;
        remaining = leftover;
    }
    rates
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::manager::{ResourceManager, Strategy};
    use crate::profiler::calibration::Calibration;
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};

    fn simulate(
        streams: Vec<StreamSpec>,
        strategy: Strategy,
        duration: f64,
    ) -> (SimReport, crate::manager::AllocationPlan) {
        let cal = Calibration::paper();
        let catalog = Catalog::paper_experiments();
        let mgr = ResourceManager::new(catalog.clone(), &cal);
        let plan = mgr.allocate(&streams, strategy).unwrap();
        let layout = catalog.layout();
        let mut sim = Simulation::from_plan(
            &plan,
            &streams,
            layout,
            |i| cal.profile(streams[i].program, streams[i].camera.frame_size),
            &catalog,
        );
        let report = sim.run(SimConfig { duration_s: duration, dt: 0.01, queue_cap: 32 });
        (report, plan)
    }

    #[test]
    fn water_fill_respects_caps_and_capacity() {
        let rates = water_fill(10.0, &[(0, 2.0), (1, 100.0)]);
        assert!((rates[0] - 2.0).abs() < 1e-9);
        assert!((rates[1] - 8.0).abs() < 1e-9);
        let rates = water_fill(4.0, &[(0, 3.0), (1, 3.0)]);
        assert!((rates[0] - 2.0).abs() < 1e-9 && (rates[1] - 2.0).abs() < 1e-9);
        let total: f64 = water_fill(1.0, &[(0, 0.4), (1, 0.4)]).iter().sum();
        assert!(total <= 1.0 + 1e-9);
    }

    #[test]
    fn underloaded_instance_meets_rates() {
        // Scenario 2 on one c4.2xlarge: must hit ~100% performance.
        let mut streams = StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.20);
        streams.extend(StreamSpec::replicate(10, 1, VGA, Program::Zf, 0.50));
        let (report, _) = simulate(streams, Strategy::St3, 120.0);
        assert!(
            report.overall_performance() > 0.9,
            "performance {}",
            report.overall_performance()
        );
        assert_eq!(report.frames_dropped, 0);
        // CPU utilization ~ 6.712/8 = 84%.
        let (mean, _) = report.device_utilization[&(0, "cpu".to_string())];
        assert!((mean - 0.839).abs() < 0.05, "cpu util {mean}");
    }

    #[test]
    fn gpu_mode_uses_both_devices() {
        let streams = StreamSpec::replicate(0, 4, VGA, Program::Zf, 2.0);
        let (report, plan) = simulate(streams, Strategy::St2, 60.0);
        assert_eq!(plan.instances[0].type_name, "g2.2xlarge");
        let cpu = report.device_utilization[&(0, "cpu".to_string())];
        let gpu = report.device_utilization[&(0, "gpu0".to_string())];
        // 4 streams x 2 fps: cpu 8*0.88/8 = 88%... wait: 4*2*0.88 = 7.04/8.
        assert!(cpu.0 > 0.5, "cpu util {}", cpu.0);
        assert!(gpu.0 > 0.2, "gpu util {}", gpu.0);
        assert!(report.overall_performance() > 0.9);
    }

    #[test]
    fn overload_degrades_performance() {
        // Force overload by simulating a plan, then doubling rates via a
        // hand-built over-subscribed workload on ST2 GPU instance:
        // 3 VGG streams at 3 FPS each = 9 fps total vs max 3.61 per GPU
        // — but the manager would refuse; build sim manually instead.
        let cal = Calibration::paper();
        let catalog = Catalog::paper_experiments();
        let streams = StreamSpec::replicate(0, 3, VGA, Program::Vgg16, 3.0);
        // Manager would give 3 instances; cram them onto one by hand.
        let mut sim = Simulation {
            devices: Vec::new(),
            device_index: BTreeMap::new(),
            device_names: Vec::new(),
            streams: Vec::new(),
        };
        sim.add_device(0, 0, "cpu", 8.0);
        sim.add_device(0, 1, "gpu0", 1536.0);
        let layout = catalog.layout();
        for spec in &streams {
            let p = cal.profile(spec.program, spec.camera.frame_size);
            sim.add_stream(0, spec, &p, ExecChoice::Gpu(0), layout);
        }
        let report = sim.run(SimConfig { duration_s: 60.0, dt: 0.01, queue_cap: 8 });
        // Offered load: GPU 3 x 3 x 353.28 = 3179 > 1536 gpu-cores AND
        // CPU residual 3 x 3 x 2.12 = 19.1 > 8 cores.  The CPU residual
        // is the binding leg (paper Fig. 5: "performance starts to drop
        // ... after the CPU resources get overutilized").
        assert!(report.overall_performance() < 0.7);
        assert!(report.frames_dropped > 0);
        let cpu = report.device_utilization[&(0, "cpu".to_string())];
        assert!(cpu.0 > 0.95, "cpu should saturate, got {}", cpu.0);
        let gpu = report.device_utilization[&(0, "gpu0".to_string())];
        assert!(gpu.0 > 0.7, "gpu should be busy, got {}", gpu.0);
    }

    #[test]
    fn solo_latency_matches_profile() {
        // One ZF stream on CPU at a low rate: every frame must complete
        // within ~1/0.56 s, performance 100%.
        let streams = StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.25);
        let (report, _) = simulate(streams, Strategy::St1, 120.0);
        assert!(report.overall_performance() > 0.95);
        // Utilization: 0.25 * 7.12 / 8 = 22.25%.
        let (mean, _) = report.device_utilization[&(0, "cpu".to_string())];
        assert!((mean - 0.2225).abs() < 0.03, "cpu util {mean}");
    }

    #[test]
    fn utilization_linear_in_stream_count() {
        // Fig. 6 shape: utilization grows ~linearly with cameras.
        let mut utils = Vec::new();
        for n in [1u32, 2, 3] {
            let streams = StreamSpec::replicate(0, n, VGA, Program::Vgg16, 1.0);
            let (report, _) = simulate(streams, Strategy::St2, 60.0);
            utils.push(report.device_utilization[&(0, "cpu".to_string())].0);
        }
        let r21 = utils[1] / utils[0];
        let r32 = utils[2] / utils[1];
        assert!((r21 - 2.0).abs() < 0.2, "ratio {r21}");
        assert!((r32 - 1.5).abs() < 0.15, "ratio {r32}");
    }
}
