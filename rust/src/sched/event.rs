//! Event-driven discrete-event simulation engine.
//!
//! Executes the same fluid processor-sharing model as the fixed-step
//! engine, but only does work when the system actually changes:
//!
//! * **frame arrival** — a stream's next frame joins its queue (or is
//!   dropped at the cap);
//! * **service completion** — the head frame of some stream finishes
//!   both device legs and leaves;
//! * the final flush at `duration_s`.
//!
//! Between events every service rate is constant, so each instance
//! advances lazily: work and utilization meters are integrated over the
//! elapsed span only when one of *its* streams has an event.  Rates are
//! re-solved (water-filling per device) for the affected instance
//! alone, and a per-instance generation counter invalidates stale
//! completion wake-ups in the heap.
//!
//! Cost is O(events x streams-per-instance x log events) instead of the
//! fixed-step engine's O(duration/dt x total streams): at fleet scale
//! (1,000+ streams spread over hundreds of instances) that is orders of
//! magnitude less work, and the result is *exact* rather than
//! tick-quantized.

use super::sim::{water_fill_into, SimConfig, SimReport, Simulation};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Work below this is considered finished (float-residue clamp).
const WORK_EPS: f64 = 1e-12;
/// Completion wake-ups are scheduled at least this far ahead so event
/// time strictly advances even when float rounding leaves sub-ulp
/// residues on a leg.
const MIN_DT: f64 = 1e-9;

/// One frame in flight (event engine).
struct EvJob {
    remaining_cpu: f64,
    remaining_gpu: f64,
}

enum EventKind {
    /// Next frame of `stream` arrives.
    Arrival { stream: usize },
    /// Wake-up to harvest completions on `instance`; stale when the
    /// instance's rates changed since it was scheduled.
    Completion { instance: usize, generation: u64 },
}

struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .expect("event times are never NaN")
            .then(self.seq.cmp(&other.seq))
    }
}

/// Mutable engine state, split from the borrowed `Simulation` topology.
struct EngineState {
    queues: Vec<VecDeque<EvJob>>,
    rate_cpu: Vec<f64>,
    rate_gpu: Vec<f64>,
    completed: Vec<u64>,
    dropped: u64,
    /// Current total allocated rate per device (for utilization).
    used: Vec<f64>,
    /// Per-instance lazy-advance clock.
    last_update: Vec<f64>,
    /// Per-instance rate generation (invalidates stale wake-ups).
    generation: Vec<u64>,
    /// Scratch buffers so the per-event hot path never allocates.
    demand_scratch: Vec<(usize, f64)>,
    rates_scratch: Vec<f64>,
    open_scratch: Vec<usize>,
}

/// Static topology lookups precomputed from the `Simulation`.
struct Topology {
    /// CPU device index per stream.
    cpu_dev: Vec<usize>,
    /// GPU device index per stream (GPU-mode streams only).
    gpu_dev: Vec<Option<usize>>,
    /// Inter-arrival period per stream (`1/fps`; infinity when idle).
    period: Vec<f64>,
    /// Streams hosted per instance.
    streams_of: Vec<Vec<usize>>,
    /// Devices per instance.
    devices_of: Vec<Vec<usize>>,
    /// Owning instance per stream.
    instance_of: Vec<usize>,
}

impl Topology {
    fn build(sim: &Simulation) -> Topology {
        let n_instances = sim
            .device_index
            .keys()
            .map(|(inst, _)| inst + 1)
            .max()
            .unwrap_or(0);
        let mut cpu_dev = Vec::with_capacity(sim.streams.len());
        let mut gpu_dev = Vec::with_capacity(sim.streams.len());
        let mut period = Vec::with_capacity(sim.streams.len());
        let mut streams_of = vec![Vec::new(); n_instances];
        let mut instance_of = Vec::with_capacity(sim.streams.len());
        for (s, exec) in sim.streams.iter().enumerate() {
            cpu_dev.push(sim.device_index[&(exec.instance, 0)]);
            gpu_dev.push(exec.gpu_index.map(|g| sim.device_index[&(exec.instance, 1 + g)]));
            period.push(if exec.desired_fps > 0.0 {
                1.0 / exec.desired_fps
            } else {
                f64::INFINITY
            });
            streams_of[exec.instance].push(s);
            instance_of.push(exec.instance);
        }
        let mut devices_of = vec![Vec::new(); n_instances];
        for (&(inst, _slot), &dev) in &sim.device_index {
            devices_of[inst].push(dev);
        }
        Topology { cpu_dev, gpu_dev, period, streams_of, devices_of, instance_of }
    }
}

/// Run `sim` under the event-driven engine.
pub(crate) fn run_event(sim: &mut Simulation, config: SimConfig) -> SimReport {
    let n_streams = sim.streams.len();
    let topo = Topology::build(sim);
    let n_instances = topo.streams_of.len();
    let mut state = EngineState {
        queues: (0..n_streams).map(|_| VecDeque::new()).collect(),
        rate_cpu: vec![0.0; n_streams],
        rate_gpu: vec![0.0; n_streams],
        completed: vec![0u64; n_streams],
        dropped: 0,
        used: vec![0.0; sim.devices.len()],
        last_update: vec![0.0; n_instances],
        generation: vec![0u64; n_instances],
        demand_scratch: Vec::new(),
        rates_scratch: Vec::new(),
        open_scratch: Vec::new(),
    };

    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut push = |heap: &mut BinaryHeap<Reverse<Event>>, time: f64, kind: EventKind| {
        heap.push(Reverse(Event { time, seq, kind }));
        seq += 1;
    };

    for (s, exec) in sim.streams.iter().enumerate() {
        if exec.desired_fps > 0.0 && config.duration_s > 0.0 {
            push(&mut heap, 0.0, EventKind::Arrival { stream: s });
        }
    }

    while let Some(Reverse(event)) = heap.pop() {
        match event.kind {
            EventKind::Arrival { stream } => {
                let inst = topo.instance_of[stream];
                advance(sim, &topo, &mut state, inst, event.time);
                let harvested = harvest(&topo, &mut state, inst);
                // Enqueue the frame (or drop at the cap) and schedule the
                // stream's next arrival inside the horizon.
                let was_empty = state.queues[stream].is_empty();
                let mut enqueued = false;
                if state.queues[stream].len() >= config.queue_cap {
                    state.dropped += 1;
                } else {
                    let exec = &sim.streams[stream];
                    state.queues[stream].push_back(EvJob {
                        remaining_cpu: exec.cpu_work,
                        remaining_gpu: exec.gpu_work,
                    });
                    enqueued = true;
                }
                let next = event.time + topo.period[stream];
                if next < config.duration_s {
                    push(&mut heap, next, EventKind::Arrival { stream });
                }
                // Rates only change when some head frame changed: a frame
                // queued behind a busy head (or dropped) leaves every
                // service rate — and the pending wake-up — valid.
                if harvested || (was_empty && enqueued) {
                    recompute(sim, &topo, &mut state, inst, event.time, config.duration_s, |t, k| {
                        push(&mut heap, t, k)
                    });
                }
            }
            EventKind::Completion { instance, generation } => {
                if generation != state.generation[instance] {
                    continue; // stale wake-up: rates changed since scheduling
                }
                advance(sim, &topo, &mut state, instance, event.time);
                harvest(&topo, &mut state, instance);
                recompute(sim, &topo, &mut state, instance, event.time, config.duration_s, |t, k| {
                    push(&mut heap, t, k)
                });
            }
        }
    }

    // Final flush: integrate meters/work up to the horizon and harvest
    // frames finishing exactly at the end (the fixed-step engine counts
    // completions through its last tick too).
    for inst in 0..n_instances {
        advance(sim, &topo, &mut state, inst, config.duration_s);
        harvest(&topo, &mut state, inst);
    }

    sim.report(&state.completed, state.dropped, config.duration_s)
}

/// Integrate the instance's meters and in-flight work from its last
/// update to `now` (rates are constant over that span).
fn advance(sim: &mut Simulation, topo: &Topology, state: &mut EngineState, inst: usize, now: f64) {
    let dt = now - state.last_update[inst];
    if dt <= 0.0 {
        return;
    }
    state.last_update[inst] = now;
    for &dev in &topo.devices_of[inst] {
        let device = &mut sim.devices[dev];
        let util = if device.capacity > 0.0 {
            state.used[dev] / device.capacity
        } else {
            0.0
        };
        device.meter.record(util, dt);
    }
    for &s in &topo.streams_of[inst] {
        if let Some(job) = state.queues[s].front_mut() {
            if state.rate_cpu[s] > 0.0 {
                let left = job.remaining_cpu - state.rate_cpu[s] * dt;
                job.remaining_cpu = if left <= WORK_EPS { 0.0 } else { left };
            }
            if state.rate_gpu[s] > 0.0 {
                let left = job.remaining_gpu - state.rate_gpu[s] * dt;
                job.remaining_gpu = if left <= WORK_EPS { 0.0 } else { left };
            }
        }
    }
}

/// Pop completed head frames on the instance's streams; reports
/// whether any head changed (rates must be re-solved then).
fn harvest(topo: &Topology, state: &mut EngineState, inst: usize) -> bool {
    let mut any = false;
    for &s in &topo.streams_of[inst] {
        while let Some(job) = state.queues[s].front() {
            if job.remaining_cpu <= WORK_EPS && job.remaining_gpu <= WORK_EPS {
                state.queues[s].pop_front();
                state.completed[s] += 1;
                any = true;
            } else {
                break;
            }
        }
    }
    any
}

/// Re-solve the instance's processor-sharing rates (water-filling per
/// device over the head frame of each stream) and schedule the next
/// completion wake-up.
fn recompute(
    sim: &Simulation,
    topo: &Topology,
    state: &mut EngineState,
    inst: usize,
    now: f64,
    horizon: f64,
    mut push: impl FnMut(f64, EventKind),
) {
    state.generation[inst] += 1;

    // Collect active legs per device of this instance (scratch-buffered:
    // this runs once per head-frame change, so it must not allocate).
    for &s in &topo.streams_of[inst] {
        state.rate_cpu[s] = 0.0;
        state.rate_gpu[s] = 0.0;
    }
    for &dev in &topo.devices_of[inst] {
        state.used[dev] = 0.0;
        state.demand_scratch.clear();
        for &s in &topo.streams_of[inst] {
            let Some(job) = state.queues[s].front() else {
                continue;
            };
            let exec = &sim.streams[s];
            if topo.cpu_dev[s] == dev && job.remaining_cpu > WORK_EPS {
                state.demand_scratch.push((s, exec.cpu_parallelism));
            } else if topo.gpu_dev[s] == Some(dev) && job.remaining_gpu > WORK_EPS {
                state.demand_scratch.push((s, exec.gpu_parallelism));
            }
        }
        if state.demand_scratch.is_empty() {
            continue;
        }
        water_fill_into(
            sim.devices[dev].capacity,
            &state.demand_scratch,
            &mut state.rates_scratch,
            &mut state.open_scratch,
        );
        for (&(s, _cap), &rate) in state.demand_scratch.iter().zip(&state.rates_scratch) {
            if topo.cpu_dev[s] == dev {
                state.rate_cpu[s] = rate;
            } else {
                state.rate_gpu[s] = rate;
            }
            state.used[dev] += rate;
        }
    }

    // Earliest leg completion among head frames at the new rates.
    let mut tmin = f64::INFINITY;
    for &s in &topo.streams_of[inst] {
        if let Some(job) = state.queues[s].front() {
            if job.remaining_cpu > WORK_EPS && state.rate_cpu[s] > 0.0 {
                tmin = tmin.min(job.remaining_cpu / state.rate_cpu[s]);
            }
            if job.remaining_gpu > WORK_EPS && state.rate_gpu[s] > 0.0 {
                tmin = tmin.min(job.remaining_gpu / state.rate_gpu[s]);
            }
        }
    }
    if tmin.is_finite() {
        let at = now + tmin.max(MIN_DT);
        if at <= horizon {
            push(at, EventKind::Completion { instance: inst, generation: state.generation[inst] });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::calibration::Calibration;
    use crate::sched::SimEngine;
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};
    use std::collections::BTreeMap;

    /// One ZF stream at 0.25 FPS on a private 8-core CPU device: 30
    /// arrivals in 120 s, each served in exactly 7.12/3.9872 ≈ 1.7857 s,
    /// so every frame completes and utilization is analytic.
    fn solo_sim() -> Simulation {
        let cal = Calibration::paper();
        let spec = &StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.25)[0];
        let mut sim = Simulation {
            devices: Vec::new(),
            device_index: BTreeMap::new(),
            device_names: Vec::new(),
            streams: Vec::new(),
        };
        sim.add_device(0, 0, "cpu", 8.0);
        let p = cal.profile(Program::Zf, VGA);
        sim.add_stream(
            0,
            spec,
            &p,
            crate::profiler::ExecChoice::Cpu,
            crate::types::DimLayout::new(0),
        );
        sim
    }

    #[test]
    fn solo_stream_completes_every_frame_exactly() {
        let mut sim = solo_sim();
        let report = sim.run(SimConfig::for_duration(120.0));
        // Arrivals at 0, 4, ..., 116 -> 30 frames, all served.
        assert_eq!(report.frames_completed, 30);
        assert_eq!(report.frames_dropped, 0);
        assert!((report.overall_performance() - 1.0).abs() < 1e-9);
        // Busy 30 * 1.7857 s at 3.9872/8 cores utilization.
        let (mean, peak) = report.device_utilization[&(0, "cpu".to_string())];
        let busy = 30.0 * (7.12 / (7.12 * 0.56)) / 120.0;
        let expect = busy * (7.12 * 0.56) / 8.0;
        assert!((mean - expect).abs() < 1e-6, "mean {mean} vs {expect}");
        assert!((peak - (7.12 * 0.56) / 8.0).abs() < 1e-9, "peak {peak}");
    }

    #[test]
    fn event_count_scales_with_arrivals_not_duration() {
        // A low-rate stream over a long horizon must stay exact: the
        // event engine has no dt to accumulate error against.
        let mut sim = solo_sim();
        let report = sim.run(SimConfig::for_duration(1200.0));
        assert_eq!(report.frames_completed, 300);
        assert!((report.overall_performance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn saturated_stream_drops_beyond_queue_cap() {
        // ZF desired 2 FPS on a 2-core device: service takes
        // 7.12/2 = 3.56 s per frame vs a 0.5 s arrival period, so the
        // queue (cap 4) fills and the tail is dropped.
        let cal = Calibration::paper();
        let spec = &StreamSpec::replicate(0, 1, VGA, Program::Zf, 2.0)[0];
        let mut sim = Simulation {
            devices: Vec::new(),
            device_index: BTreeMap::new(),
            device_names: Vec::new(),
            streams: Vec::new(),
        };
        sim.add_device(0, 0, "cpu", 2.0);
        let p = cal.profile(Program::Zf, VGA);
        sim.add_stream(
            0,
            spec,
            &p,
            crate::profiler::ExecChoice::Cpu,
            crate::types::DimLayout::new(0),
        );
        let config = SimConfig {
            duration_s: 60.0,
            queue_cap: 4,
            ..SimConfig::default()
        };
        let report = sim.run(config);
        // Throughput is capacity-bound: 60 s / 3.56 s = 16 completions.
        assert_eq!(report.frames_completed, 16);
        // 120 arrivals, 16 served, 4 still queued -> 100 dropped.
        assert_eq!(report.frames_dropped, 100);
        assert!(report.overall_performance() < 0.15);
        let (mean, _) = report.device_utilization[&(0, "cpu".to_string())];
        assert!(mean > 0.99, "device saturated, got {mean}");
    }

    #[test]
    fn zero_fps_stream_is_inert() {
        let cal = Calibration::paper();
        let spec = StreamSpec::new(
            crate::streams::Camera::new(0, VGA),
            Program::Zf,
            0.0,
        );
        let mut sim = Simulation {
            devices: Vec::new(),
            device_index: BTreeMap::new(),
            device_names: Vec::new(),
            streams: Vec::new(),
        };
        sim.add_device(0, 0, "cpu", 8.0);
        let p = cal.profile(Program::Zf, VGA);
        sim.add_stream(
            0,
            &spec,
            &p,
            crate::profiler::ExecChoice::Cpu,
            crate::types::DimLayout::new(0),
        );
        let report = sim.run(SimConfig::for_duration(10.0).with_engine(SimEngine::Event));
        assert_eq!(report.frames_completed, 0);
        assert_eq!(report.frames_dropped, 0);
        assert_eq!(report.overall_performance(), 1.0); // vacuous target
    }
}
