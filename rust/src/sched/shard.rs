//! Sharded simulation execution.
//!
//! Instances are *independent* given the stream assignments: every
//! stream queues on exactly one instance, service rates are re-solved
//! per instance, and per-instance queues never interact.  A simulation
//! over N instances therefore splits into contiguous instance
//! partitions that run concurrently — one sub-[`Simulation`] per shard
//! on a `std::thread::scope` worker — and the per-shard [`SimReport`]s
//! merge back in instance-id order.
//!
//! **Determinism guarantee.**  The merged report is bit-identical to
//! the single-threaded run for any `sim_threads` value: each
//! instance's event sequence (arrival times, water-filled rates,
//! completion wake-ups, meter integration spans) is a pure function of
//! its own streams, so which shard hosts it — and in which order the
//! shards run — cannot change a single float.  The merge scatters
//! per-stream results back by global stream index and re-bases device
//! keys by the shard's first instance, so ordering is preserved
//! exactly.  The single-worker fallback runs the identical
//! partition/merge code path with one shard covering every instance.

use super::sim::{Device, SimConfig, SimReport, Simulation};
use crate::metrics::{StreamPerf, UtilizationMeter};
use std::collections::BTreeMap;

/// One shard: instances `base..end` of the parent simulation, remapped
/// to local 0-based indices.
struct Shard {
    sim: Simulation,
    /// First parent instance index covered by this shard.
    base: usize,
    /// Parent stream index of each local stream.
    stream_map: Vec<usize>,
}

/// Number of instances in `sim` (max instance index + 1).
fn instance_count(sim: &Simulation) -> usize {
    sim.device_index
        .keys()
        .map(|&(inst, _)| inst + 1)
        .max()
        .unwrap_or(0)
}

/// Extract instances `base..end` into a self-contained sub-simulation.
fn extract(sim: &Simulation, base: usize, end: usize) -> Shard {
    let mut sub = Simulation {
        devices: Vec::new(),
        device_index: BTreeMap::new(),
        device_names: Vec::new(),
        streams: Vec::new(),
    };
    for (&(inst, slot), &dev) in &sim.device_index {
        if !(base..end).contains(&inst) {
            continue;
        }
        let idx = sub.devices.len();
        sub.devices.push(Device {
            capacity: sim.devices[dev].capacity,
            meter: UtilizationMeter::new(),
        });
        sub.device_index.insert((inst - base, slot), idx);
        sub.device_names.push((inst - base, sim.device_names[dev].1.clone()));
    }
    let mut stream_map = Vec::new();
    for (s, exec) in sim.streams.iter().enumerate() {
        if !(base..end).contains(&exec.instance) {
            continue;
        }
        let mut local = exec.clone();
        local.instance -= base;
        sub.streams.push(local);
        stream_map.push(s);
    }
    Shard { sim: sub, base, stream_map }
}

/// Partition, run every shard (concurrently when more than one), and
/// merge — the body of [`Simulation::run`].
pub(super) fn run_sharded(sim: &mut Simulation, config: SimConfig) -> SimReport {
    let n_instances = instance_count(sim);
    let workers = config.parallelism.effective_sim_threads().max(1);
    let shard_count = workers.min(n_instances).max(1);

    // Contiguous instance ranges with sizes differing by at most one.
    let mut shards = Vec::with_capacity(shard_count);
    let chunk = n_instances / shard_count;
    let extra = n_instances % shard_count;
    let mut base = 0usize;
    for i in 0..shard_count {
        let end = base + chunk + usize::from(i < extra);
        shards.push(extract(sim, base, end));
        base = end;
    }

    // The calling thread runs the last shard itself instead of idling
    // in join, so K shards use exactly K threads.
    let reports: Vec<SimReport> = if shards.len() == 1 {
        shards.iter_mut().map(|sh| sh.sim.run_engine(config)).collect()
    } else {
        let (last, rest) = shards.split_last_mut().expect("at least one shard");
        std::thread::scope(|scope| {
            let handles: Vec<_> = rest
                .iter_mut()
                .map(|sh| scope.spawn(move || sh.sim.run_engine(config)))
                .collect();
            let last_report = last.sim.run_engine(config);
            let mut reports: Vec<SimReport> = handles
                .into_iter()
                .map(|h| h.join().expect("simulation shard panicked"))
                .collect();
            reports.push(last_report);
            reports
        })
    };

    merge(sim, config, &shards, reports)
}

/// Merge per-shard reports back into the parent's stream/device
/// numbering.
fn merge(
    sim: &Simulation,
    config: SimConfig,
    shards: &[Shard],
    reports: Vec<SimReport>,
) -> SimReport {
    let mut streams: Vec<Option<StreamPerf>> = (0..sim.streams.len()).map(|_| None).collect();
    let mut device_utilization = BTreeMap::new();
    let mut frames_completed = 0u64;
    let mut frames_dropped = 0u64;
    for (shard, report) in shards.iter().zip(reports) {
        frames_completed += report.frames_completed;
        frames_dropped += report.frames_dropped;
        for (local, perf) in report.streams.into_iter().enumerate() {
            streams[shard.stream_map[local]] = Some(perf);
        }
        for ((inst, name), util) in report.device_utilization {
            device_utilization.insert((inst + shard.base, name), util);
        }
    }
    SimReport {
        streams: streams
            .into_iter()
            .map(|p| p.expect("every stream is simulated in exactly one shard"))
            .collect(),
        device_utilization,
        frames_completed,
        frames_dropped,
        duration_s: config.duration_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::manager::{ResourceManager, Strategy};
    use crate::profiler::calibration::Calibration;
    use crate::sched::Parallelism;
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};

    fn multi_instance_sim() -> Simulation {
        // Scenario-1-like demand under ST1 spreads four streams over
        // four c4.2xlarge instances — enough shards to exercise real
        // partitioning.
        let cal = Calibration::paper();
        let catalog = Catalog::paper_experiments();
        let mgr = ResourceManager::new(catalog.clone(), &cal);
        let mut streams = StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.25);
        streams.extend(StreamSpec::replicate(10, 3, VGA, Program::Zf, 0.55));
        let plan = mgr.allocate(&streams, Strategy::St1).unwrap();
        assert!(plan.instances.len() >= 2, "need a multi-instance plan");
        let profiles: Vec<_> = streams
            .iter()
            .map(|s| cal.profile(s.program, s.camera.frame_size))
            .collect();
        Simulation::from_plan(&plan, &streams, catalog.layout(), &profiles, &catalog)
    }

    fn run_with_threads(threads: usize) -> SimReport {
        let config = SimConfig::for_duration(60.0)
            .with_parallelism(Parallelism { sim_threads: threads, pipeline: true });
        multi_instance_sim().run(config)
    }

    #[test]
    fn shard_counts_clamp_to_instances() {
        let sim = multi_instance_sim();
        let n = instance_count(&sim);
        assert!(n >= 2);
        // Requesting more workers than instances must still cover every
        // instance exactly once.
        let report = run_with_threads(64);
        assert_eq!(report.streams.len(), sim.streams.len());
        assert_eq!(report.device_utilization.len(), sim.devices.len());
    }

    #[test]
    fn sharded_runs_are_bit_identical_across_thread_counts() {
        let reference = run_with_threads(1);
        for threads in [2usize, 3, 8] {
            let report = run_with_threads(threads);
            assert_eq!(report.frames_completed, reference.frames_completed);
            assert_eq!(report.frames_dropped, reference.frames_dropped);
            assert_eq!(report.streams, reference.streams, "{threads} threads");
            assert_eq!(
                report.device_utilization, reference.device_utilization,
                "{threads} threads"
            );
        }
    }

    #[test]
    fn empty_simulation_survives_sharding() {
        let mut sim = Simulation {
            devices: Vec::new(),
            device_index: BTreeMap::new(),
            device_names: Vec::new(),
            streams: Vec::new(),
        };
        let report = sim.run(SimConfig::for_duration(10.0));
        assert_eq!(report.frames_completed, 0);
        assert_eq!(report.frames_dropped, 0);
        assert!(report.streams.is_empty());
    }
}
