//! Sharded simulation execution.
//!
//! Instances are *independent* given the stream assignments: every
//! stream queues on exactly one instance, service rates are re-solved
//! per instance, and per-instance queues never interact.  A simulation
//! over N instances therefore splits into contiguous instance
//! partitions that run concurrently — one sub-[`Simulation`] per shard
//! on a `std::thread::scope` worker — and the per-shard [`SimReport`]s
//! merge back in instance-id order.
//!
//! **Determinism guarantee.**  The merged report is bit-identical to
//! the single-threaded run for any `sim_threads` value: each
//! instance's event sequence (arrival times, water-filled rates,
//! completion wake-ups, meter integration spans) is a pure function of
//! its own streams, so which shard hosts it — and in which order the
//! shards run — cannot change a single float.  The merge scatters
//! per-stream results back by global stream index and re-bases device
//! keys by the shard's first instance, so ordering is preserved
//! exactly.  The single-worker fallback runs the identical
//! partition/merge code path with one shard covering every instance.
//!
//! **Fleet distribution.**  With a worker fleet registered
//! (`net::fleet`), the shard count grows by the ready worker count and
//! one dispatcher thread per worker ships claimed shards over the wire
//! (`simulate` requests) while local threads run shards in-process.
//! The merge contract is partition-invariant — it consumes only shard
//! bases and per-shard reports in instance-id order — and a remote
//! shard's report is the worker's `run_engine` over the identical
//! sub-simulation (floats round-trip the wire bit-exactly), so
//! fleet-sharded runs stay bit-identical to local ones.  A worker that
//! fails has its claimed shard re-run locally (with retries, breaker
//! bookkeeping, and straggler hedging handled by `net::fleet` and
//! `race_chunks_remote`); a malformed reply quarantines the worker.
//! With no fleet registered this module is byte-for-byte the
//! pre-existing local path.

use super::sim::{Device, SimConfig, SimReport, Simulation};
use crate::metrics::{StreamPerf, UtilizationMeter};
use crate::net::fleet::{Fleet, RpcClass, RpcOutcome};
use crate::net::proto;
use crate::packing::solver::{race_chunks_remote, HedgeCfg, RemoteOutcome};
use crate::util::error::{ensure, Result};
use crate::util::json::Json;
use crate::util::profiling;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One shard: instances `base..end` of the parent simulation, remapped
/// to local 0-based indices.
struct Shard {
    sim: Simulation,
    /// First parent instance index covered by this shard.
    base: usize,
    /// Parent stream index of each local stream.
    stream_map: Vec<usize>,
}

/// Number of instances in `sim` (max instance index + 1).
fn instance_count(sim: &Simulation) -> usize {
    sim.device_index
        .keys()
        .map(|&(inst, _)| inst + 1)
        .max()
        .unwrap_or(0)
}

/// Extract instances `base..end` into a self-contained sub-simulation.
fn extract(sim: &Simulation, base: usize, end: usize) -> Shard {
    let mut sub = Simulation {
        devices: Vec::new(),
        device_index: BTreeMap::new(),
        device_names: Vec::new(),
        streams: Vec::new(),
    };
    for (&(inst, slot), &dev) in &sim.device_index {
        if !(base..end).contains(&inst) {
            continue;
        }
        let idx = sub.devices.len();
        sub.devices.push(Device {
            capacity: sim.devices[dev].capacity,
            meter: UtilizationMeter::new(),
        });
        sub.device_index.insert((inst - base, slot), idx);
        sub.device_names.push((inst - base, sim.device_names[dev].1.clone()));
    }
    let mut stream_map = Vec::new();
    for (s, exec) in sim.streams.iter().enumerate() {
        if !(base..end).contains(&exec.instance) {
            continue;
        }
        let mut local = exec.clone();
        local.instance -= base;
        sub.streams.push(local);
        stream_map.push(s);
    }
    Shard { sim: sub, base, stream_map }
}

/// Partition, run every shard (concurrently when more than one), and
/// merge — the body of [`Simulation::run`].
pub(super) fn run_sharded(sim: &mut Simulation, config: SimConfig) -> SimReport {
    let n_instances = instance_count(sim);
    let workers = config.parallelism.effective_sim_threads().max(1);
    // A registered fleet widens the partition by its ready worker
    // count; the merge is partition-invariant, so the shard count
    // (like the thread count) never changes the merged report.
    // `ready_workers` is also the probe point that re-admits `Open`
    // workers whose cooldown elapsed — a worker that restarted
    // mid-trace rejoins here.
    let fleet = crate::net::fleet::active();
    let live = fleet.as_ref().map(|f| f.ready_workers()).unwrap_or_default();
    let shard_count = (workers + live.len()).min(n_instances).max(1);

    // Contiguous instance ranges with sizes differing by at most one.
    let mut shards = Vec::with_capacity(shard_count);
    let chunk = n_instances / shard_count;
    let extra = n_instances % shard_count;
    let mut base = 0usize;
    for i in 0..shard_count {
        let end = base + chunk + usize::from(i < extra);
        shards.push(extract(sim, base, end));
        base = end;
    }

    // The calling thread runs the last shard itself instead of idling
    // in join, so K shards use exactly K threads.
    let reports: Vec<SimReport> = if shards.len() == 1 {
        shards.iter_mut().map(|sh| sh.sim.run_engine(config)).collect()
    } else if let Some(fleet) = fleet.filter(|_| !live.is_empty()) {
        run_mixed(&mut shards, config, &fleet, &live, workers)
    } else {
        let (last, rest) = shards.split_last_mut().expect("at least one shard");
        std::thread::scope(|scope| {
            let handles: Vec<_> = rest
                .iter_mut()
                .map(|sh| scope.spawn(move || sh.sim.run_engine(config)))
                .collect();
            let last_report = last.sim.run_engine(config);
            let mut reports: Vec<SimReport> = handles
                .into_iter()
                .map(|h| h.join().expect("simulation shard panicked"))
                .collect();
            reports.push(last_report);
            reports
        })
    };

    merge(sim, config, &shards, reports)
}

/// Mixed local/remote shard execution on `race_chunks_remote` with a
/// chunk size of one shard: `local_threads` threads run claimed shards
/// in-process while one dispatcher thread per ready fleet worker ships
/// its claims over the wire.  The pool supplies the degradation
/// contract — a failed claim re-runs locally, a straggling claim is
/// hedged — and both copies of a shard's report are the same
/// `run_engine` over the same sub-simulation, so first-wins slot
/// filling cannot change the merge.  Requests serialize under the
/// shard's cell lock but the RPC flies without it, so a hedger can run
/// the shard while the wire is still pending.  Reports land in shard
/// order, feeding the unchanged instance-id-order merge.
fn run_mixed(
    shards: &mut [Shard],
    config: SimConfig,
    fleet: &Arc<Fleet>,
    live: &[usize],
    local_threads: usize,
) -> Vec<SimReport> {
    let count = shards.len();
    let cells: Vec<Mutex<&mut Shard>> = shards.iter_mut().map(Mutex::new).collect();
    let config_json = proto::sim_config_to_json(&config);
    let tuning = fleet.tuning();
    let on_hedge = || fleet.note_hedged();
    let hedge = tuning.hedge.then(|| HedgeCfg {
        after: Duration::from_millis(tuning.hedge_after_ms),
        factor: tuning.hedge_factor,
        on_hedge: &on_hedge,
    });
    let results = race_chunks_remote(
        live.len(),
        local_threads,
        count,
        1,
        hedge,
        |w, range, cancelled| {
            let i = range.start;
            // Serialize under the cell lock, release before the RPC.
            let (request, expected_ids) = {
                let guard = cells[i].lock().expect("shard cell");
                let request = profiling::time_phase("net:serialize", || {
                    Json::obj(vec![
                        ("type".to_string(), Json::Str("simulate".to_string())),
                        ("config".to_string(), config_json.clone()),
                        ("sim".to_string(), proto::sim_to_json(&guard.sim)),
                    ])
                });
                let ids: Vec<String> = guard.sim.streams.iter().map(|s| s.id.clone()).collect();
                (request, ids)
            };
            let reply =
                match fleet.rpc_cancellable(live[w], request, RpcClass::Simulate, cancelled) {
                    RpcOutcome::Reply(reply) => reply,
                    RpcOutcome::Abandoned => return RemoteOutcome::Abandoned,
                    RpcOutcome::Lost => return RemoteOutcome::Failed,
                };
            match profiling::time_phase("net:merge", || decode_sim_reply(&reply, &expected_ids)) {
                Ok(report) => RemoteOutcome::Done(vec![Some(report)]),
                Err(e) => {
                    fleet.report_violation(live[w], &format!("bad sim reply: {e:#}"));
                    RemoteOutcome::Failed
                }
            }
        },
        |i| {
            let mut guard = cells[i].lock().expect("shard cell");
            Some(guard.sim.run_engine(config))
        },
    );
    results
        .into_iter()
        .map(|report| report.expect("every shard produced a report"))
        .collect()
}

/// Decode and sanity-check a worker's `sim_result` reply.  The stream
/// count and per-stream id order must match the shipped shard — the
/// merge scatters by local stream index, so a short or reordered reply
/// must be rejected (re-running the shard locally), never scattered.
fn decode_sim_reply(reply: &Json, expected_ids: &[String]) -> Result<SimReport> {
    let kind = reply.str_field("type")?;
    ensure!(kind == "sim_result", "expected sim_result, got {kind:?}");
    let report = proto::report_from_json(reply.field("report")?)?;
    ensure!(
        report.streams.len() == expected_ids.len(),
        "worker reported {} streams for a {}-stream shard",
        report.streams.len(),
        expected_ids.len()
    );
    for (perf, id) in report.streams.iter().zip(expected_ids) {
        ensure!(
            perf.stream_id == *id,
            "worker stream order mismatch: got {:?}, expected {:?}",
            perf.stream_id,
            id
        );
    }
    Ok(report)
}

/// Merge per-shard reports back into the parent's stream/device
/// numbering.
fn merge(
    sim: &Simulation,
    config: SimConfig,
    shards: &[Shard],
    reports: Vec<SimReport>,
) -> SimReport {
    let mut streams: Vec<Option<StreamPerf>> = (0..sim.streams.len()).map(|_| None).collect();
    let mut device_utilization = BTreeMap::new();
    let mut frames_completed = 0u64;
    let mut frames_dropped = 0u64;
    for (shard, report) in shards.iter().zip(reports) {
        frames_completed += report.frames_completed;
        frames_dropped += report.frames_dropped;
        for (local, perf) in report.streams.into_iter().enumerate() {
            streams[shard.stream_map[local]] = Some(perf);
        }
        for ((inst, name), util) in report.device_utilization {
            device_utilization.insert((inst + shard.base, name), util);
        }
    }
    SimReport {
        streams: streams
            .into_iter()
            .map(|p| p.expect("every stream is simulated in exactly one shard"))
            .collect(),
        device_utilization,
        frames_completed,
        frames_dropped,
        duration_s: config.duration_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::manager::{ResourceManager, Strategy};
    use crate::profiler::calibration::Calibration;
    use crate::sched::Parallelism;
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};

    fn multi_instance_sim() -> Simulation {
        // Scenario-1-like demand under ST1 spreads four streams over
        // four c4.2xlarge instances — enough shards to exercise real
        // partitioning.
        let cal = Calibration::paper();
        let catalog = Catalog::paper_experiments();
        let mgr = ResourceManager::new(catalog.clone(), &cal);
        let mut streams = StreamSpec::replicate(0, 1, VGA, Program::Vgg16, 0.25);
        streams.extend(StreamSpec::replicate(10, 3, VGA, Program::Zf, 0.55));
        let plan = mgr.allocate(&streams, Strategy::St1).unwrap();
        assert!(plan.instances.len() >= 2, "need a multi-instance plan");
        let profiles: Vec<_> = streams
            .iter()
            .map(|s| cal.profile(s.program, s.camera.frame_size))
            .collect();
        Simulation::from_plan(&plan, &streams, catalog.layout(), &profiles, &catalog)
    }

    fn run_with_threads(threads: usize) -> SimReport {
        let config = SimConfig::for_duration(60.0)
            .with_parallelism(Parallelism { sim_threads: threads, pipeline: true });
        multi_instance_sim().run(config)
    }

    #[test]
    fn shard_counts_clamp_to_instances() {
        let sim = multi_instance_sim();
        let n = instance_count(&sim);
        assert!(n >= 2);
        // Requesting more workers than instances must still cover every
        // instance exactly once.
        let report = run_with_threads(64);
        assert_eq!(report.streams.len(), sim.streams.len());
        assert_eq!(report.device_utilization.len(), sim.devices.len());
    }

    #[test]
    fn sharded_runs_are_bit_identical_across_thread_counts() {
        let reference = run_with_threads(1);
        for threads in [2usize, 3, 8] {
            let report = run_with_threads(threads);
            assert_eq!(report.frames_completed, reference.frames_completed);
            assert_eq!(report.frames_dropped, reference.frames_dropped);
            assert_eq!(report.streams, reference.streams, "{threads} threads");
            assert_eq!(
                report.device_utilization, reference.device_utilization,
                "{threads} threads"
            );
        }
    }

    #[test]
    fn empty_simulation_survives_sharding() {
        let mut sim = Simulation {
            devices: Vec::new(),
            device_index: BTreeMap::new(),
            device_names: Vec::new(),
            streams: Vec::new(),
        };
        let report = sim.run(SimConfig::for_duration(10.0));
        assert_eq!(report.frames_completed, 0);
        assert_eq!(report.frames_dropped, 0);
        assert!(report.streams.is_empty());
    }
}
