//! `camcloud` — CLI for the cloud resource manager.
//!
//! ```text
//! camcloud catalog                       print Table 1
//! camcloud profile [--live] [...]        run test runs, save profiles
//! camcloud allocate --scenario N ...     print an allocation plan
//! camcloud run --scenario N ...          allocate + simulate + report
//! camcloud trace --trace emergency ...   online autoscaling over a demand trace
//! camcloud report --all | --table2 ...   regenerate paper tables/figures
//! camcloud worker --listen HOST:PORT     serve solves/simulations to a coordinator
//! camcloud infer --program vgg16 ...     real PJRT inference on frames
//! ```

use camcloud::cloud::{PricingTier, RegionSpec, TierSpec};
use camcloud::config::{paper_scenario, Scenario};
use camcloud::coordinator::{
    AutoscaleConfig, AutoscaleOutcome, AutoscaleRunner, Coordinator, ScalePolicy,
};
use camcloud::manager::{ResourceManager, Strategy};
use camcloud::packing::{SolveBudget, SolverChoice};
use camcloud::profiler::store::ProfileStore;
use camcloud::reports;
use camcloud::runtime::{default_artifacts_dir, ModelRuntime};
use camcloud::sched::{Parallelism, SimConfig, SimEngine};
use camcloud::streams::{Camera, Frame};
use camcloud::types::{Dollars, Program, VGA};
use camcloud::util::cli::Args;
use camcloud::util::json::Json;
use camcloud::workload::trace::WorkloadTrace;
use camcloud::workload::FleetSpec;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("catalog") => cmd_catalog(),
        Some("profile") => cmd_profile(&args),
        Some("allocate") => cmd_allocate(&args),
        Some("run") => cmd_run(&args),
        Some("trace") => cmd_trace(&args),
        Some("report") => cmd_report(&args),
        Some("whatif") => cmd_whatif(&args),
        Some("worker") => cmd_worker(&args),
        Some("infer") => cmd_infer(&args),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}; see `camcloud help`");
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "camcloud — cloud resource manager for network-camera analytics\n\
         (reproduction of Kaseb et al., 2018)\n\n\
         Subcommands:\n\
         \u{20}  catalog                     print the instance catalog (Table 1)\n\
         \u{20}  profile [--live] [--frames N] [--out FILE]\n\
         \u{20}                              estimate resource requirements via test runs\n\
         \u{20}  allocate --scenario N --strategy st1|st2|st3 [--profiles FILE]\n\
         \u{20}  allocate --config FILE ...  allocate a custom JSON workload\n\
         \u{20}  allocate --streams N ...    allocate a synthetic N-camera fleet\n\
         \u{20}  run --scenario N [--strategy stX] [--duration S] [--engine event|fixed]\n\
         \u{20}                              allocate + simulate + performance/cost report\n\
         \u{20}  run --streams N [--seed S] ...\n\
         \u{20}                              same pipeline on a synthetic N-camera fleet\n\
         \u{20}  trace --trace emergency|diurnal|churn|spot|FILE [--policy NAME|all]\n\
         \u{20}        [--strategy stX] [--seed S] [--cameras N] [--epochs N]\n\
         \u{20}        [--horizon H] [--engine event|fixed] [--out FILE] [--profile]\n\
         \u{20}        [--tiers LIST] [--regions N]\n\
         \u{20}        (--tiers name[=factor],... e.g. ondemand,spot=0.3 and --regions N\n\
         \u{20}         overlay tiered/multi-region pricing on the trace's catalog;\n\
         \u{20}         the spot builtin schedules mid-epoch spot revocations;\n\
         \u{20}         --out FILE saves the trace plus per-policy per-epoch results\n\
         \u{20}         with solver, warm/cold mode, and certified gap)\n\
         \u{20}        (--profile prints the per-phase wall-clock table; build with\n\
         \u{20}         --features profiling to record phases)\n\
         \u{20}                              online autoscaling over a demand trace:\n\
         \u{20}                              warm-started per-epoch re-solve + hysteresis,\n\
         \u{20}                              policies static-peak/static-mean/oracle/reactive\n\
         \u{20}  (allocate/run/trace/whatif also accept --solver auto|ffd|bfd|exact|portfolio,\n\
         \u{20}   --solve-budget-ms MS, --exact-cutoff N, and --exact-threads N — 0 = all\n\
         \u{20}   cores — for the solver stack; exact results are bit-identical across\n\
         \u{20}   thread counts)\n\
         \u{20}  (run/trace also accept --sim-threads N for sharded simulation — 0 = all\n\
         \u{20}   cores — and --pipeline on|off to overlap epoch solves with simulation;\n\
         \u{20}   parallel execution changes no results while solves fit the solve budget)\n\
         \u{20}  (run/trace also accept --workers host:port,... to distribute exact-search\n\
         \u{20}   subtrees and simulation shards over camcloud worker processes; outcomes\n\
         \u{20}   are bit-identical to in-process runs.  Transient worker failures retry\n\
         \u{20}   with backoff, lost workers trip a circuit breaker and are re-probed and\n\
         \u{20}   re-admitted when they come back, and straggling claims are hedged\n\
         \u{20}   locally.  --chaos seed=N,connect=R,read-timeout=R,write-timeout=R,\n\
         \u{20}   slow=R,slow-ms=MS,disconnect=R,garbage=R (or CAMCLOUD_CHAOS) arms the\n\
         \u{20}   deterministic fault injector for resilience testing.  trace also\n\
         \u{20}   accepts --solve-cache-file FILE to persist the reactive solve cache\n\
         \u{20}   across runs)\n\
         \u{20}  worker --listen HOST:PORT [--max-requests N]\n\
         \u{20}                              serve exact-search and simulation requests to\n\
         \u{20}                              a coordinator running with --workers\n\
         \u{20}  report --all|--table2|--table3|--table5|--table6|--fig5|--fig6\n\
         \u{20}                              regenerate the paper's tables and figures\n\
         \u{20}  whatif --scenario N [--strategy stX]\n\
         \u{20}                              cost curves vs frame-rate multiplier + cliffs\n\
         \u{20}  infer --program vgg16|zf [--frames N]\n\
         \u{20}                              real PJRT inference on synthetic camera frames"
    );
}

/// `--solver {auto,ffd,bfd,exact,portfolio}` plus the solve-budget
/// knobs (`--solve-budget-ms`, `--exact-cutoff`, `--exact-threads`),
/// shared by every mode that allocates.
fn solver_config(args: &Args) -> Result<(SolverChoice, SolveBudget), String> {
    let choice: SolverChoice = args.opt_or("solver", "auto").parse()?;
    let mut budget = SolveBudget::default();
    if let Some(ms) = args.u32_opt("solve-budget-ms")? {
        budget.time_ms = u64::from(ms);
    }
    if let Some(cutoff) = args.u32_opt("exact-cutoff")? {
        budget.exact_cutoff = cutoff as usize;
    }
    // Multi-root parallel branch-and-bound; completed proofs are
    // bit-identical for any value, so this is a pure wall-clock knob.
    if let Some(threads) = args.u32_opt("exact-threads")? {
        budget.exact_threads = threads as usize;
    }
    Ok((choice, budget))
}

fn coordinator_with_profiles(args: &Args) -> Result<Coordinator, String> {
    let (solver, budget) = solver_config(args)?;
    let mut c = Coordinator::new().with_solver(solver).with_budget(budget);
    if let Some(path) = args.opt("profiles") {
        let store = ProfileStore::load(std::path::Path::new(path))
            .map_err(|e| format!("loading profiles {path}: {e}"))?;
        c = c.with_profiles(store);
    }
    Ok(c)
}

/// A resource manager over `catalog` inheriting the coordinator's
/// solver routing (allocate/whatif construct managers directly).
fn manager_for(
    catalog: camcloud::cloud::Catalog,
    coordinator: &Coordinator,
) -> ResourceManager<'_> {
    ResourceManager::with_routing(catalog, coordinator, coordinator.solver, coordinator.budget)
}

fn load_scenario(args: &Args) -> Result<Scenario, String> {
    if let Some(path) = args.opt("config") {
        return Scenario::load(std::path::Path::new(path))
            .map_err(|e| format!("loading scenario {path}: {e}"));
    }
    // Synthetic-fleet path: `--streams N [--seed S]` generates a seeded
    // N-camera workload instead of loading a scenario.
    if let Some(n) = args.u32_opt("streams")? {
        if n == 0 {
            return Err("--streams expects at least 1".into());
        }
        let seed = args.u32_opt("seed")?.map(u64::from).unwrap_or(7);
        return Ok(FleetSpec::new(n).seed(seed).build().to_scenario());
    }
    let n = args
        .u32_opt("scenario")?
        .ok_or("need --scenario N, --streams N, or --config FILE")?;
    paper_scenario(n).map_err(|e| e.to_string())
}

/// `--sim-threads N` (0 = available parallelism) and `--pipeline
/// on|off`, shared by every simulating mode.  Parallelism does not
/// change results: sharded simulation is bit-identical across thread
/// counts, and the epoch pipeline is deterministic as long as solves
/// finish within their node budget before the `--solve-budget-ms`
/// deadline (the solver stack's own reproducibility precondition).
fn parallelism_config(args: &Args) -> Result<Parallelism, String> {
    let mut parallelism = Parallelism::default();
    if let Some(n) = args.u32_opt("sim-threads")? {
        parallelism.sim_threads = n as usize;
    }
    if let Some(pipeline) = args.bool_opt("pipeline")? {
        parallelism.pipeline = pipeline;
    }
    Ok(parallelism)
}

/// `--workers host:port,...`: register a worker fleet for distributed
/// exact search and sharded simulation (see the `net` module docs).
/// Without the flag everything runs in-process; with it, outcomes are
/// bit-identical — workers are a wall-clock knob, like thread counts.
/// Addresses are validated and deduped before any connection attempt.
///
/// `--chaos key=value,...` (or the `CAMCLOUD_CHAOS` env var) arms the
/// deterministic fault injector for the run — keys `seed`, `connect`,
/// `read-timeout`, `write-timeout`, `slow`, `slow-ms`, `disconnect`,
/// `garbage` (rates in [0,1]).  It is armed *after* fleet registration
/// so the injected schedule exercises the work RPCs, not the initial
/// handshake.
fn apply_workers_flag(args: &Args) -> Result<(), String> {
    if let Some(addrs) = args.list_opt("workers") {
        let addrs =
            camcloud::net::fleet::sanitize_workers(&addrs).map_err(|e| format!("{e:#}"))?;
        let live = camcloud::net::fleet::set_workers(&addrs).map_err(|e| format!("{e:#}"))?;
        eprintln!("workers: {live}/{} reachable", addrs.len());
    }
    let spec = match args.opt("chaos") {
        Some(spec) => Some(spec.to_string()),
        None => std::env::var("CAMCLOUD_CHAOS").ok().filter(|s| !s.is_empty()),
    };
    if let Some(spec) = spec {
        let config =
            camcloud::net::chaos::ChaosConfig::parse(&spec).map_err(|e| format!("{e:#}"))?;
        camcloud::net::chaos::arm(config);
        eprintln!("chaos: fault injection armed ({spec})");
    }
    Ok(())
}

fn sim_config(args: &Args, default_duration: f64) -> Result<SimConfig, String> {
    let duration = args.f64_opt("duration")?.unwrap_or(default_duration);
    let engine: SimEngine = match args.opt("engine") {
        Some(s) => s.parse()?,
        None => SimEngine::default(),
    };
    Ok(SimConfig::for_duration(duration)
        .with_engine(engine)
        .with_parallelism(parallelism_config(args)?))
}

fn cmd_catalog() -> i32 {
    print!(
        "{}",
        reports::table1(&camcloud::cloud::Catalog::aws_table1()).render()
    );
    0
}

fn cmd_profile(args: &Args) -> i32 {
    let live = args.has("live");
    let frames = args.u32_opt("frames").unwrap_or(None).unwrap_or(8) as usize;
    let out = args.opt_or("out", "profiles.json");
    let coordinator = Coordinator::new();
    let store = if live {
        let runtime = match ModelRuntime::load(default_artifacts_dir()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        };
        println!("running live test runs ({frames} frames per program)...");
        match coordinator.profile_live(&runtime, frames) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        }
    } else {
        // Calibrated profiles for every program x frame size.
        let mut s = ProfileStore::new();
        for program in Program::ALL {
            for size in camcloud::types::FRAME_SIZES {
                s.insert(coordinator.calibration.profile(program, size));
            }
        }
        s
    };
    for p in store.iter() {
        println!(
            "{:<16} cpu {:>7.3} core-s/frame | gpu {:>8.2} core-s/frame | max fps {:>6.2} (cpu) {:>6.2} (gpu)",
            p.program.variant(p.frame_size),
            p.cpu_work_cpu_mode,
            p.gpu_work,
            p.max_fps_cpu,
            p.max_fps_gpu
        );
    }
    if let Err(e) = store.save(std::path::Path::new(out)) {
        eprintln!("error saving {out}: {e:#}");
        return 1;
    }
    println!("saved {} profiles to {out}", store.len());
    0
}

fn cmd_allocate(args: &Args) -> i32 {
    let scenario = match load_scenario(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let coordinator = match coordinator_with_profiles(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let strategies = match args.one_or_all("strategy", &Strategy::ALL) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let mgr = manager_for(scenario.catalog.clone(), &coordinator);
    for strategy in strategies {
        println!("--- {strategy} ---");
        match mgr.allocate(&scenario.streams, strategy) {
            Ok(plan) => print!("{}", plan.summary()),
            Err(e) => println!("FAIL: {e}"),
        }
    }
    0
}

fn cmd_run(args: &Args) -> i32 {
    let scenario = match load_scenario(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let coordinator = match coordinator_with_profiles(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let sim = match sim_config(args, 120.0) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    if let Err(e) = apply_workers_flag(args) {
        eprintln!("error: {e}");
        return 2;
    }
    let duration = sim.duration_s;
    match args.opt("strategy") {
        Some(s) => {
            let strategy: Strategy = match s.parse() {
                Ok(st) => st,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            match coordinator.run_scenario(&scenario, strategy, sim) {
                Ok(run) => {
                    print!("{}", run.plan.summary());
                    println!(
                        "simulated {duration}s: performance {:.1}%, {} frames ({} dropped), billed {}",
                        run.report.overall_performance() * 100.0,
                        run.report.frames_completed,
                        run.report.frames_dropped,
                        run.billed
                    );
                    0
                }
                Err(e) => {
                    println!("FAIL: {e}");
                    1
                }
            }
        }
        None => {
            let outcomes = coordinator.compare_strategies(&scenario, sim);
            print!(
                "{}",
                camcloud::coordinator::render_table6_block(&scenario, &outcomes).render()
            );
            0
        }
    }
}

fn cmd_trace(args: &Args) -> i32 {
    match run_trace_cmd(args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn run_trace_cmd(args: &Args) -> Result<i32, String> {
    let seed = args.u32_opt("seed")?.map(u64::from).unwrap_or(7);
    let cameras = args.u32_opt("cameras")?;
    let epochs = args.u32_opt("epochs")?;
    let spec = args
        .opt("trace")
        .ok_or("need --trace <emergency|diurnal|churn|spot|FILE>")?;
    // Builtin names defer to `WorkloadTrace::builtin` (one source of
    // defaults); explicit --cameras/--epochs override its generators.
    let trace = match (spec, cameras, epochs) {
        ("diurnal", Some(n), _) => WorkloadTrace::diurnal(n, seed),
        ("churn", n, e) if n.is_some() || e.is_some() => WorkloadTrace::camera_churn(
            n.unwrap_or(WorkloadTrace::CHURN_CAMERAS),
            e.map(|e| e as usize).unwrap_or(WorkloadTrace::CHURN_EPOCHS),
            seed,
        ),
        ("emergency" | "emergency-burst" | "diurnal" | "churn" | "spot" | "spot-market", _, _) => {
            WorkloadTrace::builtin(spec, seed).map_err(|e| e.to_string())?
        }
        (path, _, _) => WorkloadTrace::load(std::path::Path::new(path))
            .map_err(|e| format!("loading trace {path}: {e:#}"))?,
    };
    let trace = apply_pricing_flags(args, trace)?;
    let strategy: Strategy = args.opt_or("strategy", "st3").parse()?;
    let engine: SimEngine = match args.opt("engine") {
        Some(s) => s.parse()?,
        None => SimEngine::default(),
    };
    let horizon_hours = args.f64_opt("horizon")?;
    apply_workers_flag(args)?;
    let coordinator = coordinator_with_profiles(args)?;
    let config = AutoscaleConfig {
        strategy,
        sim: SimConfig::default()
            .with_engine(engine)
            .with_parallelism(parallelism_config(args)?),
        horizon_hours,
        ..AutoscaleConfig::default()
    };
    let runner = AutoscaleRunner::new(&coordinator)
        .with_config(config)
        .with_solve_cache_file(args.opt("solve-cache-file").map(std::path::PathBuf::from));
    let policies = args.one_or_all("policy", &ScalePolicy::ALL)?;
    println!(
        "trace {:?}: {} epochs over {:.1} h, strategy {strategy}, engine {engine}\n",
        trace.name,
        trace.epochs.len(),
        trace.total_duration_s() / 3600.0
    );
    let outcomes = runner.compare(&trace, &policies);
    for (policy, outcome) in &outcomes {
        match outcome {
            Ok(o) => println!("{}", reports::trace_epochs_table(o).render()),
            Err(e) => println!("--- {policy}: FAIL: {e:#} ---\n"),
        }
    }
    print!("{}", reports::trace_policy_table(&trace.name, &outcomes).render());
    // The --out file carries the trace config *and* the run's
    // per-policy, per-epoch results (solver, warm/cold mode, certified
    // gap), so it is written after the comparison ran.
    if let Some(out) = args.opt("out") {
        let mut doc = trace.to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("results".to_string(), trace_results_json(&outcomes));
        }
        std::fs::write(std::path::Path::new(out), doc.to_pretty())
            .map_err(|e| format!("saving trace {out}: {e:#}"))?;
        println!(
            "saved trace {:?} ({} epochs, {:.0}s) and {} policy result(s) to {out}",
            trace.name,
            trace.epochs.len(),
            trace.total_duration_s(),
            outcomes.len()
        );
    }
    if args.has("profile") {
        // Per-phase wall-clock table (solve/actuate/simulate/bill and
        // portfolio arms); prints a rebuild hint unless the binary was
        // built with `--features profiling`.
        println!("\n{}", camcloud::util::profiling::report());
    }
    let failed = outcomes.iter().any(|(_, o)| o.is_err());
    Ok(if failed { 1 } else { 0 })
}

/// `--tiers LIST` and `--regions N`: overlay a pricing model on the
/// trace's catalog.  Without either flag the trace runs with whatever
/// pricing it carries (flat for the classic builtins).
fn apply_pricing_flags(args: &Args, mut trace: WorkloadTrace) -> Result<WorkloadTrace, String> {
    let mut pricing = trace.catalog.pricing.clone();
    let mut touched = false;
    if let Some(spec) = args.opt("tiers") {
        pricing.tiers = parse_tiers(spec)?;
        touched = true;
    }
    if let Some(n) = args.u32_opt("regions")? {
        if n == 0 {
            return Err("--regions expects at least 1".into());
        }
        // Synthetic region grid: slightly pricier remote regions with
        // growing cross-region transfer charges.
        pricing.regions = (0..n)
            .map(|i| RegionSpec {
                name: format!("r{i}"),
                factor: 1.0 + 0.05 * f64::from(i),
                transfer_hourly: Dollars::from_f64(0.01 + 0.004 * f64::from(i)),
            })
            .collect();
        touched = true;
    }
    if touched {
        trace.catalog = trace.catalog.clone().with_pricing(pricing);
    }
    Ok(trace)
}

/// Parse `--tiers` syntax: `name[=factor]` entries, comma-separated,
/// e.g. `ondemand,spot=0.3` or `reserved,ondemand,spot`.
fn parse_tiers(spec: &str) -> Result<Vec<TierSpec>, String> {
    let mut tiers = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, factor) = match part.split_once('=') {
            Some((n, f)) => {
                let factor: f64 = f
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad tier factor in {part:?}"))?;
                (n.trim(), Some(factor))
            }
            None => (part, None),
        };
        let tier: PricingTier = name.parse()?;
        let factor = factor.unwrap_or_else(|| tier.default_factor());
        if !(factor > 0.0 && factor.is_finite()) {
            return Err(format!("tier factor must be positive in {part:?}"));
        }
        tiers.push(TierSpec { tier, factor });
    }
    if tiers.is_empty() {
        return Err("--tiers expects e.g. ondemand,spot=0.3".into());
    }
    Ok(tiers)
}

/// Per-policy, per-epoch results for the `--out` JSON: solver,
/// warm/cold provenance, and certified gap alongside the billing and
/// performance totals.
fn trace_results_json(
    outcomes: &[(ScalePolicy, camcloud::util::error::Result<AutoscaleOutcome>)],
) -> Json {
    Json::arr(outcomes.iter().map(|(policy, outcome)| match outcome {
        Ok(o) => Json::obj(vec![
            ("policy".to_string(), Json::Str(policy.to_string())),
            ("total_billed".to_string(), Json::Num(o.total_billed.as_f64())),
            ("peak_fleet".to_string(), Json::Num(o.peak_fleet as f64)),
            ("mean_performance".to_string(), Json::Num(o.mean_performance)),
            ("reallocations".to_string(), Json::Num(o.reallocations as f64)),
            (
                "epochs".to_string(),
                Json::arr(o.epochs.iter().map(|e| {
                    let mut fields = vec![
                        ("label".to_string(), Json::Str(e.label.clone())),
                        ("solver".to_string(), Json::Str(e.solver.to_string())),
                        ("mode".to_string(), Json::Str(e.mode.to_string())),
                        ("cached".to_string(), Json::Bool(e.cached)),
                        ("hourly_rate".to_string(), Json::Num(e.hourly_rate.as_f64())),
                        ("performance".to_string(), Json::Num(e.performance)),
                        ("unserved".to_string(), Json::Num(e.unserved as f64)),
                        ("revoked".to_string(), Json::Num(f64::from(e.revoked))),
                    ];
                    if let Some(gap) = e.gap {
                        fields.push(("gap".to_string(), Json::Num(gap)));
                    }
                    Json::obj(fields)
                })),
            ),
        ]),
        Err(e) => Json::obj(vec![
            ("policy".to_string(), Json::Str(policy.to_string())),
            ("error".to_string(), Json::Str(format!("{e:#}"))),
        ]),
    }))
}

fn cmd_report(args: &Args) -> i32 {
    let coordinator = match coordinator_with_profiles(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let duration = args.f64_opt("duration").unwrap_or(None).unwrap_or(60.0);
    let all = args.has("all") || args.switches.is_empty();
    let profiles = reports::vga_profiles(&coordinator);
    if all || args.has("table1") {
        println!(
            "{}",
            reports::table1(&camcloud::cloud::Catalog::aws_table1()).render()
        );
    }
    if all || args.has("table2") {
        println!("{}", reports::table2(&profiles).render());
    }
    if all || args.has("table3") {
        println!("{}", reports::table3(&profiles).render());
    }
    if all || args.has("table5") {
        println!("{}", reports::table5().render());
    }
    if all || args.has("fig5") {
        let rows = reports::fig5(
            &coordinator,
            &[0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 5.0, 6.0],
            duration,
        );
        println!("{}", reports::fig5_table(&rows).render());
    }
    if all || args.has("fig6") {
        let rows = reports::fig6(&coordinator, &[1, 2, 3, 4, 5, 6], duration);
        println!("{}", reports::fig6_table(&rows).render());
    }
    if all || args.has("table6") {
        for n in 1..=3 {
            println!("{}", reports::table6(&coordinator, n, duration).render());
        }
    }
    0
}

/// `camcloud worker --listen HOST:PORT [--max-requests N]`: the
/// remote end of `--workers`.  Serves exact-search subtree batches and
/// simulation shards sequentially until killed (or until
/// `--max-requests` connections, which CI uses to bound smoke jobs).
fn cmd_worker(args: &Args) -> i32 {
    let addr = match args.opt("listen") {
        Some(a) => a,
        None => {
            eprintln!("error: need --listen HOST:PORT (e.g. --listen 127.0.0.1:9001)");
            return 2;
        }
    };
    let max_requests = match args.u32_opt("max-requests") {
        Ok(n) => n.map(|n| n as usize),
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let listener = match std::net::TcpListener::bind(addr) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("error: cannot listen on {addr}: {e}");
            return 1;
        }
    };
    match listener.local_addr() {
        Ok(bound) => println!("camcloud worker listening on {bound}"),
        Err(_) => println!("camcloud worker listening on {addr}"),
    }
    match camcloud::net::worker::serve(listener, camcloud::net::worker::WorkerOptions { max_requests })
    {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_infer(args: &Args) -> i32 {
    let program: Program = match args.opt_or("program", "zf").parse() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let frames = args.u32_opt("frames").unwrap_or(None).unwrap_or(5);
    let runtime = match ModelRuntime::load(default_artifacts_dir()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let camera = Camera::new(7, VGA);
    let variant = program.variant(VGA);
    println!("compiling {variant}...");
    if let Err(e) = runtime.prepare(&variant) {
        eprintln!("error: {e:#}");
        return 1;
    }
    for i in 0..frames {
        let t = i as f64 * 0.5;
        let frame: Frame = camera.frame_at(t);
        match runtime.infer(&variant, &frame) {
            Ok((dets, stats)) => {
                println!(
                    "frame t={t:.1}s: {} detection(s) in {:.1} ms",
                    dets.len(),
                    stats.wall_seconds * 1e3
                );
                for d in dets.items.iter().take(4) {
                    println!(
                        "    {} ({:.0}%) bbox [{:.2} {:.2} {:.2} {:.2}]",
                        d.class_name,
                        d.score * 100.0,
                        d.bbox[0],
                        d.bbox[1],
                        d.bbox[2],
                        d.bbox[3]
                    );
                }
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        }
    }
    0
}

fn cmd_whatif(args: &Args) -> i32 {
    let scenario = match load_scenario(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let coordinator = match coordinator_with_profiles(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let strategies = match args.one_or_all("strategy", &Strategy::ALL) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let mgr = manager_for(scenario.catalog.clone(), &coordinator);
    let multipliers = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0];
    for strategy in strategies {
        println!("--- {strategy}: cost vs frame-rate multiplier ---");
        let curve = match camcloud::manager::whatif::sweep_rate_multiplier(
            &mgr,
            &scenario.streams,
            strategy,
            &multipliers,
        ) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        for p in &curve {
            match p.cost {
                Some(c) => {
                    println!("  x{:<5} {:>10}  ({} instance(s))", p.x, c.to_string(), p.instances)
                }
                None => println!("  x{:<5} {:>10}", p.x, "FAIL"),
            }
        }
        match camcloud::manager::whatif::feasibility_cliff(
            &mgr,
            &scenario.streams,
            strategy,
            0.25,
            16.0,
        ) {
            Ok(Some(cliff)) => println!("  feasibility cliff at x{cliff:.2}"),
            Ok(None) => {}
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }
    0
}
