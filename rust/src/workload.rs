//! First-class workload abstraction and synthetic fleet generation.
//!
//! A [`Workload`] is what the coordinator's pipeline consumes: the
//! stream specs, the catalog they price against, and (optionally) a
//! workload-specific profile store that overrides the coordinator's
//! source.  The paper's three scenarios, JSON configs, and synthetic
//! fleets all become `Workload`s and flow through one
//! profile → allocate → provision → simulate → bill path.
//!
//! [`FleetSpec`] opens the scenario space beyond the paper's Table 5:
//! it synthesizes parameterized fleets — N cameras with a seeded mix of
//! programs, frame rates, and frame sizes — so fleet-scale runs
//! (hundreds to thousands of streams) are one builder expression away.
//!
//! The [`trace`] submodule lifts workloads into the time dimension:
//! a [`trace::WorkloadTrace`] is a sequence of demand epochs (diurnal
//! curves, emergency bursts, camera churn) that the autoscaling runner
//! in `coordinator::autoscale` re-plans across.

pub mod trace;

use crate::cloud::Catalog;
use crate::config::Scenario;
use crate::profiler::store::ProfileStore;
use crate::streams::{Camera, StreamSpec};
use crate::types::{FrameSize, Program, VGA};
use crate::util::rng::Rng;

/// A named workload: streams + catalog + optional measured profiles.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub streams: Vec<StreamSpec>,
    pub catalog: Catalog,
    /// Workload-specific measured profiles; when set they take
    /// precedence over the coordinator's profile source.
    pub profiles: Option<ProfileStore>,
}

impl Workload {
    pub fn new(name: impl Into<String>, streams: Vec<StreamSpec>, catalog: Catalog) -> Workload {
        Workload {
            name: name.into(),
            streams,
            catalog,
            profiles: None,
        }
    }

    /// One of the paper's Table 5 scenarios as a workload.
    pub fn paper(number: u32) -> crate::util::error::Result<Workload> {
        Ok(crate::config::paper_scenario(number)?.into())
    }

    /// Attach measured profiles that override the coordinator's source.
    pub fn with_profiles(mut self, profiles: ProfileStore) -> Workload {
        self.profiles = Some(profiles);
        self
    }

    /// View as a [`Scenario`] (reporting paths still speak scenario).
    pub fn to_scenario(&self) -> Scenario {
        Scenario {
            name: self.name.clone(),
            streams: self.streams.clone(),
            catalog: self.catalog.clone(),
        }
    }
}

impl From<Scenario> for Workload {
    fn from(s: Scenario) -> Workload {
        Workload {
            name: s.name,
            streams: s.streams,
            catalog: s.catalog,
            profiles: None,
        }
    }
}

/// Parameterized synthetic fleet: N cameras with a seeded mix of
/// programs, rates, and frame sizes.
///
/// Defaults are chosen so the fleet is *allocatable* under every
/// strategy that admits GPUs: rates stay below the calibrated
/// `max_fps_gpu` of each program at VGA (3.61 / 9.15), mirroring the
/// mixed scenarios of the paper while scaling to thousands of streams.
#[derive(Clone, Debug)]
pub struct FleetSpec {
    /// Number of cameras (one stream each).
    pub cameras: u32,
    pub seed: u64,
    /// Fraction of streams running the heavier VGG-16 program.
    pub vgg_fraction: f64,
    /// Desired-rate range (fps) for VGG-16 streams.
    pub vgg_fps: (f64, f64),
    /// Desired-rate range (fps) for ZF streams.
    pub zf_fps: (f64, f64),
    /// Quantize drawn rates to this many discrete levels per program
    /// range (`None` = continuous).  Real deployments configure a
    /// handful of analysis rates across thousands of cameras, which is
    /// exactly the item multiplicity `packing::aggregate` exploits —
    /// quantized fleets collapse to `programs × levels × sizes`
    /// requirement classes regardless of camera count.
    pub rate_levels: Option<u32>,
    /// Frame sizes to draw from (uniformly).
    pub frame_sizes: Vec<FrameSize>,
    pub catalog: Catalog,
}

impl FleetSpec {
    pub fn new(cameras: u32) -> FleetSpec {
        FleetSpec {
            cameras,
            seed: 7,
            vgg_fraction: 0.5,
            vgg_fps: (0.05, 3.0),
            zf_fps: (0.1, 8.0),
            rate_levels: None,
            frame_sizes: vec![VGA],
            catalog: Catalog::paper_experiments(),
        }
    }

    pub fn seed(mut self, seed: u64) -> FleetSpec {
        self.seed = seed;
        self
    }

    pub fn vgg_fraction(mut self, fraction: f64) -> FleetSpec {
        self.vgg_fraction = fraction;
        self
    }

    pub fn vgg_fps(mut self, lo: f64, hi: f64) -> FleetSpec {
        self.vgg_fps = (lo, hi);
        self
    }

    pub fn zf_fps(mut self, lo: f64, hi: f64) -> FleetSpec {
        self.zf_fps = (lo, hi);
        self
    }

    /// Quantize rates to `levels` discrete values per program range —
    /// the high-multiplicity fleet shape (identical streams collapse
    /// into requirement classes the aggregated solver packs with
    /// counts).
    pub fn rate_levels(mut self, levels: u32) -> FleetSpec {
        self.rate_levels = Some(levels);
        self
    }

    pub fn frame_sizes(mut self, sizes: &[FrameSize]) -> FleetSpec {
        self.frame_sizes = sizes.to_vec();
        self
    }

    pub fn catalog(mut self, catalog: Catalog) -> FleetSpec {
        self.catalog = catalog;
        self
    }

    /// Synthesize the fleet (deterministic per seed).
    pub fn build(&self) -> Workload {
        assert!(!self.frame_sizes.is_empty(), "fleet needs frame sizes");
        let mut rng = Rng::new(self.seed);
        let streams = (0..self.cameras)
            .map(|i| {
                let program = if rng.bool(self.vgg_fraction) {
                    Program::Vgg16
                } else {
                    Program::Zf
                };
                let (lo, hi) = match program {
                    Program::Vgg16 => self.vgg_fps,
                    Program::Zf => self.zf_fps,
                };
                let fps = rng.range_f64(lo, hi);
                // Snap to the level midpoint: the same (range, level)
                // always produces bit-identical rates, so equal-level
                // streams share one requirement class.
                let fps = match self.rate_levels {
                    Some(k) if k > 0 && hi > lo => {
                        let step = (hi - lo) / k as f64;
                        let level = ((fps - lo) / step).floor().min((k - 1) as f64);
                        lo + (level + 0.5) * step
                    }
                    _ => fps,
                };
                let size = *rng.choose(&self.frame_sizes);
                StreamSpec::new(Camera::new(i, size), program, fps)
            })
            .collect();
        Workload::new(
            format!("fleet-{}-{}", self.seed, self.cameras),
            streams,
            self.catalog.clone(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Coordinator;
    use crate::manager::Strategy;

    #[test]
    fn fleet_is_deterministic_per_seed() {
        let a = FleetSpec::new(50).seed(11).build();
        let b = FleetSpec::new(50).seed(11).build();
        assert_eq!(a.streams.len(), 50);
        assert_eq!(a.name, "fleet-11-50");
        for (x, y) in a.streams.iter().zip(&b.streams) {
            assert_eq!(x.desired_fps, y.desired_fps);
            assert_eq!(x.program, y.program);
            assert_eq!(x.camera.id, y.camera.id);
        }
        let c = FleetSpec::new(50).seed(12).build();
        assert!(a
            .streams
            .iter()
            .zip(&c.streams)
            .any(|(x, y)| x.desired_fps != y.desired_fps));
    }

    #[test]
    fn fleet_mix_parameters_apply() {
        let all_vgg = FleetSpec::new(30).vgg_fraction(1.0).build();
        assert!(all_vgg.streams.iter().all(|s| s.program == Program::Vgg16));
        let all_zf = FleetSpec::new(30).vgg_fraction(0.0).zf_fps(2.0, 4.0).build();
        assert!(all_zf
            .streams
            .iter()
            .all(|s| s.program == Program::Zf && (2.0..4.0).contains(&s.desired_fps)));
        let sizes = [FrameSize::new(192, 256)];
        let small = FleetSpec::new(5).frame_sizes(&sizes).build();
        assert!(small.streams.iter().all(|s| s.camera.frame_size == sizes[0]));
    }

    #[test]
    fn default_fleet_is_allocatable_under_st3() {
        // The generator's default ranges stay below the GPU latency
        // caps, so ST3 must always find a plan.
        for seed in [1u64, 2, 3] {
            let fleet = FleetSpec::new(60).seed(seed).build();
            let c = Coordinator::new();
            let profiled = c.profile_workload(fleet);
            let plan = profiled.allocate(Strategy::St3).unwrap();
            assert!(!plan.instances.is_empty());
            let placed: usize = plan.instances.iter().map(|i| i.streams.len()).sum();
            assert_eq!(placed, 60);
        }
    }

    #[test]
    fn rate_levels_collapse_the_fleet_into_classes() {
        let fleet = FleetSpec::new(500).seed(9).rate_levels(4).build();
        let mut rates: Vec<(Program, u64)> = fleet
            .streams
            .iter()
            .map(|s| (s.program, s.desired_fps.to_bits()))
            .collect();
        rates.sort_unstable();
        rates.dedup();
        // At most programs × levels distinct (program, rate) pairs.
        assert!(rates.len() <= 8, "got {} distinct rates", rates.len());
        // Levels stay inside the configured ranges.
        for s in &fleet.streams {
            let (lo, hi) = match s.program {
                Program::Vgg16 => (0.05, 3.0),
                Program::Zf => (0.1, 8.0),
            };
            assert!(s.desired_fps > lo && s.desired_fps < hi);
        }
        // Continuous fleets stay (essentially) all-distinct.
        let continuous = FleetSpec::new(500).seed(9).build();
        let mut cr: Vec<u64> = continuous
            .streams
            .iter()
            .map(|s| s.desired_fps.to_bits())
            .collect();
        cr.sort_unstable();
        cr.dedup();
        assert!(cr.len() > 400);
    }

    #[test]
    fn workload_round_trips_scenario() {
        let scenario = crate::config::paper_scenario(1).unwrap();
        let w: Workload = scenario.clone().into();
        assert_eq!(w.name, "scenario-1");
        assert_eq!(w.streams.len(), scenario.streams.len());
        let back = w.to_scenario();
        assert_eq!(back.name, scenario.name);
        assert_eq!(back.catalog.types.len(), scenario.catalog.types.len());
        assert!(Workload::paper(2).unwrap().profiles.is_none());
        assert!(Workload::paper(9).is_err());
    }
}
