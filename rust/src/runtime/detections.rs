//! Decoding the detector head output into object detections.
//!
//! The model emits `[num_anchors, head_out]` where each row is
//! `[class logits (C) ‖ box refinement (4)]`.  Decoding applies a
//! softmax over the logits, drops background/below-threshold anchors,
//! and maps box refinements onto the anchor grid (3x4 cells x 3
//! aspect ratios, in normalized image coordinates).


/// One detected object.
#[derive(Clone, Debug, PartialEq)]
pub struct Detection {
    pub anchor: usize,
    pub class_index: usize,
    pub class_name: String,
    /// Softmax probability of the winning class.
    pub score: f32,
    /// Normalized `[x0, y0, x1, y1]` in `[0, 1]`.
    pub bbox: [f32; 4],
}

/// All detections from one frame.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Detections {
    pub items: Vec<Detection>,
}

/// Anchor grid layout: must match `python/compile/model.py`.
const GRID_H: usize = 3;
const GRID_W: usize = 4;
const ASPECTS: usize = 3;
/// Detection confidence threshold.
const SCORE_THRESHOLD: f32 = 0.5;

fn softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|l| (l - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

/// Anchor base box in normalized coordinates.
fn anchor_box(anchor: usize) -> [f32; 4] {
    let cell = anchor / ASPECTS;
    let aspect = anchor % ASPECTS;
    let gy = cell / GRID_W;
    let gx = cell % GRID_W;
    let cx = (gx as f32 + 0.5) / GRID_W as f32;
    let cy = (gy as f32 + 0.5) / GRID_H as f32;
    // Aspect ratios 0.5, 1.0, 2.0 over a base extent of one cell.
    let (bw, bh) = match aspect {
        0 => (0.5 / GRID_W as f32, 1.0 / GRID_H as f32),
        1 => (1.0 / GRID_W as f32, 1.0 / GRID_H as f32),
        _ => (1.0 / GRID_W as f32, 0.5 / GRID_H as f32),
    };
    [cx - bw / 2.0, cy - bh / 2.0, cx + bw / 2.0, cy + bh / 2.0]
}

impl Detections {
    /// Decode the raw head output.
    ///
    /// `head_out = classes.len() + 4`; anchors with background argmax or
    /// score below threshold are dropped.
    pub fn from_head_output(
        raw: &[f32],
        num_anchors: usize,
        head_out: usize,
        classes: &[String],
    ) -> Detections {
        assert_eq!(raw.len(), num_anchors * head_out, "head output shape");
        let n_classes = classes.len();
        assert_eq!(head_out, n_classes + 4, "head_out = classes + 4");
        let mut items = Vec::new();
        for a in 0..num_anchors {
            let row = &raw[a * head_out..(a + 1) * head_out];
            let probs = softmax(&row[..n_classes]);
            let (best, &score) = probs
                .iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .unwrap();
            if best == 0 || score < SCORE_THRESHOLD {
                continue; // background or low confidence
            }
            let base = anchor_box(a);
            let refine = &row[n_classes..];
            // Small additive refinement, clamped to the image.
            let bbox = [
                (base[0] + 0.1 * refine[0].tanh()).clamp(0.0, 1.0),
                (base[1] + 0.1 * refine[1].tanh()).clamp(0.0, 1.0),
                (base[2] + 0.1 * refine[2].tanh()).clamp(0.0, 1.0),
                (base[3] + 0.1 * refine[3].tanh()).clamp(0.0, 1.0),
            ];
            items.push(Detection {
                anchor: a,
                class_index: best,
                class_name: classes[best].clone(),
                score,
                bbox,
            });
        }
        Detections { items }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Count of detections of a given class name.
    pub fn count_class(&self, name: &str) -> usize {
        self.items.iter().filter(|d| d.class_name == name).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<String> {
        ["background", "person", "car", "bus", "monitor"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn decodes_confident_foreground() {
        let cls = classes();
        let mut raw = vec![0.0f32; 36 * 9];
        // Anchor 5: strong "car" logit.
        raw[5 * 9 + 2] = 10.0;
        let d = Detections::from_head_output(&raw, 36, 9, &cls);
        assert_eq!(d.len(), 1);
        assert_eq!(d.items[0].class_name, "car");
        assert_eq!(d.items[0].anchor, 5);
        assert!(d.items[0].score > 0.9);
        assert_eq!(d.count_class("car"), 1);
        assert_eq!(d.count_class("person"), 0);
    }

    #[test]
    fn background_and_uncertain_dropped() {
        let cls = classes();
        let mut raw = vec![0.0f32; 36 * 9];
        raw[9] = 10.0; // anchor 1: background
        let d = Detections::from_head_output(&raw, 36, 9, &cls);
        // Uniform logits elsewhere -> score 0.2 < threshold; bg dropped.
        assert!(d.is_empty());
    }

    #[test]
    fn bboxes_inside_image() {
        let cls = classes();
        let mut raw = vec![0.0f32; 36 * 9];
        for a in 0..36 {
            raw[a * 9 + 1] = 8.0; // everyone is a person
            for r in 0..4 {
                raw[a * 9 + 5 + r] = 100.0; // extreme refinements
            }
        }
        let d = Detections::from_head_output(&raw, 36, 9, &cls);
        assert_eq!(d.len(), 36);
        for det in &d.items {
            for v in det.bbox {
                assert!((0.0..=1.0).contains(&v));
            }
            assert!(det.bbox[0] <= det.bbox[2]);
            assert!(det.bbox[1] <= det.bbox[3]);
        }
    }

    #[test]
    fn anchor_boxes_tile_the_grid() {
        // First cell's middle-aspect anchor is centred at (1/8, 1/6).
        let b = anchor_box(1);
        assert!((((b[0] + b[2]) / 2.0) - 0.125).abs() < 1e-6);
        assert!((((b[1] + b[3]) / 2.0) - 1.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "head output shape")]
    fn rejects_bad_shape() {
        Detections::from_head_output(&[0.0; 10], 36, 9, &classes());
    }
}
