//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): parse
//! `artifacts/*.hlo.txt` with `xla::HloModuleProto::from_text_file`,
//! compile once per model variant, and serve inference from the Layer-3
//! hot path.  Python never runs here — the artifacts are self-contained
//! (weights baked as constants).
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits protos with
//! 64-bit instruction ids that this XLA build rejects; the text parser
//! reassigns ids (see `python/compile/aot.py`).
//!
//! The `xla` crate is the crate's single external dependency and must
//! be vendored, so the real runtime is gated behind the **`pjrt`**
//! feature.  Without it a stub [`ModelRuntime`] with the same API keeps
//! the whole pipeline compiling; `load` reports the missing feature and
//! callers (CLI `--live`, live examples, live benches) surface that
//! error or skip.  Everything downstream of profiles — allocation,
//! simulation, billing — is pure Rust and unaffected.

pub mod detections;
pub mod manifest;

pub use detections::{Detection, Detections};
pub use manifest::{KernelEntry, Manifest, ModelEntry};

use std::path::PathBuf;

/// Timing of one inference call.
#[derive(Clone, Copy, Debug)]
pub struct InferStats {
    /// Wall-clock seconds of the execute call (host-to-host).
    pub wall_seconds: f64,
}

#[cfg(feature = "pjrt")]
mod pjrt_runtime {
    use super::{InferStats, Manifest};
    use crate::streams::Frame;
    use crate::types::FrameSize;
    use crate::util::error::{anyhow, ensure, Context, Result};
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::time::Instant;

    /// Compiled-model runtime over the PJRT CPU client.
    ///
    /// Executables are compiled lazily per variant and cached.  The type
    /// is deliberately `!Send` (PJRT handles are thread-affine in the C
    /// API wrapper); the coordinator owns it on a dedicated thread.
    pub struct ModelRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        artifacts_dir: PathBuf,
        executables: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl ModelRuntime {
        /// Open the artifacts directory (reads `meta.json`, creates the
        /// PJRT CPU client; compiles nothing yet).
        pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<ModelRuntime> {
            let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
            let manifest = Manifest::load(&artifacts_dir.join("meta.json"))
                .context("loading artifacts manifest (run `make artifacts`?)")?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
            Ok(ModelRuntime {
                client,
                manifest,
                artifacts_dir,
                executables: RefCell::new(HashMap::new()),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Compile (and cache) the executable for `variant`.
        pub fn prepare(&self, variant: &str) -> Result<()> {
            if self.executables.borrow().contains_key(variant) {
                return Ok(());
            }
            let entry = self
                .manifest
                .model(variant)
                .map(|m| m.hlo.clone())
                .or_else(|| self.manifest.kernel(variant).map(|k| k.hlo.clone()))
                .ok_or_else(|| anyhow!("unknown artifact variant {variant:?}"))?;
            let path = self.artifacts_dir.join(entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {variant}: {e:?}"))?;
            self.executables.borrow_mut().insert(variant.to_string(), exe);
            Ok(())
        }

        /// Run one frame through a model variant; returns the raw
        /// `[36, 9]` head output plus timing.
        pub fn infer_raw(&self, variant: &str, frame: &Frame) -> Result<(Vec<f32>, InferStats)> {
            let entry = self
                .manifest
                .model(variant)
                .ok_or_else(|| anyhow!("unknown model variant {variant:?}"))?;
            let expect = FrameSize::new(entry.frame_h, entry.frame_w);
            if frame.size != expect {
                return Err(anyhow!(
                    "variant {variant} wants {expect} frames, got {}",
                    frame.size
                ));
            }
            let out_len: usize = entry.output_shape.iter().product::<u32>() as usize;
            let shape = [1usize, entry.frame_h as usize, entry.frame_w as usize, 3];
            self.prepare(variant)?;

            let start = Instant::now();
            // Single host->device copy (§Perf, L3 iteration 3): building a
            // Literal and reshaping it copies the 3.7 MB frame twice; a
            // device buffer straight from the host slice copies once.
            let input = self
                .client
                .buffer_from_host_buffer(&frame.data, &shape, None)
                .map_err(|e| anyhow!("uploading frame: {e:?}"))?;
            let exes = self.executables.borrow();
            let exe = exes.get(variant).expect("prepared above");
            let result = exe
                .execute_b::<xla::PjRtBuffer>(&[input])
                .map_err(|e| anyhow!("executing {variant}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result: {e:?}"))?;
            let wall = start.elapsed().as_secs_f64();

            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow!("unwrapping tuple: {e:?}"))?;
            let values = out
                .to_vec::<f32>()
                .map_err(|e| anyhow!("reading output: {e:?}"))?;
            if values.len() != out_len {
                return Err(anyhow!(
                    "output length {} != expected {out_len}",
                    values.len()
                ));
            }
            Ok((values, InferStats { wall_seconds: wall }))
        }

        /// Run one frame and decode detections.
        pub fn infer(
            &self,
            variant: &str,
            frame: &Frame,
        ) -> Result<(super::Detections, InferStats)> {
            let (raw, stats) = self.infer_raw(variant, frame)?;
            let dets = super::Detections::from_head_output(
                &raw,
                self.manifest.num_anchors as usize,
                self.manifest.head_out as usize,
                &self.manifest.classes,
            );
            Ok((dets, stats))
        }

        /// Execute the bare Layer-1 kernel artifact (microbenchmarks).
        pub fn run_kernel(
            &self,
            name: &str,
            x: &[f32],
            w: &[f32],
            b: &[f32],
        ) -> Result<(Vec<f32>, InferStats)> {
            let entry = self
                .manifest
                .kernel(name)
                .ok_or_else(|| anyhow!("unknown kernel {name:?}"))?
                .clone();
            self.prepare(name)?;
            let (m, k, n) = (entry.m as usize, entry.k as usize, entry.n as usize);
            ensure!(x.len() == m * k, "x length mismatch");
            ensure!(w.len() == k * n, "w length mismatch");
            ensure!(b.len() == n, "b length mismatch");

            let start = Instant::now();
            let xs = self
                .client
                .buffer_from_host_buffer(x, &[m, k], None)
                .map_err(|e| anyhow!("{e:?}"))?;
            let ws = self
                .client
                .buffer_from_host_buffer(w, &[k, n], None)
                .map_err(|e| anyhow!("{e:?}"))?;
            let bs = self
                .client
                .buffer_from_host_buffer(b, &[n], None)
                .map_err(|e| anyhow!("{e:?}"))?;
            let exes = self.executables.borrow();
            let exe = exes.get(name).expect("prepared above");
            let result = exe
                .execute_b::<xla::PjRtBuffer>(&[xs, ws, bs])
                .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{e:?}"))?;
            let wall = start.elapsed().as_secs_f64();
            let out = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
            let values = out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            Ok((values, InferStats { wall_seconds: wall }))
        }

        /// Artifacts directory this runtime reads from.
        pub fn artifacts_dir(&self) -> &Path {
            &self.artifacts_dir
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_runtime::ModelRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub_runtime {
    use super::{InferStats, Manifest};
    use crate::streams::Frame;
    use crate::util::error::{anyhow, Result};
    use std::path::Path;

    /// Uninhabited stand-in for the PJRT runtime when the crate is
    /// built without the `pjrt` feature.  [`ModelRuntime::load`] always
    /// errors, so the accessor methods can never actually be reached —
    /// but they keep every caller compiling against one API.
    pub enum ModelRuntime {}

    fn unavailable() -> crate::util::error::Error {
        anyhow!(
            "camcloud was built without the `pjrt` feature; to run live \
             inference, vendor the `xla` crate, add it as an optional \
             dependency wired to the `pjrt` feature (see rust/Cargo.toml), \
             and rebuild with `--features pjrt`"
        )
    }

    impl ModelRuntime {
        pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<ModelRuntime> {
            let _ = artifacts_dir.as_ref();
            Err(unavailable())
        }

        pub fn manifest(&self) -> &Manifest {
            match *self {}
        }

        pub fn prepare(&self, _variant: &str) -> Result<()> {
            match *self {}
        }

        pub fn infer_raw(&self, _variant: &str, _frame: &Frame) -> Result<(Vec<f32>, InferStats)> {
            match *self {}
        }

        pub fn infer(
            &self,
            _variant: &str,
            _frame: &Frame,
        ) -> Result<(super::Detections, InferStats)> {
            match *self {}
        }

        pub fn run_kernel(
            &self,
            _name: &str,
            _x: &[f32],
            _w: &[f32],
            _b: &[f32],
        ) -> Result<(Vec<f32>, InferStats)> {
            match *self {}
        }

        pub fn artifacts_dir(&self) -> &Path {
            match *self {}
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_runtime::ModelRuntime;

/// Locate the repo's artifacts directory from the `CAMCLOUD_ARTIFACTS`
/// environment variable or by walking up from the current directory
/// (works from target/ subdirs during `cargo test` / `cargo bench`).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CAMCLOUD_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let candidate = dir.join("artifacts");
        if candidate.join("meta.json").exists() {
            return candidate;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::ModelRuntime;

    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = ModelRuntime::load("artifacts").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
