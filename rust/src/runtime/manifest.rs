//! Artifact manifest (`artifacts/meta.json`), written by the AOT step.

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::path::Path;

/// One AOT-compiled model variant.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// Program name (`vgg16` / `zf`).
    pub name: String,
    /// Variant name (`vgg16_480x640`).
    pub variant: String,
    /// HLO text filename relative to the artifacts dir.
    pub hlo: String,
    pub frame_h: u32,
    pub frame_w: u32,
    pub input_shape: Vec<u32>,
    pub output_shape: Vec<u32>,
    /// Analytic FLOPs per frame (from `model.flops_per_frame`).
    pub flops_per_frame: u64,
    pub param_count: u64,
}

/// One bare-kernel artifact (microbenchmarks).
#[derive(Clone, Debug)]
pub struct KernelEntry {
    pub name: String,
    pub hlo: String,
    pub m: u32,
    pub k: u32,
    pub n: u32,
    pub flops: u64,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model_h: u32,
    pub model_w: u32,
    pub classes: Vec<String>,
    pub num_anchors: u32,
    pub head_out: u32,
    pub models: Vec<ModelEntry>,
    pub kernels: Vec<KernelEntry>,
}

fn u32_arr(v: &Json, key: &str) -> Result<Vec<u32>> {
    v.arr_field(key)?
        .iter()
        .map(|x| {
            x.as_u64()
                .map(|n| n as u32)
                .ok_or_else(|| crate::anyhow!("{key}: non-integer element"))
        })
        .collect()
}

impl ModelEntry {
    fn from_json(v: &Json) -> Result<ModelEntry> {
        Ok(ModelEntry {
            name: v.str_field("name")?.to_string(),
            variant: v.str_field("variant")?.to_string(),
            hlo: v.str_field("hlo")?.to_string(),
            frame_h: v.u64_field("frame_h")? as u32,
            frame_w: v.u64_field("frame_w")? as u32,
            input_shape: u32_arr(v, "input_shape")?,
            output_shape: u32_arr(v, "output_shape")?,
            flops_per_frame: v.u64_field("flops_per_frame")?,
            param_count: v.u64_field("param_count")?,
        })
    }
}

impl KernelEntry {
    fn from_json(v: &Json) -> Result<KernelEntry> {
        Ok(KernelEntry {
            name: v.str_field("name")?.to_string(),
            hlo: v.str_field("hlo")?.to_string(),
            m: v.u64_field("m")? as u32,
            k: v.u64_field("k")? as u32,
            n: v.u64_field("n")? as u32,
            flops: v.u64_field("flops")?,
        })
    }
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("parsing manifest json")?;
        Ok(Manifest {
            model_h: v.u64_field("model_h")? as u32,
            model_w: v.u64_field("model_w")? as u32,
            classes: v
                .arr_field("classes")?
                .iter()
                .map(|c| {
                    c.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| crate::anyhow!("classes: non-string element"))
                })
                .collect::<Result<Vec<_>>>()?,
            num_anchors: v.u64_field("num_anchors")? as u32,
            head_out: v.u64_field("head_out")? as u32,
            models: v
                .arr_field("models")?
                .iter()
                .map(ModelEntry::from_json)
                .collect::<Result<Vec<_>>>()?,
            kernels: v
                .arr_field("kernels")?
                .iter()
                .map(KernelEntry::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let json = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&json).with_context(|| format!("parsing {path:?}"))
    }

    pub fn model(&self, variant: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.variant == variant)
    }

    pub fn kernel(&self, name: &str) -> Option<&KernelEntry> {
        self.kernels.iter().find(|k| k.name == name)
    }

    /// All variants of one program.
    pub fn variants_of(&self, program: &str) -> Vec<&ModelEntry> {
        self.models.iter().filter(|m| m.name == program).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model_h": 96, "model_w": 128,
      "classes": ["background", "person"],
      "num_anchors": 36, "head_out": 9,
      "models": [{
        "name": "vgg16", "variant": "vgg16_480x640",
        "hlo": "vgg16_480x640.hlo.txt",
        "frame_h": 480, "frame_w": 640,
        "input_shape": [1, 480, 640, 3], "output_shape": [36, 9],
        "flops_per_frame": 124478464, "param_count": 502124
      }],
      "kernels": [{
        "name": "kernel_matmul_512x256x128",
        "hlo": "kernel_matmul_512x256x128.hlo.txt",
        "m": 512, "k": 256, "n": 128, "flops": 33554432
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model_h, 96);
        assert_eq!(m.models.len(), 1);
        assert_eq!(m.models[0].input_shape, vec![1, 480, 640, 3]);
        assert_eq!(m.kernels[0].flops, 33_554_432);
    }

    #[test]
    fn lookup_helpers() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.model("vgg16_480x640").is_some());
        assert!(m.model("nope").is_none());
        assert!(m.kernel("kernel_matmul_512x256x128").is_some());
        assert_eq!(m.variants_of("vgg16").len(), 1);
        assert_eq!(m.variants_of("zf").len(), 0);
    }

    #[test]
    fn missing_field_errors() {
        assert!(Manifest::parse("{}").is_err());
        let bad = SAMPLE.replace("\"frame_h\": 480,", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = crate::runtime::default_artifacts_dir();
        let path = dir.join("meta.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert_eq!(m.models.len(), 6); // 2 programs x 3 frame sizes
            assert_eq!(m.num_anchors, 36);
            assert_eq!(m.head_out, 9);
            assert_eq!(m.classes.len(), 5);
        }
    }
}
