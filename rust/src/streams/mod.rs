//! Simulated network cameras (DESIGN.md substitution: public MJPEG
//! streams → synthetic frame generators).
//!
//! A [`Camera`] produces [`Frame`]s at its native rate; a
//! [`StreamSpec`] pairs a camera with the analysis program and *desired*
//! frame rate the user wants (the paper's workload unit).  Frame content
//! is synthetic — moving rectangles over a deterministic background —
//! because allocation decisions depend only on rates and sizes, but the
//! pixels are real enough that detectors produce stable outputs.

pub mod camera;
pub mod frame;

pub use camera::{Camera, CameraId, StreamSpec};
pub use frame::Frame;
