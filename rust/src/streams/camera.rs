//! Camera registry and workload stream specifications.

use super::frame::Frame;
use crate::types::{FrameSize, Program};

/// Unique camera identifier.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct CameraId(pub u32);

impl std::fmt::Display for CameraId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cam-{:03}", self.0)
    }
}

/// A simulated network camera.
#[derive(Clone, Debug)]
pub struct Camera {
    pub id: CameraId,
    pub frame_size: FrameSize,
    /// Native stream rate of the camera (frames per second).  The
    /// *analysis* rate is chosen per stream and is usually lower.
    pub native_fps: f64,
    /// Content seed (scene identity).
    pub seed: u64,
    /// How busy the scene is (number of moving objects).
    pub activity: usize,
}

impl Camera {
    pub fn new(id: u32, frame_size: FrameSize) -> Camera {
        Camera {
            id: CameraId(id),
            frame_size,
            native_fps: 30.0,
            seed: id as u64 * 7919 + 13,
            activity: 3 + (id as usize % 5),
        }
    }

    /// The frame this camera shows at simulation time `t` seconds.
    pub fn frame_at(&self, t: f64) -> Frame {
        Frame::synthetic(self.frame_size, self.seed, t, self.activity)
    }
}

/// One unit of analysis workload: a camera stream, the program to run
/// on it, and the desired analysis frame rate (paper Table 5 rows).
#[derive(Clone, Debug)]
pub struct StreamSpec {
    pub camera: Camera,
    pub program: Program,
    pub desired_fps: f64,
}

impl StreamSpec {
    pub fn new(camera: Camera, program: Program, desired_fps: f64) -> StreamSpec {
        StreamSpec { camera, program, desired_fps }
    }

    /// Stream identifier used in packing items and reports.
    pub fn id(&self) -> String {
        format!("{}/{}", self.camera.id, self.program.name())
    }

    /// Expand a Table-5-style row into `count` streams over distinct
    /// cameras (ids starting at `first_camera_id`).
    pub fn replicate(
        first_camera_id: u32,
        count: u32,
        frame_size: FrameSize,
        program: Program,
        desired_fps: f64,
    ) -> Vec<StreamSpec> {
        (0..count)
            .map(|i| {
                StreamSpec::new(
                    Camera::new(first_camera_id + i, frame_size),
                    program,
                    desired_fps,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::VGA;

    #[test]
    fn camera_frames_animate_deterministically() {
        let cam = Camera::new(1, VGA);
        let f0 = cam.frame_at(0.0);
        let f1 = cam.frame_at(0.5);
        assert_ne!(f0, f1);
        assert_eq!(f0, cam.frame_at(0.0));
    }

    #[test]
    fn distinct_cameras_have_distinct_scenes() {
        let a = Camera::new(1, VGA).frame_at(0.0);
        let b = Camera::new(2, VGA).frame_at(0.0);
        assert_ne!(a, b);
    }

    #[test]
    fn replicate_builds_table5_rows() {
        // Table 5, scenario 3: ZF at 8 FPS on 10 cameras.
        let streams = StreamSpec::replicate(100, 10, VGA, Program::Zf, 8.0);
        assert_eq!(streams.len(), 10);
        assert!(streams.iter().all(|s| s.desired_fps == 8.0));
        assert_eq!(streams[0].camera.id, CameraId(100));
        assert_eq!(streams[9].camera.id, CameraId(109));
        assert_eq!(streams[0].id(), "cam-100/zf");
        // Distinct camera seeds.
        assert_ne!(streams[0].camera.seed, streams[1].camera.seed);
    }
}
