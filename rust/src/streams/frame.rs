//! Frame buffers and synthetic content generation.

use crate::types::FrameSize;

/// One RGB frame in HWC layout, f32 pixels in `[0, 1]` — exactly the
/// input layout of the AOT model artifacts (`[1, H, W, 3]` with the
/// leading batch dim implicit).
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub size: FrameSize,
    pub data: Vec<f32>,
}

impl Frame {
    /// Number of f32 elements for a frame of `size`.
    pub fn elements(size: FrameSize) -> usize {
        (size.pixels() * 3) as usize
    }

    /// Black frame.
    pub fn zeros(size: FrameSize) -> Frame {
        Frame {
            size,
            data: vec![0.0; Self::elements(size)],
        }
    }

    /// The deterministic golden pattern shared with the python AOT step:
    /// `frame[y, x, c] = ((y*31 + x*17 + c*7) % 256) / 255`.
    ///
    /// MUST stay bit-identical to `python/compile/aot.py::golden_frame`;
    /// the cross-language integration test depends on it.
    pub fn golden(size: FrameSize) -> Frame {
        let (h, w) = (size.h as usize, size.w as usize);
        let mut data = Vec::with_capacity(Self::elements(size));
        for y in 0..h {
            for x in 0..w {
                for c in 0..3usize {
                    let v = (y * 31 + x * 17 + c * 7) % 256;
                    data.push(v as f32 / 255.0);
                }
            }
        }
        Frame { size, data }
    }

    /// Synthetic camera content at time `t` (seconds): a textured
    /// background with `n_objects` bright rectangles orbiting at
    /// object-specific speeds.  Deterministic in `(seed, t)`.
    pub fn synthetic(size: FrameSize, seed: u64, t: f64, n_objects: usize) -> Frame {
        let (h, w) = (size.h as usize, size.w as usize);
        let mut frame = Frame::golden(size);
        // Dim the background texture.
        for v in frame.data.iter_mut() {
            *v *= 0.3;
        }
        for obj in 0..n_objects {
            // Simple LCG-style per-object parameters.
            let mix = seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(obj as u64 * 1442695040888963407);
            let ow = 8 + (mix % 24) as usize; // object width
            let oh = 8 + ((mix >> 8) % 24) as usize;
            let speed_x = 10.0 + ((mix >> 16) % 40) as f64;
            let speed_y = 5.0 + ((mix >> 24) % 20) as f64;
            let phase = ((mix >> 32) % 1000) as f64 / 1000.0;
            let cx = ((phase * w as f64 + speed_x * t) % w as f64) as usize;
            let cy = ((phase * h as f64 + speed_y * t) % h as f64) as usize;
            let color = [
                0.5 + 0.5 * ((mix >> 40) % 2) as f32,
                0.5 + 0.5 * ((mix >> 41) % 2) as f32,
                0.5 + 0.5 * ((mix >> 42) % 2) as f32,
            ];
            for dy in 0..oh {
                for dx in 0..ow {
                    let y = (cy + dy) % h;
                    let x = (cx + dx) % w;
                    let base = (y * w + x) * 3;
                    frame.data[base..base + 3].copy_from_slice(&color);
                }
            }
        }
        frame
    }

    /// Mean pixel value (test helper / content sanity checks).
    pub fn mean(&self) -> f32 {
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{FrameSize, VGA};

    const SMALL: FrameSize = FrameSize::new(192, 256);

    #[test]
    fn golden_matches_python_formula() {
        let f = Frame::golden(SMALL);
        assert_eq!(f.data.len(), 192 * 256 * 3);
        assert_eq!(f.data[0], 0.0);
        // (y=0, x=0, c=1) -> 7/255
        assert!((f.data[1] - 7.0 / 255.0).abs() < 1e-7);
        // (y=0, x=1, c=0) -> 17/255
        assert!((f.data[3] - 17.0 / 255.0).abs() < 1e-7);
        // (y=1, x=0, c=0) -> 31/255 at offset w*3
        assert!((f.data[256 * 3] - 31.0 / 255.0).abs() < 1e-7);
        // (y=2, x=3, c=1) -> ((62+51+7)%256)/255
        let idx = (2 * 256 + 3) * 3 + 1;
        assert!((f.data[idx] - 120.0 / 255.0).abs() < 1e-7);
    }

    #[test]
    fn golden_is_deterministic() {
        assert_eq!(Frame::golden(SMALL), Frame::golden(SMALL));
    }

    #[test]
    fn synthetic_moves_with_time() {
        let a = Frame::synthetic(VGA, 1, 0.0, 3);
        let b = Frame::synthetic(VGA, 1, 1.0, 3);
        assert_ne!(a, b);
        // Same (seed, t) reproduces exactly.
        assert_eq!(a, Frame::synthetic(VGA, 1, 0.0, 3));
        // Different seeds give different content.
        assert_ne!(a, Frame::synthetic(VGA, 2, 0.0, 3));
    }

    #[test]
    fn synthetic_objects_brighten_frame() {
        let empty = Frame::synthetic(SMALL, 7, 0.0, 0);
        let busy = Frame::synthetic(SMALL, 7, 0.0, 8);
        assert!(busy.mean() > empty.mean());
    }

    #[test]
    fn pixel_range_valid() {
        let f = Frame::synthetic(SMALL, 3, 2.5, 5);
        assert!(f.data.iter().all(|v| (0.0..=1.0).contains(v)));
    }
}
