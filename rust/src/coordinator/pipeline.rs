//! Deterministic staged epoch pipeline.
//!
//! The autoscale runner decomposes every epoch into four stages —
//! **plan** (solve the epoch's target + serving plans), **actuate**
//! (gate the transition and mutate the fleet), **simulate** (execute
//! the serving plan on the sharded engine), **bill** (fold the epoch
//! into the outcome rows).  The [`PipelineExecutor`] drives them with
//! one overlap: while epoch `i` simulates on the main thread, epoch
//! `i+1`'s *plan* stage runs speculatively on a `std::thread::scope`
//! worker.
//!
//! **Stage contract.**  The plan stage must be a *pure function* of
//! `(epoch index, seed)` — no access to the live fleet state — where
//! the seed is the snapshot `actuate` emits (incumbent plan + warm-
//! start bookkeeping).  `actuate` is the only stage that mutates
//! shared state, and it runs strictly in epoch order on the main
//! thread.  `finish` (simulate + bill) must not touch anything the
//! plan stage reads; that independence is exactly what makes the
//! overlap sound.
//!
//! **Speculation + invalidation rule.**  Epoch `i+1` is planned
//! against the seed produced by actuating epoch `i` — planning needs
//! only the epoch's demand plus the incumbent plan, both fixed before
//! simulation starts.  If, by the time the speculative plan is
//! consumed, the live seed no longer equals the snapshot it was
//! dispatched with (e.g. a future stage starts feeding simulated
//! outcomes back into the fleet), the speculation is discarded and the
//! epoch is re-planned synchronously against the real seed.  Under the
//! current stages simulation never mutates the seed, so speculation
//! always validates — the rule is the safety net that keeps the
//! pipeline correct if that ever changes.
//!
//! **Determinism guarantee.**  With `pipeline` off the executor calls
//! the plan stage synchronously at the top of each iteration; with it
//! on, the same function runs earlier on a worker with the same
//! inputs.  Either way every epoch consumes a plan computed from the
//! identical `(index, seed)` pair, so `--pipeline on|off` produce
//! identical outcomes, epoch for epoch — *provided the plan stage
//! itself is deterministic*.  That holds under the solver stack's own
//! precondition: solves must finish within their node budget before
//! the wall-clock deadline fires (see `SolveBudget::time_ms`), which
//! they do by a wide margin at every scale this repo runs.  Under a
//! deliberately starved `--solve-budget-ms` the portfolio may shed
//! different arms depending on machine load — pipelined or not — and
//! no execution mode can promise bit-equal plans.
//!
//! **Distribution.**  A registered worker fleet (`--workers`, see the
//! [`net`](crate::net) module) slots in *under* this pipeline, not
//! beside it: the plan stage's exact solves race frontier subtrees
//! across workers and the simulate stage ships simulation shards to
//! them, both behind seams that fold results exactly as the local
//! thread pool would.  Worker count is therefore like `--sim-threads`
//! — a wall-clock knob that never changes an outcome — and worker
//! *loss* degrades to local re-execution of the lost work, so the
//! pipeline's determinism contract survives an unreliable fleet.

use crate::util::error::Result;

/// The mutable half of the pipeline: consumes planned epochs strictly
/// in order.
pub(crate) trait EpochConsumer {
    /// Planning context snapshot the *next* epoch's plan stage starts
    /// from (compared by value for speculation validation; owned data —
    /// it crosses into the plan worker).
    type Seed: Clone + PartialEq + Send + 'static;
    /// Output of the plan stage (owned data — it crosses back from the
    /// plan worker).
    type Planned: Send + 'static;
    /// Data carried from actuation to simulation of the same epoch.
    type Carry;

    /// Stage 2 — apply the planned transition to live state; returns
    /// the carry plus the seed epoch `i+1` must be planned from.
    fn actuate(&mut self, planned: Self::Planned) -> Result<(Self::Carry, Self::Seed)>;

    /// Stages 3–4 — simulate the epoch and bill it.
    fn finish(&mut self, carry: Self::Carry) -> Result<()>;
}

/// Drives `n` epochs through plan → actuate → simulate/bill, optionally
/// overlapping epoch `i+1`'s plan with epoch `i`'s simulation.
pub(crate) struct PipelineExecutor {
    /// Overlap on (`--pipeline on`) or strictly sequential (`off`).
    pub pipeline: bool,
}

impl PipelineExecutor {
    pub(crate) fn execute<C, P>(
        &self,
        epochs: usize,
        initial: C::Seed,
        plan: P,
        consumer: &mut C,
    ) -> Result<()>
    where
        C: EpochConsumer,
        P: Fn(usize, &C::Seed) -> Result<C::Planned> + Sync,
    {
        let plan = &plan;
        std::thread::scope(|scope| {
            let mut seed = initial;
            let mut speculative: Option<(
                C::Seed,
                std::thread::ScopedJoinHandle<'_, Result<C::Planned>>,
            )> = None;
            for i in 0..epochs {
                let planned = match speculative.take() {
                    Some((basis, worker)) => {
                        let speculated = worker.join().expect("plan stage panicked");
                        if basis == seed {
                            speculated?
                        } else {
                            // Invalidation: the incumbent changed after
                            // the speculative solve was dispatched —
                            // discard it and re-plan against the real
                            // seed.
                            let _ = speculated;
                            plan(i, &seed)?
                        }
                    }
                    None => plan(i, &seed)?,
                };
                let (carry, next) = consumer.actuate(planned)?;
                seed = next;
                if self.pipeline && i + 1 < epochs {
                    let snapshot = seed.clone();
                    speculative =
                        Some((seed.clone(), scope.spawn(move || plan(i + 1, &snapshot))));
                }
                consumer.finish(carry)?;
            }
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::error::anyhow;

    /// Records the order stages run in; seeds count actuated epochs.
    struct Recorder {
        log: Vec<String>,
        fail_finish_at: Option<usize>,
    }

    impl EpochConsumer for Recorder {
        type Seed = usize;
        type Planned = (usize, usize);
        type Carry = usize;

        fn actuate(&mut self, (i, seed): (usize, usize)) -> Result<(usize, usize)> {
            self.log.push(format!("actuate {i} from seed {seed}"));
            Ok((i, i + 1))
        }

        fn finish(&mut self, i: usize) -> Result<()> {
            if self.fail_finish_at == Some(i) {
                return Err(anyhow!("finish {i} failed"));
            }
            self.log.push(format!("finish {i}"));
            Ok(())
        }
    }

    fn run(pipeline: bool, epochs: usize, fail_finish_at: Option<usize>) -> (Recorder, Result<()>) {
        let mut consumer = Recorder { log: Vec::new(), fail_finish_at };
        let result = PipelineExecutor { pipeline }.execute(
            epochs,
            0usize,
            |i, &seed| Ok((i, seed)),
            &mut consumer,
        );
        (consumer, result)
    }

    #[test]
    fn pipelined_and_sequential_consume_identical_seeds() {
        let (seq, r1) = run(false, 4, None);
        let (par, r2) = run(true, 4, None);
        r1.unwrap();
        r2.unwrap();
        assert_eq!(seq.log, par.log);
        // Every epoch was planned from the seed its predecessor's
        // actuation produced.
        assert_eq!(seq.log[0], "actuate 0 from seed 0");
        assert_eq!(seq.log[6], "actuate 3 from seed 3");
    }

    #[test]
    fn plan_errors_surface_at_the_failing_epoch() {
        let mut consumer = Recorder { log: Vec::new(), fail_finish_at: None };
        let result = PipelineExecutor { pipeline: true }.execute(
            3,
            0usize,
            |i, &seed| {
                if i == 2 {
                    Err(anyhow!("epoch {i} unplannable"))
                } else {
                    Ok((i, seed))
                }
            },
            &mut consumer,
        );
        assert!(result.is_err());
        // Epochs 0 and 1 completed before the failure propagated.
        assert_eq!(consumer.log.iter().filter(|l| l.starts_with("finish")).count(), 2);
    }

    #[test]
    fn finish_errors_abort_with_speculation_in_flight() {
        let (consumer, result) = run(true, 4, Some(1));
        assert!(result.is_err());
        assert!(consumer.log.contains(&"finish 0".to_string()));
        assert!(!consumer.log.contains(&"finish 1".to_string()));
    }
}
