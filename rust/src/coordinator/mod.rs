//! End-to-end orchestration: profile → allocate → provision → run →
//! report.  This is the binary's engine and what the examples drive.

use crate::cloud::{BillingMeter, InstanceId, SimInstance};
use crate::config::Scenario;
use crate::manager::{AllocationError, AllocationPlan, ResourceManager, Strategy};
use crate::profiler::calibration::Calibration;
use crate::profiler::live::TestRunner;
use crate::profiler::store::ProfileStore;
use crate::profiler::ResourceProfile;
use crate::runtime::ModelRuntime;
use crate::sched::{SimConfig, SimReport, Simulation};
use crate::streams::StreamSpec;
use crate::types::{Dollars, Program, VGA};
use anyhow::Result;

/// Outcome of one scenario run under one strategy.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub strategy: Strategy,
    pub plan: AllocationPlan,
    pub report: SimReport,
    /// Cost actually billed for the simulated span (started hours).
    pub billed: Dollars,
}

/// Outcome or failure per strategy — Table 6 rows ("Fail" included).
pub type StrategyOutcome = std::result::Result<RunOutcome, AllocationError>;

/// The coordinator: owns profiles and drives the full pipeline.
pub struct Coordinator {
    pub calibration: Calibration,
    /// Measured profiles (live test runs) override calibration when set.
    pub profiles: Option<ProfileStore>,
}

impl Default for Coordinator {
    fn default() -> Self {
        Coordinator { calibration: Calibration::paper(), profiles: None }
    }
}

impl Coordinator {
    pub fn new() -> Coordinator {
        Coordinator::default()
    }

    /// Use live-measured profiles (from [`Coordinator::profile_live`]).
    pub fn with_profiles(mut self, profiles: ProfileStore) -> Coordinator {
        self.profiles = Some(profiles);
        self
    }

    /// Resolve the profile for one stream spec.
    pub fn profile_for(&self, spec: &StreamSpec) -> ResourceProfile {
        if let Some(store) = &self.profiles {
            if let Some(p) = store.get(spec.program, spec.camera.frame_size) {
                return p.clone();
            }
        }
        self.calibration
            .profile(spec.program, spec.camera.frame_size)
    }

    /// Run the paper's test-run step for both programs at VGA on the
    /// real PJRT runtime, producing a measured profile store.
    pub fn profile_live(&self, runtime: &ModelRuntime, frames: usize) -> Result<ProfileStore> {
        let mut runner = TestRunner::new(runtime);
        runner.frames = frames;
        let mut store = ProfileStore::new();
        for program in Program::ALL {
            store.insert(runner.profile(program, VGA, &self.calibration)?);
        }
        Ok(store)
    }

    /// Allocate + provision + simulate one scenario under one strategy.
    pub fn run_scenario(
        &self,
        scenario: &Scenario,
        strategy: Strategy,
        sim: SimConfig,
    ) -> StrategyOutcome {
        let mgr = ResourceManager::new(scenario.catalog.clone(), self);
        let plan = mgr.allocate(&scenario.streams, strategy)?;

        // Provision simulated instances + billing.
        let mut billing = BillingMeter::new();
        for (i, inst) in plan.instances.iter().enumerate() {
            let itype = scenario
                .catalog
                .get(&inst.type_name)
                .expect("plan types come from the catalog")
                .clone();
            let mut sim_inst = SimInstance::new(InstanceId(i as u32), itype, 0.0);
            billing.on_provision(&sim_inst);
            sim_inst.mark_running();
        }

        // Execute the frame loops.
        let layout = scenario.catalog.layout();
        let mut simulation = Simulation::from_plan(
            &plan,
            &scenario.streams,
            layout,
            |i| self.profile_for(&scenario.streams[i]),
            &scenario.catalog,
        );
        let report = simulation.run(sim);
        let billed = billing.total_cost(sim.duration_s);
        Ok(RunOutcome { strategy, plan, report, billed })
    }

    /// Run all three strategies on a scenario — one Table 6 block.
    pub fn compare_strategies(
        &self,
        scenario: &Scenario,
        sim: SimConfig,
    ) -> Vec<(Strategy, StrategyOutcome)> {
        Strategy::ALL
            .iter()
            .map(|&s| (s, self.run_scenario(scenario, s, sim)))
            .collect()
    }
}

impl crate::manager::ProfileSource for Coordinator {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile> {
        Some(Coordinator::profile_for(self, spec))
    }
}

/// Render a Table-6-style block for one scenario's strategy outcomes.
pub fn render_table6_block(
    scenario: &Scenario,
    outcomes: &[(Strategy, StrategyOutcome)],
) -> crate::metrics::Table {
    let mut table = crate::metrics::Table::new(&format!(
        "Table 6 — {} ({} streams)",
        scenario.name,
        scenario.streams.len()
    ))
    .header(&[
        "Strategy", "non-GPU", "GPU", "Hourly Cost", "Savings", "Perf",
    ]);
    // Savings are relative to the most expensive successful strategy,
    // exactly as the paper computes them.
    let max_cost = outcomes
        .iter()
        .filter_map(|(_, o)| o.as_ref().ok())
        .map(|o| o.plan.hourly_cost)
        .max()
        .unwrap_or(Dollars::ZERO);
    for (strategy, outcome) in outcomes {
        match outcome {
            Ok(run) => {
                let (non_gpu, gpu) = run.plan.instance_counts(&scenario.catalog);
                table.row(&[
                    strategy.to_string(),
                    non_gpu.to_string(),
                    gpu.to_string(),
                    run.plan.hourly_cost.to_string(),
                    format!("{:.0}%", run.plan.hourly_cost.savings_vs(max_cost)),
                    format!("{:.0}%", run.report.overall_performance() * 100.0),
                ]);
            }
            Err(_) => {
                table.row(&[
                    strategy.to_string(),
                    "Fail".into(),
                    "Fail".into(),
                    "Fail".into(),
                    "Fail".into(),
                    "-".into(),
                ]);
            }
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_scenario;

    fn quick_sim() -> SimConfig {
        SimConfig { duration_s: 60.0, dt: 0.01, queue_cap: 32 }
    }

    #[test]
    fn scenario1_table6_row() {
        let c = Coordinator::new();
        let scenario = paper_scenario(1).unwrap();
        let outcomes = c.compare_strategies(&scenario, quick_sim());

        let st1 = outcomes[0].1.as_ref().unwrap();
        assert_eq!(st1.plan.hourly_cost, Dollars::from_f64(1.676));
        let st2 = outcomes[1].1.as_ref().unwrap();
        assert_eq!(st2.plan.hourly_cost, Dollars::from_f64(0.650));
        let st3 = outcomes[2].1.as_ref().unwrap();
        assert_eq!(st3.plan.hourly_cost, Dollars::from_f64(0.650));
        // 61% saving of ST3 vs ST1.
        assert_eq!(
            st3.plan.hourly_cost.savings_vs(st1.plan.hourly_cost).round() as i64,
            61
        );
        // All strategies must meet the >=90% performance target.
        for (_, o) in &outcomes {
            let o = o.as_ref().unwrap();
            assert!(
                o.report.overall_performance() >= 0.9,
                "{}: perf {}",
                o.strategy,
                o.report.overall_performance()
            );
        }
    }

    #[test]
    fn scenario2_table6_row() {
        let c = Coordinator::new();
        let scenario = paper_scenario(2).unwrap();
        let outcomes = c.compare_strategies(&scenario, quick_sim());
        let st1 = outcomes[0].1.as_ref().unwrap();
        let st2 = outcomes[1].1.as_ref().unwrap();
        let st3 = outcomes[2].1.as_ref().unwrap();
        assert_eq!(st1.plan.hourly_cost, Dollars::from_f64(0.419));
        assert_eq!(st2.plan.hourly_cost, Dollars::from_f64(0.650));
        assert_eq!(st3.plan.hourly_cost, Dollars::from_f64(0.419));
        assert_eq!(
            st3.plan.hourly_cost.savings_vs(st2.plan.hourly_cost).round() as i64,
            36
        );
    }

    #[test]
    fn scenario3_table6_row() {
        let c = Coordinator::new();
        let scenario = paper_scenario(3).unwrap();
        let outcomes = c.compare_strategies(&scenario, quick_sim());
        assert!(outcomes[0].1.is_err(), "ST1 must fail scenario 3");
        let st2 = outcomes[1].1.as_ref().unwrap();
        let st3 = outcomes[2].1.as_ref().unwrap();
        assert_eq!(st2.plan.hourly_cost, Dollars::from_f64(7.150));
        assert_eq!(st3.plan.hourly_cost, Dollars::from_f64(6.919));
        assert_eq!(st2.plan.instance_counts(&scenario.catalog), (0, 11));
        assert_eq!(st3.plan.instance_counts(&scenario.catalog), (1, 10));
        assert_eq!(
            st3.plan.hourly_cost.savings_vs(st2.plan.hourly_cost).round() as i64,
            3
        );
    }

    #[test]
    fn billing_covers_simulated_hours() {
        let c = Coordinator::new();
        let scenario = paper_scenario(2).unwrap();
        let run = c
            .run_scenario(&scenario, Strategy::St3, quick_sim())
            .unwrap();
        // One c4.2xlarge for <=1h -> one billed hour.
        assert_eq!(run.billed, Dollars::from_f64(0.419));
    }

    #[test]
    fn table6_rendering_includes_fail() {
        let c = Coordinator::new();
        let scenario = paper_scenario(3).unwrap();
        let outcomes = c.compare_strategies(&scenario, quick_sim());
        let rendered = render_table6_block(&scenario, &outcomes).render();
        assert!(rendered.contains("Fail"));
        assert!(rendered.contains("$6.919"));
        assert!(rendered.contains("$7.150"));
        assert!(rendered.contains("3%"));
    }
}
