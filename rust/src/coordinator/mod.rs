//! End-to-end orchestration: profile → allocate → provision → simulate
//! → bill.  This is the binary's engine and what the examples drive.
//!
//! The pipeline is composed of explicit stages that consume a
//! [`Workload`](crate::workload::Workload):
//!
//! 1. [`Coordinator::profile_workload`] resolves every stream's
//!    [`ResourceProfile`] once (workload store → coordinator store →
//!    calibration) into a [`ProfiledWorkload`];
//! 2. [`ProfiledWorkload::allocate`] runs the resource manager under a
//!    strategy and yields an [`AllocationPlan`];
//! 3. [`Provisioned::provision`] boots the planned [`SimInstance`]s and
//!    starts their [`BillingMeter`] records — the instances are
//!    *retained* so per-instance billed hours survive the run;
//! 4. [`ProfiledWorkload::simulation`] + [`Simulation::run`] execute
//!    the frame loops under the configured engine;
//! 5. [`Provisioned::settle`] terminates the fleet at the simulated
//!    horizon and prices the billed span.
//!
//! [`Coordinator::run_workload`] composes the five stages;
//! [`Coordinator::run_scenario`] is the scenario-flavored facade the
//! reports and examples use.  Paper scenarios and synthetic fleets go
//! through the same path.
//!
//! The [`autoscale`] submodule lifts the pipeline into the time
//! dimension: an [`autoscale::AutoscaleRunner`] re-plans per epoch of a
//! demand trace, carries the provisioned fleet across epochs, and
//! compares provisioning policies under started-hour billing.  Its
//! epochs execute as an explicit plan → actuate → simulate → bill
//! stage pipeline (the `pipeline` module's executor overlaps epoch
//! `i+1`'s solve with epoch `i`'s sharded simulation).

pub mod autoscale;
pub(crate) mod pipeline;

pub use autoscale::{
    AutoscaleConfig, AutoscaleOutcome, AutoscaleRunner, ScalePolicy, SolveMode,
};

use crate::cloud::{BillingMeter, Catalog, InstanceId, SimInstance};
use crate::config::Scenario;
use crate::manager::{AllocationError, AllocationPlan, ProfileSource, ResourceManager, Strategy};
use crate::packing::{SolveBudget, SolverChoice};
use crate::profiler::calibration::Calibration;
use crate::profiler::live::TestRunner;
use crate::profiler::store::ProfileStore;
use crate::profiler::ResourceProfile;
use crate::runtime::ModelRuntime;
use crate::sched::{SimConfig, SimReport, Simulation};
use crate::streams::StreamSpec;
use crate::types::{Dollars, Program, VGA};
use crate::util::error::Result;
use crate::workload::Workload;
use std::collections::BTreeMap;

/// Billed span of one retained instance.
#[derive(Clone, Copy, Debug)]
pub struct InstanceBill {
    pub id: InstanceId,
    pub hours: u32,
    pub cost: Dollars,
}

/// Outcome of one workload run under one strategy.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub strategy: Strategy,
    pub plan: AllocationPlan,
    pub report: SimReport,
    /// Cost actually billed for the simulated span (started hours).
    pub billed: Dollars,
    /// The provisioned instances, terminated at the simulated horizon —
    /// retained so lifecycle and billing can be inspected per instance.
    pub instances: Vec<SimInstance>,
    /// Per-instance billed hours and cost (sums to `billed`).
    pub instance_bills: Vec<InstanceBill>,
}

/// Outcome or failure per strategy — Table 6 rows ("Fail" included).
pub type StrategyOutcome = std::result::Result<RunOutcome, AllocationError>;

/// The coordinator: owns profiles and drives the full pipeline.
pub struct Coordinator {
    pub calibration: Calibration,
    /// Measured profiles (live test runs) override calibration when set.
    pub profiles: Option<ProfileStore>,
    /// Solver routing for every allocation made through this
    /// coordinator (the CLI's `--solver`).
    pub solver: SolverChoice,
    /// Solve budget handed down with the routing (`--solve-budget-ms`,
    /// `--exact-cutoff`).
    pub budget: SolveBudget,
}

impl Default for Coordinator {
    fn default() -> Self {
        Coordinator {
            calibration: Calibration::paper(),
            profiles: None,
            solver: SolverChoice::Auto,
            budget: SolveBudget::default(),
        }
    }
}

/// Stage-1 output: a workload with every stream's profile resolved.
///
/// Implements [`ProfileSource`] so the allocation stage and any
/// re-planning consume the *same* resolved profiles the simulation
/// will use.
pub struct ProfiledWorkload {
    pub workload: Workload,
    /// Resolved profile per (program, frame-size) variant in use.
    by_variant: BTreeMap<String, ResourceProfile>,
    /// Resolved profile per stream (parallel to `workload.streams`),
    /// materialized once so simulation setup is allocation-cheap even
    /// when called repeatedly (benches build one `Simulation` per run).
    per_stream: Vec<ResourceProfile>,
    /// Solver routing inherited from the coordinator at profile time.
    solver: SolverChoice,
    budget: SolveBudget,
}

impl ProfiledWorkload {
    /// The resolved profile of stream `index`.
    pub fn profile(&self, index: usize) -> &ResourceProfile {
        &self.per_stream[index]
    }

    /// Profiles parallel to the stream list (simulation input).
    pub fn per_stream(&self) -> &[ResourceProfile] {
        &self.per_stream
    }

    /// A resource manager over this workload's catalog and profiles,
    /// carrying the coordinator's solver routing — the one the
    /// allocation stage and the autoscaler's repack/warm-start calls
    /// share.
    pub fn manager(&self) -> ResourceManager<'_> {
        ResourceManager::with_routing(self.workload.catalog.clone(), self, self.solver, self.budget)
    }

    /// Stage 2: allocate instances for the workload under `strategy`.
    pub fn allocate(
        &self,
        strategy: Strategy,
    ) -> std::result::Result<AllocationPlan, AllocationError> {
        self.manager().allocate(&self.workload.streams, strategy)
    }

    /// Stage 4 setup: build the frame-loop simulation for a plan.
    pub fn simulation(&self, plan: &AllocationPlan) -> Simulation {
        let layout = self.workload.catalog.layout();
        Simulation::from_plan(
            plan,
            &self.workload.streams,
            layout,
            &self.per_stream,
            &self.workload.catalog,
        )
    }
}

impl ProfileSource for ProfiledWorkload {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile> {
        self.by_variant
            .get(&spec.program.variant(spec.camera.frame_size))
            .cloned()
    }
}

/// Stage-3 output: the provisioned fleet plus its running meter.
pub struct Provisioned {
    pub instances: Vec<SimInstance>,
    pub billing: BillingMeter,
}

impl Provisioned {
    /// Boot one [`SimInstance`] per planned instance at time `now`,
    /// opening a billing record for each.
    pub fn provision(plan: &AllocationPlan, catalog: &Catalog, now: f64) -> Provisioned {
        let mut billing = BillingMeter::new();
        let instances = plan
            .instances
            .iter()
            .enumerate()
            .map(|(i, inst)| {
                let off = catalog
                    .resolve(&inst.type_name)
                    .expect("plan types come from the catalog");
                let mut sim_inst = SimInstance::new(InstanceId(i as u32), off.itype, now);
                sim_inst.tier = off.tier;
                billing.on_provision(&sim_inst);
                sim_inst.mark_running();
                sim_inst
            })
            .collect();
        Provisioned { instances, billing }
    }

    /// Stage 5: terminate the fleet at time `now` and price the span.
    pub fn settle(&mut self, now: f64) -> (Dollars, Vec<InstanceBill>) {
        for inst in &mut self.instances {
            inst.terminate(now);
            self.billing.on_terminate(inst.id, now);
        }
        let bills: Vec<InstanceBill> = self
            .billing
            .per_instance(now)
            .into_iter()
            .map(|(id, hours, cost)| InstanceBill { id, hours, cost })
            .collect();
        (self.billing.total_cost(now), bills)
    }
}

impl Coordinator {
    pub fn new() -> Coordinator {
        Coordinator::default()
    }

    /// Use live-measured profiles (from [`Coordinator::profile_live`]).
    pub fn with_profiles(mut self, profiles: ProfileStore) -> Coordinator {
        self.profiles = Some(profiles);
        self
    }

    /// Route every downstream allocation through `solver`.
    pub fn with_solver(mut self, solver: SolverChoice) -> Coordinator {
        self.solver = solver;
        self
    }

    /// Solve budget handed to every downstream allocation.
    pub fn with_budget(mut self, budget: SolveBudget) -> Coordinator {
        self.budget = budget;
        self
    }

    /// Resolve the profile for one stream spec.
    pub fn profile_for(&self, spec: &StreamSpec) -> ResourceProfile {
        if let Some(store) = &self.profiles {
            if let Some(p) = store.get(spec.program, spec.camera.frame_size) {
                return p.clone();
            }
        }
        self.calibration
            .profile(spec.program, spec.camera.frame_size)
    }

    /// Run the paper's test-run step for both programs at VGA on the
    /// real PJRT runtime, producing a measured profile store.
    pub fn profile_live(&self, runtime: &ModelRuntime, frames: usize) -> Result<ProfileStore> {
        let mut runner = TestRunner::new(runtime);
        runner.frames = frames;
        let mut store = ProfileStore::new();
        for program in Program::ALL {
            store.insert(runner.profile(program, VGA, &self.calibration)?);
        }
        Ok(store)
    }

    /// Stage 1: resolve every stream's profile once.  Precedence:
    /// workload-attached store, then the coordinator's store, then
    /// calibration.
    pub fn profile_workload(&self, workload: Workload) -> ProfiledWorkload {
        let mut by_variant = BTreeMap::new();
        for spec in &workload.streams {
            let variant = spec.program.variant(spec.camera.frame_size);
            if by_variant.contains_key(&variant) {
                continue;
            }
            let profile = workload
                .profiles
                .as_ref()
                .and_then(|store| store.get(spec.program, spec.camera.frame_size).cloned())
                .unwrap_or_else(|| self.profile_for(spec));
            by_variant.insert(variant, profile);
        }
        let per_stream = workload
            .streams
            .iter()
            .map(|spec| by_variant[&spec.program.variant(spec.camera.frame_size)].clone())
            .collect();
        ProfiledWorkload {
            workload,
            by_variant,
            per_stream,
            solver: self.solver,
            budget: self.budget,
        }
    }

    /// The full pipeline on one workload under one strategy.
    pub fn run_workload(
        &self,
        workload: Workload,
        strategy: Strategy,
        sim: SimConfig,
    ) -> StrategyOutcome {
        let profiled = self.profile_workload(workload);
        let plan = profiled.allocate(strategy)?;
        let mut provisioned = Provisioned::provision(&plan, &profiled.workload.catalog, 0.0);
        let report = profiled.simulation(&plan).run(sim);
        let (billed, instance_bills) = provisioned.settle(sim.duration_s);
        Ok(RunOutcome {
            strategy,
            plan,
            report,
            billed,
            instances: provisioned.instances,
            instance_bills,
        })
    }

    /// Allocate + provision + simulate one scenario under one strategy.
    pub fn run_scenario(
        &self,
        scenario: &Scenario,
        strategy: Strategy,
        sim: SimConfig,
    ) -> StrategyOutcome {
        self.run_workload(Workload::from(scenario.clone()), strategy, sim)
    }

    /// Run all three strategies on a scenario — one Table 6 block.
    pub fn compare_strategies(
        &self,
        scenario: &Scenario,
        sim: SimConfig,
    ) -> Vec<(Strategy, StrategyOutcome)> {
        Strategy::ALL
            .iter()
            .map(|&s| (s, self.run_scenario(scenario, s, sim)))
            .collect()
    }
}

impl ProfileSource for Coordinator {
    fn profile_for(&self, spec: &StreamSpec) -> Option<ResourceProfile> {
        Some(Coordinator::profile_for(self, spec))
    }
}

/// Render a Table-6-style block for one scenario's strategy outcomes.
pub fn render_table6_block(
    scenario: &Scenario,
    outcomes: &[(Strategy, StrategyOutcome)],
) -> crate::metrics::Table {
    let mut table = crate::metrics::Table::new(&format!(
        "Table 6 — {} ({} streams)",
        scenario.name,
        scenario.streams.len()
    ))
    .header(&[
        "Strategy", "non-GPU", "GPU", "Hourly Cost", "Savings", "Perf",
    ]);
    // Savings are relative to the most expensive successful strategy,
    // exactly as the paper computes them.
    let max_cost = outcomes
        .iter()
        .filter_map(|(_, o)| o.as_ref().ok())
        .map(|o| o.plan.hourly_cost)
        .max()
        .unwrap_or(Dollars::ZERO);
    for (strategy, outcome) in outcomes {
        match outcome {
            Ok(run) => {
                let (non_gpu, gpu) = run.plan.instance_counts(&scenario.catalog);
                table.row(&[
                    strategy.to_string(),
                    non_gpu.to_string(),
                    gpu.to_string(),
                    run.plan.hourly_cost.to_string(),
                    format!("{:.0}%", run.plan.hourly_cost.savings_vs(max_cost)),
                    format!("{:.0}%", run.report.overall_performance() * 100.0),
                ]);
            }
            Err(_) => {
                table.row(&[
                    strategy.to_string(),
                    "Fail".into(),
                    "Fail".into(),
                    "Fail".into(),
                    "Fail".into(),
                    "-".into(),
                ]);
            }
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::InstanceState;
    use crate::config::paper_scenario;

    fn quick_sim() -> SimConfig {
        SimConfig::for_duration(60.0)
    }

    #[test]
    fn scenario1_table6_row() {
        let c = Coordinator::new();
        let scenario = paper_scenario(1).unwrap();
        let outcomes = c.compare_strategies(&scenario, quick_sim());

        let st1 = outcomes[0].1.as_ref().unwrap();
        assert_eq!(st1.plan.hourly_cost, Dollars::from_f64(1.676));
        let st2 = outcomes[1].1.as_ref().unwrap();
        assert_eq!(st2.plan.hourly_cost, Dollars::from_f64(0.650));
        let st3 = outcomes[2].1.as_ref().unwrap();
        assert_eq!(st3.plan.hourly_cost, Dollars::from_f64(0.650));
        // 61% saving of ST3 vs ST1.
        assert_eq!(
            st3.plan.hourly_cost.savings_vs(st1.plan.hourly_cost).round() as i64,
            61
        );
        // All strategies must meet the >=90% performance target.
        for (_, o) in &outcomes {
            let o = o.as_ref().unwrap();
            assert!(
                o.report.overall_performance() >= 0.9,
                "{}: perf {}",
                o.strategy,
                o.report.overall_performance()
            );
        }
    }

    #[test]
    fn scenario2_table6_row() {
        let c = Coordinator::new();
        let scenario = paper_scenario(2).unwrap();
        let outcomes = c.compare_strategies(&scenario, quick_sim());
        let st1 = outcomes[0].1.as_ref().unwrap();
        let st2 = outcomes[1].1.as_ref().unwrap();
        let st3 = outcomes[2].1.as_ref().unwrap();
        assert_eq!(st1.plan.hourly_cost, Dollars::from_f64(0.419));
        assert_eq!(st2.plan.hourly_cost, Dollars::from_f64(0.650));
        assert_eq!(st3.plan.hourly_cost, Dollars::from_f64(0.419));
        assert_eq!(
            st3.plan.hourly_cost.savings_vs(st2.plan.hourly_cost).round() as i64,
            36
        );
    }

    #[test]
    fn scenario3_table6_row() {
        let c = Coordinator::new();
        let scenario = paper_scenario(3).unwrap();
        let outcomes = c.compare_strategies(&scenario, quick_sim());
        assert!(outcomes[0].1.is_err(), "ST1 must fail scenario 3");
        let st2 = outcomes[1].1.as_ref().unwrap();
        let st3 = outcomes[2].1.as_ref().unwrap();
        assert_eq!(st2.plan.hourly_cost, Dollars::from_f64(7.150));
        assert_eq!(st3.plan.hourly_cost, Dollars::from_f64(6.919));
        assert_eq!(st2.plan.instance_counts(&scenario.catalog), (0, 11));
        assert_eq!(st3.plan.instance_counts(&scenario.catalog), (1, 10));
        assert_eq!(
            st3.plan.hourly_cost.savings_vs(st2.plan.hourly_cost).round() as i64,
            3
        );
    }

    #[test]
    fn billing_covers_simulated_hours() {
        let c = Coordinator::new();
        let scenario = paper_scenario(2).unwrap();
        let run = c
            .run_scenario(&scenario, Strategy::St3, quick_sim())
            .unwrap();
        // One c4.2xlarge for <=1h -> one billed hour.
        assert_eq!(run.billed, Dollars::from_f64(0.419));
    }

    #[test]
    fn provisioned_instances_are_retained_and_billed_per_instance() {
        // Scenario 3 / ST2: 11 g2.2xlarge — each must survive the run
        // with a terminated lifecycle and one billed hour at $0.650.
        let c = Coordinator::new();
        let scenario = paper_scenario(3).unwrap();
        let run = c
            .run_scenario(&scenario, Strategy::St2, quick_sim())
            .unwrap();
        assert_eq!(run.instances.len(), 11);
        assert_eq!(run.instance_bills.len(), 11);
        for inst in &run.instances {
            assert_eq!(inst.state, InstanceState::Terminated);
            assert_eq!(inst.terminated_at, Some(60.0));
            assert!((inst.billable_seconds(1e9) - 60.0).abs() < 1e-9);
        }
        for bill in &run.instance_bills {
            assert_eq!(bill.hours, 1);
            assert_eq!(bill.cost, Dollars::from_f64(0.650));
        }
        let total: Dollars = run.instance_bills.iter().map(|b| b.cost).sum();
        assert_eq!(total, run.billed);
        assert_eq!(run.billed, Dollars::from_f64(7.150));
    }

    #[test]
    fn pipeline_stages_compose_like_run_workload() {
        // Driving the stages by hand must equal the composed facade.
        let c = Coordinator::new();
        let workload = Workload::paper(2).unwrap();
        let profiled = c.profile_workload(workload.clone());
        let plan = profiled.allocate(Strategy::St3).unwrap();
        let mut provisioned =
            Provisioned::provision(&plan, &profiled.workload.catalog, 0.0);
        let report = profiled.simulation(&plan).run(quick_sim());
        let (billed, bills) = provisioned.settle(60.0);

        let composed = c
            .run_workload(workload.clone(), Strategy::St3, quick_sim())
            .unwrap();
        assert_eq!(composed.plan.hourly_cost, plan.hourly_cost);
        assert_eq!(composed.billed, billed);
        assert_eq!(composed.instance_bills.len(), bills.len());
        assert_eq!(composed.report.frames_completed, report.frames_completed);
        assert_eq!(
            composed.report.overall_performance(),
            report.overall_performance()
        );
    }

    #[test]
    fn workload_profile_store_overrides_coordinator() {
        // A workload-attached store takes precedence over calibration.
        let c = Coordinator::new();
        let mut store = ProfileStore::new();
        let mut p = c.calibration.profile(Program::Zf, VGA);
        p.cpu_work_cpu_mode = 1.0; // much cheaper than calibrated 7.12
        store.insert(p);
        let workload = Workload::new(
            "override",
            crate::streams::StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.5),
            crate::cloud::Catalog::paper_experiments(),
        )
        .with_profiles(store);
        let profiled = c.profile_workload(workload);
        assert_eq!(profiled.profile(0).cpu_work_cpu_mode, 1.0);
        // And the coordinator's calibration path is untouched.
        let plain = c.profile_workload(Workload::new(
            "plain",
            crate::streams::StreamSpec::replicate(0, 1, VGA, Program::Zf, 0.5),
            crate::cloud::Catalog::paper_experiments(),
        ));
        assert!((plain.profile(0).cpu_work_cpu_mode - 7.12).abs() < 1e-9);
    }

    #[test]
    fn table6_rendering_includes_fail() {
        let c = Coordinator::new();
        let scenario = paper_scenario(3).unwrap();
        let outcomes = c.compare_strategies(&scenario, quick_sim());
        let rendered = render_table6_block(&scenario, &outcomes).render();
        assert!(rendered.contains("Fail"));
        assert!(rendered.contains("$6.919"));
        assert!(rendered.contains("$7.150"));
        assert!(rendered.contains("3%"));
    }
}
