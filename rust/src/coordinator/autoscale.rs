//! Online autoscaling over a demand trace.
//!
//! [`AutoscaleRunner`] turns the static profile → allocate → provision
//! → simulate → bill pipeline into the *dynamic* resource manager the
//! paper motivates (§1): per [`Epoch`](crate::workload::trace::Epoch)
//! of a [`WorkloadTrace`] it re-solves the MVBP for the epoch's
//! streams, computes the fleet transition with
//! [`plan_transition`](crate::manager::plan_transition), gates it with
//! the feasibility-first [`worth_reallocating`] hysteresis, applies the
//! surviving actions to a fleet of [`SimInstance`]s carried *across*
//! epochs (so started-hour billing prices churn honestly — see
//! [`cloud::billing`](crate::cloud::billing)), and simulates the epoch
//! on the event engine.
//!
//! Four [`ScalePolicy`]s make the cost/performance trade-off
//! measurable:
//!
//! * [`ScalePolicy::StaticPeak`] — provision once for the most
//!   expensive epoch's plan and hold it (the "always ready" baseline);
//! * [`ScalePolicy::StaticMean`] — provision once for typical demand;
//!   bursts overflow onto a best-effort assignment and performance
//!   pays for it;
//! * [`ScalePolicy::Oracle`] — the *lower bound*: each epoch billed at
//!   its own optimal plan's hourly rate, pro-rated to the exact epoch
//!   duration with no churn cost.  No causal policy that actually
//!   *serves* every epoch can bill less, because a serving fleet costs
//!   at least the epoch's optimal rate and real billing rounds started
//!   hours up (an under-provisioned fleet can bill less — by dropping
//!   demand, which its performance metric exposes);
//! * [`ScalePolicy::Reactive`] — the paper-faithful online policy:
//!   warm-start solve per epoch (the previous epoch's plan carried in
//!   [`FleetState`] seeds the next solve so only the stream delta is
//!   re-packed; a certified-gap drift check falls back to a cold
//!   solve), hysteresis-gated transitions, fleet carried across epochs.

use super::{Coordinator, ProfiledWorkload};
use crate::cloud::{BillingMeter, Catalog, InstanceId, InstanceState, SimInstance};
use crate::manager::{
    assign_best_effort, plan_transition, repack_onto, worth_reallocating, AllocationPlan,
    Reallocation, ResourceManager, Strategy, TransitionAction,
};
use crate::packing::SolverKind;
use crate::sched::{SimConfig, SimReport};
use crate::types::Dollars;
use crate::util::error::{anyhow, Context, Result};
use crate::workload::trace::WorkloadTrace;

/// Provisioning policy compared by the autoscale harness.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ScalePolicy {
    /// One fleet sized for the costliest epoch, held for the whole trace.
    StaticPeak,
    /// One fleet sized for typical demand, held for the whole trace.
    StaticMean,
    /// Per-epoch optimal rate, pro-rated, churn-free: the lower bound.
    Oracle,
    /// Online re-planning with the feasibility-first hysteresis gate.
    Reactive,
}

impl ScalePolicy {
    pub const ALL: [ScalePolicy; 4] = [
        ScalePolicy::StaticPeak,
        ScalePolicy::StaticMean,
        ScalePolicy::Oracle,
        ScalePolicy::Reactive,
    ];
}

impl std::fmt::Display for ScalePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ScalePolicy::StaticPeak => "static-peak",
            ScalePolicy::StaticMean => "static-mean",
            ScalePolicy::Oracle => "oracle",
            ScalePolicy::Reactive => "reactive+hysteresis",
        })
    }
}

impl std::str::FromStr for ScalePolicy {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "static-peak" | "peak" => Ok(ScalePolicy::StaticPeak),
            "static-mean" | "mean" => Ok(ScalePolicy::StaticMean),
            "oracle" => Ok(ScalePolicy::Oracle),
            "reactive" | "reactive+hysteresis" | "hysteresis" => Ok(ScalePolicy::Reactive),
            other => Err(format!(
                "unknown policy {other:?} (expected static-peak, static-mean, oracle, or reactive)"
            )),
        }
    }
}

/// Autoscaling knobs shared by every policy run.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    pub strategy: Strategy,
    /// Per-epoch simulation template; `duration_s` is overridden by
    /// each epoch's duration.
    pub sim: SimConfig,
    /// Hysteresis planning horizon in hours; `None` = the remaining
    /// trace duration at each decision point.
    pub horizon_hours: Option<f64>,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            strategy: Strategy::St3,
            sim: SimConfig::default(),
            horizon_hours: None,
        }
    }
}

/// What happened in one epoch of a policy run.
#[derive(Clone, Debug)]
pub struct EpochOutcome {
    pub label: String,
    pub start_s: f64,
    pub duration_s: f64,
    /// Streams demanded by the epoch.
    pub streams: usize,
    /// Whether the fleet changed at this epoch boundary.
    pub reallocated: bool,
    pub kept: u32,
    pub provisioned: u32,
    pub terminated: u32,
    /// Running instances during the epoch.
    pub fleet_size: usize,
    /// Fleet run-rate during the epoch ([`BillingMeter::hourly_rate`]).
    pub hourly_rate: Dollars,
    /// Mean performance over *all* demanded streams (unserved count 0).
    pub performance: f64,
    /// Streams with no latency-sustainable device in the fleet.
    pub unserved: usize,
    pub frames_completed: u64,
    pub frames_dropped: u64,
    /// Which solver produced the plan served this epoch (warm-start,
    /// portfolio, exact, ...).
    pub solver: SolverKind,
    /// Certified optimality gap of the serving plan vs the full
    /// catalog.  `None` when the epoch ran on a hand-built best-effort
    /// placement or on a kept fleet (whose repack is solved against the
    /// fleet-restricted catalog and therefore carries no full-catalog
    /// certificate).
    pub gap: Option<f64>,
}

/// Result of one policy over one trace.
#[derive(Clone, Debug)]
pub struct AutoscaleOutcome {
    pub policy: ScalePolicy,
    pub trace_name: String,
    pub strategy: Strategy,
    pub epochs: Vec<EpochOutcome>,
    /// Total started-hour cost of the run (pro-rated for the oracle).
    pub total_billed: Dollars,
    /// Largest concurrent fleet across the trace.
    pub peak_fleet: usize,
    /// Epoch-duration-weighted mean performance.
    pub mean_performance: f64,
    /// Fleet transitions applied after the initial provisioning.
    pub reallocations: usize,
}

/// The provisioned fleet carried across epochs, plus its meter.  The
/// `plan` doubles as the warm-start incumbent: the reactive policy
/// seeds each epoch's solve with it so only the stream delta is
/// re-packed (`ResourceManager::allocate_warm`).
struct FleetState {
    instances: Vec<SimInstance>,
    billing: BillingMeter,
    /// Shape of the running fleet (per-type counts mirror `instances`)
    /// and the incumbent the next epoch's warm solve starts from.
    plan: AllocationPlan,
    next_id: u32,
}

/// Unused fraction of `inst`'s current started billing hour at `now`
/// (0 exactly on an hour boundary — terminating there wastes nothing).
fn wasted_fraction(inst: &SimInstance, now: f64) -> f64 {
    let run = (now - inst.started_at).max(0.0);
    let rem = run % 3600.0;
    if rem <= 1e-9 {
        0.0
    } else {
        (3600.0 - rem) / 3600.0
    }
}

impl FleetState {
    fn new(strategy: Strategy) -> FleetState {
        FleetState {
            instances: Vec::new(),
            billing: BillingMeter::new(),
            plan: AllocationPlan {
                strategy,
                solver: SolverKind::Exact,
                instances: Vec::new(),
                hourly_cost: Dollars::ZERO,
                // An empty fleet is vacuously optimal.
                lower_bound: Some(Dollars::ZERO),
            },
            next_id: 0,
        }
    }

    fn running_count(&self) -> usize {
        self.instances
            .iter()
            .filter(|i| i.state == InstanceState::Running)
            .count()
    }

    /// Indices of running instances of `type_name`, cheapest-to-kill
    /// first (smallest wasted fraction of the current started hour).
    fn termination_order(&self, type_name: &str, now: f64) -> Vec<usize> {
        let mut cands: Vec<(f64, usize)> = self
            .instances
            .iter()
            .enumerate()
            .filter(|(_, i)| i.state == InstanceState::Running && i.itype.name == type_name)
            .map(|(n, i)| (wasted_fraction(i, now), n))
            .collect();
        cands.sort_by(|a, b| a.0.total_cmp(&b.0));
        cands.into_iter().map(|(_, n)| n).collect()
    }

    /// Mean wasted fraction over the instances a transition would
    /// terminate — the `wasted_fraction` input of the hysteresis gate.
    fn mean_wasted_if(&self, realloc: &Reallocation, now: f64) -> f64 {
        let mut fractions = Vec::new();
        for action in &realloc.actions {
            if let TransitionAction::Terminate { type_name, count } = action {
                for &idx in self
                    .termination_order(type_name, now)
                    .iter()
                    .take(*count as usize)
                {
                    fractions.push(wasted_fraction(&self.instances[idx], now));
                }
            }
        }
        if fractions.is_empty() {
            0.5
        } else {
            fractions.iter().sum::<f64>() / fractions.len() as f64
        }
    }

    /// Apply a transition's terminate/provision actions at time `now`
    /// and adopt `target` as the fleet shape.
    fn apply(
        &mut self,
        realloc: &Reallocation,
        target: &AllocationPlan,
        catalog: &Catalog,
        now: f64,
    ) {
        for action in &realloc.actions {
            match action {
                TransitionAction::Keep { .. } => {}
                TransitionAction::Terminate { type_name, count } => {
                    for idx in self
                        .termination_order(type_name, now)
                        .into_iter()
                        .take(*count as usize)
                    {
                        let id = self.instances[idx].id;
                        self.instances[idx].terminate(now);
                        self.billing.on_terminate(id, now);
                    }
                }
                TransitionAction::Provision { type_name, count } => {
                    let itype = catalog
                        .get(type_name)
                        .expect("plan types come from the catalog")
                        .clone();
                    for _ in 0..*count {
                        let mut inst =
                            SimInstance::new(InstanceId(self.next_id), itype.clone(), now);
                        self.next_id += 1;
                        self.billing.on_provision(&inst);
                        inst.mark_running();
                        self.instances.push(inst);
                    }
                }
            }
        }
        self.plan = target.clone();
    }

    /// Terminate everything still running and price the whole span.
    fn settle(&mut self, now: f64) -> Dollars {
        for inst in &mut self.instances {
            if inst.state != InstanceState::Terminated {
                inst.terminate(now);
                self.billing.on_terminate(inst.id, now);
            }
        }
        self.billing.total_cost(now)
    }
}

/// Drives [`ScalePolicy`] runs over a [`WorkloadTrace`].
pub struct AutoscaleRunner<'a> {
    pub coordinator: &'a Coordinator,
    pub config: AutoscaleConfig,
}

impl<'a> AutoscaleRunner<'a> {
    pub fn new(coordinator: &'a Coordinator) -> AutoscaleRunner<'a> {
        AutoscaleRunner { coordinator, config: AutoscaleConfig::default() }
    }

    pub fn with_config(mut self, config: AutoscaleConfig) -> AutoscaleRunner<'a> {
        self.config = config;
        self
    }

    /// Run every requested policy over the trace (the comparison
    /// harness behind `camcloud trace --policy all`).
    pub fn compare(
        &self,
        trace: &WorkloadTrace,
        policies: &[ScalePolicy],
    ) -> Vec<(ScalePolicy, Result<AutoscaleOutcome>)> {
        policies
            .iter()
            .map(|&p| (p, self.run(trace, p)))
            .collect()
    }

    /// Run one policy over the trace.
    pub fn run(&self, trace: &WorkloadTrace, policy: ScalePolicy) -> Result<AutoscaleOutcome> {
        if trace.epochs.is_empty() {
            return Err(anyhow!("trace {:?} has no epochs", trace.name));
        }
        let strategy = self.config.strategy;
        // Stage 1 per epoch: resolve profiles once.
        let profiled: Vec<ProfiledWorkload> = (0..trace.epochs.len())
            .map(|i| self.coordinator.profile_workload(trace.workload(i)))
            .collect();
        // Stage 2: the static and oracle policies need every epoch's
        // fresh optimal plan up front (peak/mean selection, the oracle
        // integral).  The reactive policy solves per epoch instead,
        // warm-started from the incumbent fleet.
        let mut fresh: Vec<AllocationPlan> = Vec::new();
        if policy != ScalePolicy::Reactive {
            for (i, epoch) in trace.epochs.iter().enumerate() {
                let plan = profiled[i]
                    .allocate(strategy)
                    .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?;
                fresh.push(plan);
            }
        }

        if policy == ScalePolicy::Oracle {
            return Ok(self.run_oracle(trace, &profiled, &fresh));
        }

        let static_plan = match policy {
            ScalePolicy::StaticPeak => Some(pick_peak(&fresh)),
            ScalePolicy::StaticMean => Some(pick_mean(trace, &fresh)),
            _ => None,
        };

        let total_s = trace.total_duration_s();
        let mut state = FleetState::new(strategy);
        let mut epochs = Vec::with_capacity(trace.epochs.len());
        let mut peak_fleet = 0usize;
        let mut reallocations = 0usize;
        let mut now = 0.0;
        for (i, epoch) in trace.epochs.iter().enumerate() {
            let pw = &profiled[i];
            let mgr = pw.manager();
            let target = match &static_plan {
                // A held static fleet re-uses its one plan as the target.
                Some(plan) => plan.clone(),
                // Reactive: warm-start from the incumbent fleet (cold
                // solve on the first epoch or when the incumbent cannot
                // seed the problem / its quality drifted).
                None => {
                    if state.plan.instances.is_empty() {
                        pw.allocate(strategy)
                            .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?
                    } else {
                        mgr.allocate_warm(&epoch.streams, strategy, &state.plan)
                            .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?
                    }
                }
            };
            let serving = repack_onto(&mgr, &state.plan, &epoch.streams, strategy)
                .with_context(|| format!("repacking epoch {:?}", epoch.label))?;
            let realloc = plan_transition(&state.plan, &target);
            let do_realloc = match policy {
                ScalePolicy::Reactive => {
                    let horizon = self
                        .config
                        .horizon_hours
                        .unwrap_or(((total_s - now) / 3600.0).max(1e-9));
                    let wasted = state.mean_wasted_if(&realloc, now);
                    // Feasibility-first hysteresis; if the gate keeps
                    // the fleet it must actually be able to serve.
                    worth_reallocating(&realloc, &state.plan, serving.is_some(), horizon, wasted)
                        || serving.is_none()
                }
                // Static policies provision once and never move again.
                _ => i == 0,
            };

            let changed = realloc.provisioned > 0 || realloc.terminated > 0;
            let (sim_plan, unserved) = if do_realloc {
                state.apply(&realloc, &target, &trace.catalog, now);
                if i > 0 && changed {
                    reallocations += 1;
                }
                match policy {
                    // A held static fleet still needs the epoch's
                    // streams mapped onto it.
                    ScalePolicy::StaticPeak | ScalePolicy::StaticMean => {
                        self.serve_static(&mgr, &state.plan, pw, epoch)?
                    }
                    _ => (target.clone(), Vec::new()),
                }
            } else if let Some(plan) = serving {
                (plan, Vec::new())
            } else {
                // Static fleet that cannot serve this epoch cleanly:
                // degrade rather than refuse.
                assign_best_effort(
                    &state.plan,
                    &epoch.streams,
                    pw.per_stream(),
                    strategy,
                    &trace.catalog,
                    mgr.headroom,
                )
            };

            peak_fleet = peak_fleet.max(state.running_count());
            let report = pw
                .simulation(&sim_plan)
                .run(SimConfig { duration_s: epoch.duration_s, ..self.config.sim });
            // A declined transition is no churn: the fleet was kept.
            let churn = if do_realloc {
                (realloc.kept, realloc.provisioned, realloc.terminated)
            } else {
                (state.running_count() as u32, 0, 0)
            };
            epochs.push(epoch_outcome(
                epoch,
                now,
                do_realloc && changed,
                churn,
                state.running_count(),
                state.billing.hourly_rate(now),
                &sim_plan,
                &report,
                unserved.len(),
            ));
            now += epoch.duration_s;
        }
        let total_billed = state.settle(total_s);
        Ok(finish_outcome(
            policy,
            trace,
            strategy,
            epochs,
            total_billed,
            peak_fleet,
            reallocations,
        ))
    }

    /// Map an epoch onto a held static fleet: clean repack if the fleet
    /// covers it, best-effort overflow otherwise.
    fn serve_static(
        &self,
        mgr: &ResourceManager<'_>,
        fleet: &AllocationPlan,
        pw: &ProfiledWorkload,
        epoch: &crate::workload::trace::Epoch,
    ) -> Result<(AllocationPlan, Vec<usize>)> {
        Ok(
            match repack_onto(mgr, fleet, &epoch.streams, self.config.strategy)
                .with_context(|| format!("repacking epoch {:?}", epoch.label))?
            {
                Some(plan) => (plan, Vec::new()),
                None => assign_best_effort(
                    fleet,
                    &epoch.streams,
                    pw.per_stream(),
                    self.config.strategy,
                    &mgr.catalog,
                    mgr.headroom,
                ),
            },
        )
    }

    /// The churn-free lower bound: each epoch billed at its optimal
    /// plan's hourly rate, pro-rated to the exact epoch duration.
    fn run_oracle(
        &self,
        trace: &WorkloadTrace,
        profiled: &[ProfiledWorkload],
        fresh: &[AllocationPlan],
    ) -> AutoscaleOutcome {
        let mut epochs = Vec::with_capacity(trace.epochs.len());
        let mut billed = 0.0f64;
        let mut peak_fleet = 0usize;
        let mut reallocations = 0usize;
        let mut now = 0.0;
        for (i, epoch) in trace.epochs.iter().enumerate() {
            let plan = &fresh[i];
            billed += plan.hourly_cost.as_f64() * epoch.duration_s / 3600.0;
            peak_fleet = peak_fleet.max(plan.instances.len());
            let report = profiled[i]
                .simulation(plan)
                .run(SimConfig { duration_s: epoch.duration_s, ..self.config.sim });
            // Churn accounted like the online policies account it — the
            // type-matched transition from the previous epoch's plan —
            // so the comparison table reads one metric across policies.
            let (churn, changed) = if i == 0 {
                ((0, plan.instances.len() as u32, 0), true)
            } else {
                let r = plan_transition(&fresh[i - 1], plan);
                let changed = r.provisioned > 0 || r.terminated > 0;
                ((r.kept, r.provisioned, r.terminated), changed)
            };
            if i > 0 && changed {
                reallocations += 1;
            }
            epochs.push(epoch_outcome(
                epoch,
                now,
                changed,
                churn,
                plan.instances.len(),
                plan.hourly_cost,
                plan,
                &report,
                0,
            ));
            now += epoch.duration_s;
        }
        finish_outcome(
            ScalePolicy::Oracle,
            trace,
            self.config.strategy,
            epochs,
            Dollars::from_f64(billed),
            peak_fleet,
            reallocations,
        )
    }
}

/// The costliest per-epoch plan — "provision for the peak".
fn pick_peak(fresh: &[AllocationPlan]) -> AllocationPlan {
    fresh
        .iter()
        .max_by(|a, b| a.hourly_cost.cmp(&b.hourly_cost))
        .expect("non-empty trace")
        .clone()
}

/// The per-epoch plan closest to the duration-weighted mean hourly
/// cost — "provision for typical demand".
fn pick_mean(trace: &WorkloadTrace, fresh: &[AllocationPlan]) -> AllocationPlan {
    let total: f64 = trace.total_duration_s();
    let mean: f64 = trace
        .epochs
        .iter()
        .zip(fresh)
        .map(|(e, p)| p.hourly_cost.as_f64() * e.duration_s)
        .sum::<f64>()
        / total;
    fresh
        .iter()
        .min_by(|a, b| {
            (a.hourly_cost.as_f64() - mean)
                .abs()
                .total_cmp(&(b.hourly_cost.as_f64() - mean).abs())
        })
        .expect("non-empty trace")
        .clone()
}

#[allow(clippy::too_many_arguments)]
fn epoch_outcome(
    epoch: &crate::workload::trace::Epoch,
    start_s: f64,
    reallocated: bool,
    (kept, provisioned, terminated): (u32, u32, u32),
    fleet_size: usize,
    hourly_rate: Dollars,
    sim_plan: &AllocationPlan,
    report: &SimReport,
    unserved: usize,
) -> EpochOutcome {
    let total = epoch.streams.len();
    let served_perf: f64 = report
        .streams
        .iter()
        .map(crate::metrics::StreamPerf::performance)
        .sum();
    let performance = if total == 0 { 1.0 } else { served_perf / total as f64 };
    EpochOutcome {
        label: epoch.label.clone(),
        start_s,
        duration_s: epoch.duration_s,
        streams: total,
        reallocated,
        kept,
        provisioned,
        terminated,
        fleet_size,
        hourly_rate,
        performance,
        unserved,
        frames_completed: report.frames_completed,
        frames_dropped: report.frames_dropped,
        solver: sim_plan.solver,
        gap: sim_plan.gap(),
    }
}

fn finish_outcome(
    policy: ScalePolicy,
    trace: &WorkloadTrace,
    strategy: Strategy,
    epochs: Vec<EpochOutcome>,
    total_billed: Dollars,
    peak_fleet: usize,
    reallocations: usize,
) -> AutoscaleOutcome {
    let total_s = trace.total_duration_s();
    let mean_performance = if total_s > 0.0 {
        epochs
            .iter()
            .map(|e| e.performance * e.duration_s)
            .sum::<f64>()
            / total_s
    } else {
        1.0
    };
    AutoscaleOutcome {
        policy,
        trace_name: trace.name.clone(),
        strategy,
        epochs,
        total_billed,
        peak_fleet,
        mean_performance,
        reallocations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};
    use crate::workload::trace::WorkloadTrace;

    #[test]
    fn reactive_tracks_the_demand_curve() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(7);
        let out = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert_eq!(out.epochs.len(), 3);
        // Normal: one c4.2xlarge; emergency: two g2.2xlarge; recovery:
        // back to one c4.2xlarge.
        assert_eq!(out.epochs[0].fleet_size, 1);
        assert_eq!(out.epochs[1].fleet_size, 2);
        assert_eq!(out.epochs[2].fleet_size, 1);
        assert_eq!(out.epochs[0].hourly_rate, Dollars::from_f64(0.419));
        assert_eq!(out.epochs[1].hourly_rate, Dollars::from_f64(1.300));
        assert_eq!(out.epochs[2].hourly_rate, Dollars::from_f64(0.419));
        assert!(out.epochs[1].reallocated && out.epochs[2].reallocated);
        assert_eq!(out.reallocations, 2);
        // c4 billed 2 started hours, 2 g2 for 1 hour, c4 again 2 hours.
        assert_eq!(out.total_billed, Dollars::from_f64(2.976));
        assert!(out.mean_performance >= 0.9, "perf {}", out.mean_performance);
        assert_eq!(out.peak_fleet, 2);
    }

    #[test]
    fn static_peak_holds_the_burst_fleet() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(7);
        let out = runner.run(&trace, ScalePolicy::StaticPeak).unwrap();
        // Two g2.2xlarge held for the whole 4 h trace.
        assert!(out.epochs.iter().all(|e| e.fleet_size == 2));
        assert_eq!(out.reallocations, 0);
        assert_eq!(out.total_billed, Dollars::from_f64(5.200));
        assert!(out.mean_performance >= 0.9);
    }

    #[test]
    fn static_mean_is_cheap_but_fails_the_burst() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(7);
        let out = runner.run(&trace, ScalePolicy::StaticMean).unwrap();
        // One c4.2xlarge held throughout: cheapest fleet...
        assert_eq!(out.total_billed, Dollars::from_f64(1.676));
        assert_eq!(out.reallocations, 0);
        // ...but ZF at ~1 FPS has no sustainable device on it, so the
        // emergency epoch collapses.
        assert_eq!(out.epochs[1].unserved, 10);
        assert!(out.epochs[1].performance < 0.1);
        assert!(out.mean_performance < 0.9);
    }

    #[test]
    fn oracle_is_a_lower_bound_and_fractional() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(7);
        let oracle = runner.run(&trace, ScalePolicy::Oracle).unwrap();
        // 0.419 * 1.5h + 1.30 * 1h + 0.419 * 1.5h = 2.557.
        assert_eq!(oracle.total_billed, Dollars::from_f64(2.557));
        // The bound applies to policies that *serve* every epoch; an
        // under-provisioned static-mean fleet escapes it by dropping the
        // burst on the floor (its performance shows it).
        for policy in [ScalePolicy::Reactive, ScalePolicy::StaticPeak] {
            let out = runner.run(&trace, policy).unwrap();
            assert!(
                out.total_billed >= oracle.total_billed,
                "{policy}: {} < oracle {}",
                out.total_billed,
                oracle.total_billed
            );
            assert!(out.mean_performance >= 0.9, "{policy} must actually serve");
        }
        let mean = runner.run(&trace, ScalePolicy::StaticMean).unwrap();
        assert!(mean.total_billed < oracle.total_billed);
        assert!(mean.mean_performance < 0.9);
    }

    #[test]
    fn hysteresis_keeps_fleet_when_churn_beats_savings() {
        // Two epochs: a burst, then a 90-second wind-down.  Scaling
        // down for the last sliver wastes more than it saves, so the
        // reactive policy keeps the GPU fleet and serves normal ops on
        // it via repack.
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let burst = StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0);
        let quiet = StreamSpec::replicate(0, 3, VGA, Program::Zf, 0.2);
        let trace = WorkloadTrace::new("winddown", Catalog::paper_experiments())
            .epoch("burst", 3000.0, burst)
            .epoch("tail", 90.0, quiet);
        let out = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert!(!out.epochs[1].reallocated, "tail must not churn");
        assert_eq!(out.reallocations, 0);
        assert_eq!(out.epochs[1].fleet_size, 2);
        // Kept fleet still serves the quiet epoch at full performance.
        assert!(out.epochs[1].performance >= 0.9);
        // One billed hour for each g2: churning would have added a c4
        // hour on top.
        assert_eq!(out.total_billed, Dollars::from_f64(1.300));
    }

    #[test]
    fn reactive_epochs_report_warm_start_provenance() {
        // Stable stream ids under a CPU-only strategy (tight certified
        // bound): epoch 0 solves cold, epoch 1 must be served by the
        // warm-start incremental repack, and every solved epoch carries
        // a finite certified gap.
        let c = Coordinator::new();
        let config = AutoscaleConfig {
            strategy: Strategy::St1,
            sim: SimConfig::default(),
            horizon_hours: None,
        };
        let runner = AutoscaleRunner::new(&c).with_config(config);
        let base = StreamSpec::replicate(0, 4, VGA, Program::Zf, 0.5);
        let mut grown = base.clone();
        grown.extend(StreamSpec::replicate(100, 2, VGA, Program::Zf, 0.5));
        let trace = WorkloadTrace::new("grow", Catalog::paper_experiments())
            .epoch("base", 3600.0, base)
            .epoch("grow", 3600.0, grown);
        let out = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert_eq!(out.epochs[0].solver, SolverKind::Exact);
        assert_eq!(out.epochs[1].solver, SolverKind::WarmStart);
        for e in &out.epochs {
            let gap = e.gap.expect("solved epochs carry a certified gap");
            assert!(gap.is_finite() && (0.0..=1.0).contains(&gap), "{gap}");
        }
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(13);
        let a = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        let b = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert_eq!(a.total_billed, b.total_billed);
        assert_eq!(a.mean_performance, b.mean_performance);
        assert_eq!(a.reallocations, b.reallocations);
    }

    #[test]
    fn empty_trace_is_an_error() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::new("empty", Catalog::paper_experiments());
        assert!(runner.run(&trace, ScalePolicy::Reactive).is_err());
    }

    #[test]
    fn policy_parse_round_trip() {
        for p in ScalePolicy::ALL {
            assert_eq!(p.to_string().parse::<ScalePolicy>().unwrap(), p);
        }
        assert_eq!("peak".parse::<ScalePolicy>().unwrap(), ScalePolicy::StaticPeak);
        assert_eq!("mean".parse::<ScalePolicy>().unwrap(), ScalePolicy::StaticMean);
        assert!("elastic".parse::<ScalePolicy>().is_err());
    }
}
