//! Online autoscaling over a demand trace, executed as a staged epoch
//! pipeline.
//!
//! [`AutoscaleRunner`] turns the static profile → allocate → provision
//! → simulate → bill pipeline into the *dynamic* resource manager the
//! paper motivates (§1).  Every epoch of a
//! [`WorkloadTrace`](crate::workload::trace::WorkloadTrace) flows
//! through four explicit stages (see [`super::pipeline`] for the
//! executor and the full stage contract):
//!
//! 1. **plan** ([`PlanStage`]) — solve the epoch's *target* plan
//!    (cold, or warm-started from the incumbent via
//!    `ResourceManager::allocate_warm` with periodic cold refresh) and
//!    derive a *serving* plan answering "can the fleet I already pay
//!    for serve this epoch?".  Pure in `(epoch, seed)`, so it can run
//!    speculatively on a worker thread;
//! 2. **actuate** ([`ActuateStage`]) — gate the transition with the
//!    feasibility-first [`worth_reallocating`] hysteresis and apply
//!    the surviving terminate/provision actions to the
//!    [`SimInstance`] fleet carried *across* epochs (started-hour
//!    billing prices churn honestly — see
//!    [`cloud::billing`](crate::cloud::billing));
//! 3. **simulate** ([`SimulateStage`]) — execute the serving plan on
//!    the sharded event engine (`--sim-threads`);
//! 4. **bill** ([`BillStage`]) — fold the simulated epoch into the
//!    outcome rows.
//!
//! The executor overlaps epoch `i+1`'s plan with epoch `i`'s
//! simulation (`--pipeline on`, the default): planning needs only the
//! epoch's demand plus the incumbent snapshot actuation emits, and a
//! speculative plan is invalidated and recomputed if the incumbent
//! changed underneath it.  Pipelining and simulation sharding never
//! change results — `--pipeline on|off` and any `--sim-threads` value
//! produce identical policy tables (see `tests/parallel.rs`).
//!
//! **Serving-plan reuse.**  The hysteresis gate needs to know whether
//! the current fleet can serve the new workload.  When the epoch's
//! target plan already fits within the incumbent's per-type instance
//! counts — the common case under warm-started churn — it *is* such a
//! plan and no extra solve runs; only when it does not fit does the
//! stage fall back to the restricted [`repack_onto`] solve (the cold
//! path).
//!
//! **Warm/cold provenance.**  Reactive epochs record a [`SolveMode`]:
//! warm-start accepted, cold solve, or a forced
//! [`SolveMode::ColdRefresh`] (every
//! [`AutoscaleConfig::cold_refresh_every`] consecutive warm epochs, or
//! when the warm plan's certified gap drifts more than
//! [`AutoscaleConfig::cold_refresh_drift`] above the last cold
//! solve's) so warm-start ratcheting is bounded *and visible* in the
//! per-epoch report.  A periodic refresh is *certificate-gated*: the
//! warm repack runs first, and when its certified gap is within
//! [`AutoscaleConfig::refresh_skip_gap`] the cold solve is provably
//! near-redundant and skipped — tighter lower bounds (the DFF family)
//! therefore translate directly into fewer cold solves on churny
//! traces.
//!
//! **Cross-epoch solve memoization.**  Diurnal traces repeat: hour 26
//! often demands the exact fleet hour 2 did.  Reactive cold solves
//! therefore consult a bounded [`SolveCache`] keyed by an
//! order-independent fingerprint of the aggregated problem plus the
//! solver routing ([`solve_key`]); a hit replays the cached plan
//! against the *current* epoch's streams — structurally re-validated
//! and cost-checked before reuse, falling back to the cold solve on
//! any mismatch — so repeat epochs skip the solve entirely.  Because
//! the solver stack is deterministic, a validated replay is
//! bit-identical to the solve it skips: every compared outcome field
//! (costs, fleet, gap, provenance) is unchanged, and only the
//! [`EpochOutcome::cached`] observability flag records that work was
//! saved.  That flag is *not* part of the pipeline determinism
//! contract — a mis-speculated pipelined plan can warm the cache for
//! its own replan — which is why `tests/parallel.rs` compares
//! everything except it.
//!
//! Four [`ScalePolicy`]s make the cost/performance trade-off
//! measurable:
//!
//! * [`ScalePolicy::StaticPeak`] — provision once for the most
//!   expensive epoch's plan and hold it (the "always ready" baseline);
//! * [`ScalePolicy::StaticMean`] — provision once for typical demand;
//!   bursts overflow onto a best-effort assignment and performance
//!   pays for it;
//! * [`ScalePolicy::Oracle`] — the *lower bound*: each epoch billed at
//!   its own optimal plan's hourly rate, pro-rated to the exact epoch
//!   duration with no churn cost.  No causal policy that actually
//!   *serves* every epoch can bill less, because a serving fleet costs
//!   at least the epoch's optimal rate and real billing rounds started
//!   hours up (an under-provisioned fleet can bill less — by dropping
//!   demand, which its performance metric exposes);
//! * [`ScalePolicy::Reactive`] — the paper-faithful online policy:
//!   warm-start solve per epoch with cold refresh, hysteresis-gated
//!   transitions, fleet carried across epochs.

use super::pipeline::{EpochConsumer, PipelineExecutor};
use super::{Coordinator, ProfiledWorkload};
use crate::cloud::{BillingMeter, Catalog, InstanceId, InstanceState, PricingTier, SimInstance};
use crate::manager::{
    assign_best_effort, plan_transition, repack_onto, solve_key, worth_reallocating,
    AllocationPlan, Reallocation, SolveCache, Strategy, TransitionAction,
};
use crate::packing::SolverKind;
use crate::sched::{SimConfig, SimReport};
use crate::types::Dollars;
use crate::util::error::{anyhow, Context, Result};
use crate::util::json::Json;
use crate::util::profiling;
use crate::workload::trace::WorkloadTrace;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Provisioning policy compared by the autoscale harness.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ScalePolicy {
    /// One fleet sized for the costliest epoch, held for the whole trace.
    StaticPeak,
    /// One fleet sized for typical demand, held for the whole trace.
    StaticMean,
    /// Per-epoch optimal rate, pro-rated, churn-free: the lower bound.
    Oracle,
    /// Online re-planning with the feasibility-first hysteresis gate.
    Reactive,
}

impl ScalePolicy {
    pub const ALL: [ScalePolicy; 4] = [
        ScalePolicy::StaticPeak,
        ScalePolicy::StaticMean,
        ScalePolicy::Oracle,
        ScalePolicy::Reactive,
    ];
}

impl std::fmt::Display for ScalePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ScalePolicy::StaticPeak => "static-peak",
            ScalePolicy::StaticMean => "static-mean",
            ScalePolicy::Oracle => "oracle",
            ScalePolicy::Reactive => "reactive+hysteresis",
        })
    }
}

impl std::str::FromStr for ScalePolicy {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "static-peak" | "peak" => Ok(ScalePolicy::StaticPeak),
            "static-mean" | "mean" => Ok(ScalePolicy::StaticMean),
            "oracle" => Ok(ScalePolicy::Oracle),
            "reactive" | "reactive+hysteresis" | "hysteresis" => Ok(ScalePolicy::Reactive),
            other => Err(format!(
                "unknown policy {other:?} (expected static-peak, static-mean, oracle, or reactive)"
            )),
        }
    }
}

/// How an epoch's target plan was produced — the Warm/Cold column of
/// the per-epoch report, making warm-start ratcheting visible.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SolveMode {
    /// Warm-start incremental repack accepted.
    Warm,
    /// Cold solve: first epoch, static/oracle pre-solve, or the warm
    /// path declining on its own quality gate.
    Cold,
    /// Cold solve *forced* by the periodic refresh or the cumulative
    /// gap-drift gate.
    ColdRefresh,
}

impl std::fmt::Display for SolveMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SolveMode::Warm => "warm",
            SolveMode::Cold => "cold",
            SolveMode::ColdRefresh => "refresh",
        })
    }
}

/// Autoscaling knobs shared by every policy run.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    pub strategy: Strategy,
    /// Per-epoch simulation template; `duration_s` is overridden by
    /// each epoch's duration.  Its [`Parallelism`](crate::sched::Parallelism)
    /// also drives the epoch pipeline (`--pipeline`) and simulation
    /// sharding (`--sim-threads`).
    pub sim: SimConfig,
    /// Hysteresis planning horizon in hours; `None` = the remaining
    /// trace duration at each decision point.
    pub horizon_hours: Option<f64>,
    /// Trigger a periodic refresh after this many consecutive
    /// warm-served epochs (0 disables it).  The refresh cold-solves
    /// unless the epoch's warm repack certifies a gap within
    /// [`AutoscaleConfig::refresh_skip_gap`].
    pub cold_refresh_every: usize,
    /// Force a cold solve when a warm plan's certified gap exceeds the
    /// last cold solve's by more than this (cumulative-drift anchor;
    /// the per-epoch `warm_gap_margin` gate in `allocate_warm` only
    /// bounds drift *per step* and can ratchet).
    pub cold_refresh_drift: f64,
    /// At a periodic refresh, keep the warm plan (and skip the cold
    /// solve) when its certified gap is at most this: the certificate
    /// proves a cold solve could recoup no more.  The knob only has
    /// teeth when the lower bound is tight — the DFF certificates are
    /// what let churny mixed-catalog traces skip most refresh solves.
    pub refresh_skip_gap: f64,
    /// Memoize reactive cold solves across epochs (see the module
    /// docs): repeat problems replay their validated cached plan
    /// instead of re-solving.  Replays are bit-identical to the solves
    /// they skip, so this is a pure wall-clock knob; disable it to
    /// force every cold site to solve (ablations, timing baselines).
    pub solve_cache: bool,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            strategy: Strategy::St3,
            sim: SimConfig::default(),
            horizon_hours: None,
            cold_refresh_every: 8,
            cold_refresh_drift: 0.15,
            refresh_skip_gap: 0.05,
            solve_cache: true,
        }
    }
}

/// What happened in one epoch of a policy run.
#[derive(Clone, Debug)]
pub struct EpochOutcome {
    pub label: String,
    pub start_s: f64,
    pub duration_s: f64,
    /// Streams demanded by the epoch.
    pub streams: usize,
    /// Whether the fleet changed at this epoch boundary.
    pub reallocated: bool,
    pub kept: u32,
    pub provisioned: u32,
    pub terminated: u32,
    /// Running instances during the epoch.
    pub fleet_size: usize,
    /// Fleet run-rate during the epoch ([`BillingMeter::hourly_rate`]).
    pub hourly_rate: Dollars,
    /// Mean performance over *all* demanded streams (unserved count 0).
    pub performance: f64,
    /// Streams with no latency-sustainable device in the fleet.
    pub unserved: usize,
    pub frames_completed: u64,
    pub frames_dropped: u64,
    /// Which solver produced the plan served this epoch (warm-start,
    /// portfolio, exact, ...).
    pub solver: SolverKind,
    /// Certified optimality gap of the serving plan vs the full
    /// catalog.  `None` when the epoch ran on a hand-built best-effort
    /// placement or on a restricted kept-fleet repack (whose solve runs
    /// against the fleet-restricted catalog and therefore carries no
    /// full-catalog certificate); kept epochs served by a fitting
    /// full-catalog plan keep that plan's certificate.
    pub gap: Option<f64>,
    /// Warm/cold provenance of the epoch's target plan.
    pub mode: SolveMode,
    /// The cold solve was skipped: the target plan was replayed from
    /// the cross-epoch [`SolveCache`].  Observability only — replays
    /// are bit-identical to the solves they skip, and this flag is
    /// deliberately excluded from the pipeline determinism contract
    /// (speculative planning may warm the cache for its own replan).
    pub cached: bool,
    /// Spot instances reclaimed by the provider mid-epoch
    /// (trace-scheduled revocation events).
    pub revoked: u32,
}

/// Result of one policy over one trace.
#[derive(Clone, Debug)]
pub struct AutoscaleOutcome {
    pub policy: ScalePolicy,
    pub trace_name: String,
    pub strategy: Strategy,
    pub epochs: Vec<EpochOutcome>,
    /// Total started-hour cost of the run (pro-rated for the oracle).
    pub total_billed: Dollars,
    /// Largest concurrent fleet across the trace.
    pub peak_fleet: usize,
    /// Epoch-duration-weighted mean performance.
    pub mean_performance: f64,
    /// Fleet transitions applied after the initial provisioning.
    pub reallocations: usize,
}

/// The provisioned fleet carried across epochs, plus its meter.  The
/// `plan` doubles as the warm-start incumbent: the reactive policy
/// seeds each epoch's solve with it so only the stream delta is
/// re-packed (`ResourceManager::allocate_warm`).
struct FleetState {
    instances: Vec<SimInstance>,
    billing: BillingMeter,
    /// Shape of the running fleet (per-type counts mirror `instances`)
    /// and the incumbent the next epoch's warm solve starts from.
    plan: AllocationPlan,
    next_id: u32,
}

/// Unused fraction of `inst`'s current started billing hour at `now`
/// (0 exactly on an hour boundary — terminating there wastes nothing).
fn wasted_fraction(inst: &SimInstance, now: f64) -> f64 {
    let run = (now - inst.started_at).max(0.0);
    let rem = run % 3600.0;
    if rem <= 1e-9 {
        0.0
    } else {
        (3600.0 - rem) / 3600.0
    }
}

impl FleetState {
    fn new(strategy: Strategy) -> FleetState {
        FleetState {
            instances: Vec::new(),
            billing: BillingMeter::new(),
            plan: AllocationPlan {
                strategy,
                solver: SolverKind::Exact,
                instances: Vec::new(),
                hourly_cost: Dollars::ZERO,
                transfer_rate: Dollars::ZERO,
                // An empty fleet is vacuously optimal.
                lower_bound: Some(Dollars::ZERO),
            },
            next_id: 0,
        }
    }

    fn running_count(&self) -> usize {
        self.instances
            .iter()
            .filter(|i| i.state == InstanceState::Running)
            .count()
    }

    /// Indices of running instances of `type_name`, cheapest-to-kill
    /// first (smallest wasted fraction of the current started hour).
    fn termination_order(&self, type_name: &str, now: f64) -> Vec<usize> {
        let mut cands: Vec<(f64, usize)> = self
            .instances
            .iter()
            .enumerate()
            .filter(|(_, i)| i.state == InstanceState::Running && i.itype.name == type_name)
            .map(|(n, i)| (wasted_fraction(i, now), n))
            .collect();
        cands.sort_by(|a, b| a.0.total_cmp(&b.0));
        cands.into_iter().map(|(_, n)| n).collect()
    }

    /// Mean wasted fraction over the instances a transition would
    /// terminate — the `wasted_fraction` input of the hysteresis gate.
    fn mean_wasted_if(&self, realloc: &Reallocation, now: f64) -> f64 {
        let mut fractions = Vec::new();
        for action in &realloc.actions {
            if let TransitionAction::Terminate { type_name, count } = action {
                for &idx in self
                    .termination_order(type_name, now)
                    .iter()
                    .take(*count as usize)
                {
                    fractions.push(wasted_fraction(&self.instances[idx], now));
                }
            }
        }
        if fractions.is_empty() {
            0.5
        } else {
            fractions.iter().sum::<f64>() / fractions.len() as f64
        }
    }

    /// Apply a transition's terminate/provision actions at time `now`
    /// and adopt `target` as the fleet shape.
    fn apply(
        &mut self,
        realloc: &Reallocation,
        target: &AllocationPlan,
        catalog: &Catalog,
        now: f64,
    ) {
        for action in &realloc.actions {
            match action {
                TransitionAction::Keep { .. } => {}
                TransitionAction::Terminate { type_name, count } => {
                    for idx in self
                        .termination_order(type_name, now)
                        .into_iter()
                        .take(*count as usize)
                    {
                        let id = self.instances[idx].id;
                        self.instances[idx].terminate(now);
                        self.billing.on_terminate(id, now);
                    }
                }
                TransitionAction::Provision { type_name, count } => {
                    let off = catalog
                        .resolve(type_name)
                        .expect("plan types come from the catalog");
                    for _ in 0..*count {
                        let mut inst =
                            SimInstance::new(InstanceId(self.next_id), off.itype.clone(), now);
                        inst.tier = off.tier;
                        self.next_id += 1;
                        self.billing.on_provision(&inst);
                        inst.mark_running();
                        self.instances.push(inst);
                    }
                }
            }
        }
        self.plan = target.clone();
    }

    /// Provider-side spot reclaim at time `now`: revoke
    /// `ceil(fraction x running spot)` instances — most recently
    /// provisioned first, a deterministic stand-in for the market
    /// preempting the newest capacity — and return the (offering) type
    /// names reclaimed.  Billing forgives the revoked partial hour
    /// ([`BillingMeter::on_revoke`]); on-demand and reserved instances
    /// are never touched.
    fn revoke_spot(&mut self, fraction: f64, now: f64) -> Vec<String> {
        let mut spot: Vec<usize> = self
            .instances
            .iter()
            .enumerate()
            .filter(|(_, i)| i.state == InstanceState::Running && i.tier == PricingTier::Spot)
            .map(|(n, _)| n)
            .collect();
        if spot.is_empty() || fraction <= 0.0 {
            return Vec::new();
        }
        let k = ((fraction * spot.len() as f64).ceil() as usize).min(spot.len());
        spot.sort_by_key(|&n| self.instances[n].id.0);
        spot.iter()
            .rev()
            .take(k)
            .map(|&idx| {
                let id = self.instances[idx].id;
                self.instances[idx].terminate(now);
                self.billing.on_revoke(id, now);
                self.instances[idx].itype.name.clone()
            })
            .collect()
    }

    /// Terminate everything still running and price the whole span.
    fn settle(&mut self, now: f64) -> Dollars {
        for inst in &mut self.instances {
            if inst.state != InstanceState::Terminated {
                inst.terminate(now);
                self.billing.on_terminate(inst.id, now);
            }
        }
        self.billing.total_cost(now)
    }
}

/// Does `plan` fit within `fleet`'s per-type instance counts — i.e. is
/// it executable on the fleet without provisioning anything?
fn fits_within(plan: &AllocationPlan, fleet: &AllocationPlan) -> bool {
    if fleet.instances.is_empty() {
        return plan.instances.is_empty();
    }
    let have = fleet.counts_by_type();
    plan.counts_by_type()
        .iter()
        .all(|(t, n)| have.get(t).copied().unwrap_or(0) >= *n)
}

/// Planning context snapshot: emitted by [`ActuateStage`], consumed —
/// possibly on a pipeline worker — by [`PlanStage`].  Compared by value
/// to validate speculative plans (see [`super::pipeline`]); the
/// derived equality is a *full structural* comparison — the incumbent's
/// stream assignments feed `allocate_warm`, so a seed that differs only
/// in assignments must still invalidate the speculation.
#[derive(Clone, PartialEq)]
pub(crate) struct PlanSeed {
    /// Incumbent plan: the fleet shape carried across epochs (the
    /// previous epoch's fresh plan for the oracle).
    incumbent: AllocationPlan,
    /// Consecutive warm-served epochs since the last cold solve.
    warm_streak: usize,
    /// Certified gap of the last cold solve — the drift anchor.
    cold_gap: Option<f64>,
}

/// Output of the plan stage for one epoch.
pub(crate) struct PlannedEpoch {
    index: usize,
    /// The plan the policy *wants* this epoch (warm/cold solve, held
    /// static plan, or the oracle's fresh optimum).
    target: AllocationPlan,
    /// A plan serving the epoch on the incumbent fleet without any
    /// provisioning, when one exists — the hysteresis feasibility
    /// signal *and* the plan simulated when the gate keeps the fleet.
    serving: Option<AllocationPlan>,
    mode: SolveMode,
    /// The target plan was replayed from the solve cache.
    cached: bool,
}

/// Stage 1 — **plan**.  Pure in `(epoch index, seed)`: reads only the
/// trace, the resolved profiles, and the pre-solved static plans, so
/// the pipeline executor can run it speculatively on a worker thread.
struct PlanStage<'a> {
    policy: ScalePolicy,
    config: &'a AutoscaleConfig,
    trace: &'a WorkloadTrace,
    profiled: &'a [ProfiledWorkload],
    /// Held plan of the static policies.
    static_plan: Option<AllocationPlan>,
    /// Fresh per-epoch optimal plans (static policies only — used both
    /// for peak/mean selection and as serving candidates).
    fresh: Vec<AllocationPlan>,
    /// Cross-epoch solve memoization (reactive policy only; `None`
    /// when disabled).  Guarded by a mutex because the stage may run
    /// speculatively on a pipeline worker.
    cache: Option<Mutex<SolveCache>>,
}

impl PlanStage<'_> {
    fn plan(&self, i: usize, seed: &PlanSeed) -> Result<PlannedEpoch> {
        profiling::time_phase("epoch:solve", || self.plan_inner(i, seed))
    }

    fn plan_inner(&self, i: usize, seed: &PlanSeed) -> Result<PlannedEpoch> {
        match self.policy {
            ScalePolicy::Oracle => {
                let epoch = &self.trace.epochs[i];
                let target = self.profiled[i]
                    .allocate(self.config.strategy)
                    .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?;
                Ok(PlannedEpoch {
                    index: i,
                    target,
                    serving: None,
                    mode: SolveMode::Cold,
                    cached: false,
                })
            }
            ScalePolicy::StaticPeak | ScalePolicy::StaticMean => {
                let held = self
                    .static_plan
                    .as_ref()
                    .expect("static policies pre-solve their held plan")
                    .clone();
                // The incumbent is the held fleet from epoch 0 onward;
                // the epoch's fresh optimum doubles as the serving
                // candidate.
                let serving = self.serving_plan(i, &held, Some(&self.fresh[i]))?;
                Ok(PlannedEpoch {
                    index: i,
                    target: held,
                    serving,
                    mode: SolveMode::Cold,
                    cached: false,
                })
            }
            ScalePolicy::Reactive => self.plan_reactive(i, seed),
        }
    }

    /// Warm-start solve with periodic/drift-gated cold refresh.
    fn plan_reactive(&self, i: usize, seed: &PlanSeed) -> Result<PlannedEpoch> {
        let epoch = &self.trace.epochs[i];
        let pw = &self.profiled[i];
        let strategy = self.config.strategy;
        let (target, mode, cached) = if seed.incumbent.instances.is_empty() {
            let (plan, cached) = self
                .cold_solve(i)
                .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?;
            (plan, SolveMode::Cold, cached)
        } else if self.config.cold_refresh_every > 0
            && seed.warm_streak >= self.config.cold_refresh_every
        {
            // Periodic refresh, warm-first: a warm repack whose
            // certified gap is within `refresh_skip_gap` proves a cold
            // solve could recoup at most that much — keep it and skip
            // the cold solve.  Only a warm plan that declines or
            // certifies worse pays for one.
            let plan = pw
                .manager()
                .allocate_warm(&epoch.streams, strategy, &seed.incumbent)
                .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?;
            if plan.solver != SolverKind::WarmStart {
                // allocate_warm already fell back to a cold solve on
                // its own gate; that is the refresh.
                (plan, SolveMode::ColdRefresh, false)
            } else if plan.gap().map_or(false, |g| g <= self.config.refresh_skip_gap) {
                (plan, SolveMode::Warm, false)
            } else {
                let (cold, cached) = self
                    .cold_solve(i)
                    .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?;
                (cold, SolveMode::ColdRefresh, cached)
            }
        } else {
            let plan = pw
                .manager()
                .allocate_warm(&epoch.streams, strategy, &seed.incumbent)
                .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?;
            if plan.solver == SolverKind::WarmStart {
                // Cumulative-drift gate: warm quality is measured
                // against the last *cold* solve, not just the previous
                // epoch, so per-step margins cannot ratchet unbounded.
                let drifted = match (plan.gap(), seed.cold_gap) {
                    (Some(gap), Some(anchor)) => gap - anchor > self.config.cold_refresh_drift,
                    _ => false,
                };
                if drifted {
                    let (cold, cached) = self
                        .cold_solve(i)
                        .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?;
                    (cold, SolveMode::ColdRefresh, cached)
                } else {
                    (plan, SolveMode::Warm, false)
                }
            } else {
                // allocate_warm already fell back to a cold solve on
                // its own per-step quality gate.
                (plan, SolveMode::Cold, false)
            }
        };
        let serving = self.serving_plan(i, &seed.incumbent, Some(&target))?;
        Ok(PlannedEpoch { index: i, target, serving, mode, cached })
    }

    /// Cold-solve epoch `i`, consulting the cross-epoch solve cache
    /// when one is enabled.  The second element reports whether the
    /// plan was *replayed* (`true`: the cache validated and reused a
    /// prior epoch's plan, skipping the solve).  Misses and rejected
    /// (stale) entries fall through to the cold solve and memoize its
    /// result for later epochs.
    fn cold_solve(
        &self,
        i: usize,
    ) -> std::result::Result<(AllocationPlan, bool), crate::manager::AllocationError> {
        let epoch = &self.trace.epochs[i];
        let pw = &self.profiled[i];
        let strategy = self.config.strategy;
        let cache = match &self.cache {
            Some(cache) => cache,
            None => return pw.allocate(strategy).map(|plan| (plan, false)),
        };
        let mgr = pw.manager();
        let built = mgr.build_problem(&epoch.streams, strategy)?;
        let key = solve_key(&built.problem, strategy, mgr.solver, &mgr.budget);
        let mut cache = cache.lock().expect("solve cache lock poisoned");
        if let Some(plan) = cache.replay(key, &built, &epoch.streams, strategy) {
            return Ok((plan, true));
        }
        let plan = mgr.solve_built(&built, &epoch.streams, strategy, None)?;
        cache.insert(key, plan.clone());
        Ok((plan, false))
    }

    /// Can `fleet` serve epoch `i` without provisioning?  When
    /// `candidate` (a full-catalog plan for exactly this epoch) fits
    /// within the fleet's per-type counts it *is* a serving plan and —
    /// unlike the restricted re-solve — keeps its full-catalog
    /// certificate; only otherwise does the restricted [`repack_onto`]
    /// solve run.
    fn serving_plan(
        &self,
        i: usize,
        fleet: &AllocationPlan,
        candidate: Option<&AllocationPlan>,
    ) -> Result<Option<AllocationPlan>> {
        if let Some(candidate) = candidate {
            if fits_within(candidate, fleet) {
                return Ok(Some(candidate.clone()));
            }
        }
        let epoch = &self.trace.epochs[i];
        let pw = &self.profiled[i];
        repack_onto(&pw.manager(), fleet, &epoch.streams, self.config.strategy)
            .with_context(|| format!("repacking epoch {:?}", epoch.label))
    }
}

/// What actuation hands to simulation for one epoch.
struct SimJob {
    index: usize,
    start_s: f64,
    sim_plan: AllocationPlan,
    unserved: usize,
    reallocated: bool,
    /// `(kept, provisioned, terminated)`.
    churn: (u32, u32, u32),
    fleet_size: usize,
    hourly_rate: Dollars,
    mode: SolveMode,
    /// The epoch's target plan was replayed from the solve cache.
    cached: bool,
    /// Spot instances reclaimed mid-epoch by revocation events.
    revoked: u32,
}

/// Stage 2 — **actuate**: the only stage that mutates shared state.
/// Gates the planned transition, applies it to the carried fleet, and
/// emits the [`PlanSeed`] the next epoch's plan stage starts from.
struct ActuateStage<'a> {
    policy: ScalePolicy,
    config: &'a AutoscaleConfig,
    total_s: f64,
    now: f64,
    state: FleetState,
    peak_fleet: usize,
    reallocations: usize,
    /// Oracle accumulator (pro-rated; no fleet is provisioned).
    oracle_billed: f64,
    warm_streak: usize,
    cold_gap: Option<f64>,
}

impl ActuateStage<'_> {
    fn seed(&self, incumbent: AllocationPlan) -> PlanSeed {
        PlanSeed { incumbent, warm_streak: self.warm_streak, cold_gap: self.cold_gap }
    }

    fn apply(
        &mut self,
        trace: &WorkloadTrace,
        profiled: &[ProfiledWorkload],
        planned: PlannedEpoch,
    ) -> (SimJob, PlanSeed) {
        let mode = planned.mode;
        let target_gap = planned.target.gap();
        let (job, incumbent) = if self.policy == ScalePolicy::Oracle {
            self.apply_oracle(trace, planned)
        } else {
            self.apply_fleet(trace, profiled, planned)
        };
        match mode {
            SolveMode::Warm => self.warm_streak += 1,
            SolveMode::Cold | SolveMode::ColdRefresh => {
                self.warm_streak = 0;
                self.cold_gap = target_gap;
            }
        }
        let seed = self.seed(incumbent);
        (job, seed)
    }

    fn apply_fleet(
        &mut self,
        trace: &WorkloadTrace,
        profiled: &[ProfiledWorkload],
        planned: PlannedEpoch,
    ) -> (SimJob, AllocationPlan) {
        let PlannedEpoch { index: i, target, serving, mode, cached } = planned;
        let epoch = &trace.epochs[i];
        let realloc = plan_transition(&self.state.plan, &target);
        let do_realloc = match self.policy {
            ScalePolicy::Reactive => {
                let horizon = self
                    .config
                    .horizon_hours
                    .unwrap_or(((self.total_s - self.now) / 3600.0).max(1e-9));
                let wasted = self.state.mean_wasted_if(&realloc, self.now);
                // Feasibility-first hysteresis; if the gate keeps the
                // fleet it must actually be able to serve.
                worth_reallocating(&realloc, &self.state.plan, serving.is_some(), horizon, wasted)
                    || serving.is_none()
            }
            // Static policies provision once and never move again.
            _ => i == 0,
        };

        let changed = realloc.provisioned > 0 || realloc.terminated > 0;
        let (mut sim_plan, mut unserved) = if do_realloc {
            profiling::time_phase("billing:actuate", || {
                self.state.apply(&realloc, &target, &trace.catalog, self.now);
            });
            if i > 0 && changed {
                self.reallocations += 1;
            }
            match self.policy {
                // A held static fleet still needs the epoch's streams
                // mapped onto it; the plan stage judged serving against
                // exactly this fleet.
                ScalePolicy::StaticPeak | ScalePolicy::StaticMean => match serving {
                    Some(plan) => (plan, Vec::new()),
                    None => self.best_effort(trace, profiled, i),
                },
                _ => (target, Vec::new()),
            }
        } else if let Some(plan) = serving {
            (plan, Vec::new())
        } else {
            // Fleet kept but unable to serve cleanly: degrade rather
            // than refuse.
            self.best_effort(trace, profiled, i)
        };

        self.peak_fleet = self.peak_fleet.max(self.state.running_count());
        // A declined transition is no churn: the fleet was kept.
        let churn = if do_realloc {
            (realloc.kept, realloc.provisioned, realloc.terminated)
        } else {
            (self.state.running_count() as u32, 0, 0)
        };
        let hourly_rate = self.state.billing.hourly_rate(self.now);
        // Mid-epoch spot reclaims fire after the boundary transition.
        let revoked = self.apply_revocations(trace, profiled, i, &mut sim_plan, &mut unserved);
        // Cross-region transfer accrues continuously at the serving
        // plan's rate for the epoch's duration.
        let transfer = sim_plan.transfer_rate.as_f64() * epoch.duration_s / 3600.0;
        if transfer > 0.0 {
            self.state.billing.add_transfer(Dollars::from_f64(transfer));
        }
        let job = SimJob {
            index: i,
            start_s: self.now,
            sim_plan,
            unserved: unserved.len(),
            reallocated: do_realloc && changed,
            churn,
            fleet_size: self.state.running_count(),
            hourly_rate,
            mode,
            cached,
            revoked,
        };
        self.now += epoch.duration_s;
        (job, self.state.plan.clone())
    }

    /// Actuate the epoch's scheduled spot-market reclaim events.  Each
    /// event terminates part of the running spot fleet mid-epoch
    /// ([`FleetState::revoke_spot`]) and emergency-repacks the orphaned
    /// streams through the warm-start delta path: the surviving fleet
    /// becomes the incumbent and [`crate::manager::ResourceManager::allocate_warm`]
    /// re-places only what the reclaim displaced (a cold solve runs
    /// only if the warm quality gate fires).  Returns the number of
    /// instances reclaimed this epoch.
    fn apply_revocations(
        &mut self,
        trace: &WorkloadTrace,
        profiled: &[ProfiledWorkload],
        i: usize,
        sim_plan: &mut AllocationPlan,
        unserved: &mut Vec<usize>,
    ) -> u32 {
        let epoch = &trace.epochs[i];
        if epoch.revocations.is_empty() {
            return 0;
        }
        let pw = &profiled[i];
        let mut events = epoch.revocations.clone();
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        let mut revoked = 0u32;
        for event in events {
            let at = self.now + event.at_s;
            let reclaimed = self.state.revoke_spot(event.fraction, at);
            if reclaimed.is_empty() {
                continue;
            }
            revoked += reclaimed.len() as u32;
            // Survivor fleet: the carried plan minus one entry per
            // reclaimed instance (orphaning its streams).
            let mut survivor = self.state.plan.clone();
            for name in &reclaimed {
                if let Some(pos) = survivor
                    .instances
                    .iter()
                    .rposition(|inst| inst.type_name == *name)
                {
                    survivor.instances.remove(pos);
                }
            }
            survivor.hourly_cost = survivor.instances.iter().map(|inst| inst.hourly_cost).sum();
            survivor.lower_bound = None;
            let repacked = if survivor.instances.is_empty() {
                pw.allocate(self.config.strategy)
            } else {
                pw.manager()
                    .allocate_warm(&epoch.streams, self.config.strategy, &survivor)
            };
            match repacked {
                Ok(target) => {
                    let realloc = plan_transition(&survivor, &target);
                    profiling::time_phase("billing:actuate", || {
                        self.state.apply(&realloc, &target, &trace.catalog, at);
                    });
                    self.reallocations += 1;
                    *sim_plan = target;
                    unserved.clear();
                }
                Err(_) => {
                    // Degrade rather than refuse: keep the survivors
                    // and best-effort the epoch's streams onto them.
                    self.state.plan = survivor;
                    let (plan, missed) = self.best_effort(trace, profiled, i);
                    *sim_plan = plan;
                    *unserved = missed;
                }
            }
            self.peak_fleet = self.peak_fleet.max(self.state.running_count());
        }
        revoked
    }

    /// The churn-free lower bound: each epoch billed at its optimal
    /// plan's hourly rate, pro-rated to the exact epoch duration.
    /// Churn is accounted like the online policies account it — the
    /// type-matched transition from the previous epoch's plan — so the
    /// comparison table reads one metric across policies.
    fn apply_oracle(
        &mut self,
        trace: &WorkloadTrace,
        planned: PlannedEpoch,
    ) -> (SimJob, AllocationPlan) {
        let PlannedEpoch { index: i, target: plan, mode, cached, .. } = planned;
        let epoch = &trace.epochs[i];
        self.oracle_billed += plan.total_rate().as_f64() * epoch.duration_s / 3600.0;
        self.peak_fleet = self.peak_fleet.max(plan.instances.len());
        let (churn, changed) = if i == 0 {
            ((0, plan.instances.len() as u32, 0), true)
        } else {
            let r = plan_transition(&self.state.plan, &plan);
            (
                (r.kept, r.provisioned, r.terminated),
                r.provisioned > 0 || r.terminated > 0,
            )
        };
        if i > 0 && changed {
            self.reallocations += 1;
        }
        let job = SimJob {
            index: i,
            start_s: self.now,
            sim_plan: plan.clone(),
            unserved: 0,
            reallocated: changed,
            churn,
            fleet_size: plan.instances.len(),
            hourly_rate: plan.hourly_cost,
            mode,
            cached,
            revoked: 0,
        };
        self.state.plan = plan;
        self.now += epoch.duration_s;
        (job, self.state.plan.clone())
    }

    /// Best-effort placement of an epoch a fixed fleet cannot serve
    /// cleanly.
    fn best_effort(
        &self,
        trace: &WorkloadTrace,
        profiled: &[ProfiledWorkload],
        i: usize,
    ) -> (AllocationPlan, Vec<usize>) {
        let pw = &profiled[i];
        assign_best_effort(
            &self.state.plan,
            &trace.epochs[i].streams,
            pw.per_stream(),
            self.config.strategy,
            &trace.catalog,
            pw.manager().headroom,
        )
    }
}

/// Stage 3 — **simulate**: execute the epoch's serving plan on the
/// (sharded) engine selected by the sim config; `duration_s` comes
/// from the epoch.
struct SimulateStage {
    sim: SimConfig,
}

impl SimulateStage {
    fn run(&self, trace: &WorkloadTrace, profiled: &[ProfiledWorkload], job: &SimJob) -> SimReport {
        let epoch = &trace.epochs[job.index];
        profiled[job.index]
            .simulation(&job.sim_plan)
            .run(SimConfig { duration_s: epoch.duration_s, ..self.sim })
    }
}

/// Stage 4 — **bill**: fold the simulated epoch into the outcome rows.
struct BillStage {
    epochs: Vec<EpochOutcome>,
}

impl BillStage {
    fn record(&mut self, trace: &WorkloadTrace, job: SimJob, report: &SimReport) {
        let epoch = &trace.epochs[job.index];
        let total = epoch.streams.len();
        let served_perf: f64 = report
            .streams
            .iter()
            .map(crate::metrics::StreamPerf::performance)
            .sum();
        let performance = if total == 0 { 1.0 } else { served_perf / total as f64 };
        let (kept, provisioned, terminated) = job.churn;
        self.epochs.push(EpochOutcome {
            label: epoch.label.clone(),
            start_s: job.start_s,
            duration_s: epoch.duration_s,
            streams: total,
            reallocated: job.reallocated,
            kept,
            provisioned,
            terminated,
            fleet_size: job.fleet_size,
            hourly_rate: job.hourly_rate,
            performance,
            unserved: job.unserved,
            frames_completed: report.frames_completed,
            frames_dropped: report.frames_dropped,
            solver: job.sim_plan.solver,
            gap: job.sim_plan.gap(),
            mode: job.mode,
            cached: job.cached,
            revoked: job.revoked,
        });
    }
}

/// The composed consumer the pipeline executor drives: actuate →
/// simulate → bill, with the plan stage running (speculatively) on the
/// executor's worker.
struct EpochDriver<'a> {
    trace: &'a WorkloadTrace,
    profiled: &'a [ProfiledWorkload],
    actuate: ActuateStage<'a>,
    simulate: SimulateStage,
    bill: BillStage,
}

impl EpochConsumer for EpochDriver<'_> {
    type Seed = PlanSeed;
    type Planned = PlannedEpoch;
    type Carry = SimJob;

    fn actuate(&mut self, planned: PlannedEpoch) -> Result<(SimJob, PlanSeed)> {
        Ok(profiling::time_phase("epoch:actuate", || {
            self.actuate.apply(self.trace, self.profiled, planned)
        }))
    }

    fn finish(&mut self, job: SimJob) -> Result<()> {
        let report =
            profiling::time_phase("epoch:simulate", || self.simulate.run(self.trace, self.profiled, &job));
        profiling::time_phase("epoch:bill", || self.bill.record(self.trace, job, &report));
        Ok(())
    }
}

/// Drives [`ScalePolicy`] runs over a [`WorkloadTrace`].
pub struct AutoscaleRunner<'a> {
    pub coordinator: &'a Coordinator,
    pub config: AutoscaleConfig,
    /// Persist the reactive policy's [`SolveCache`] across runs
    /// (`--solve-cache-file`): loaded before the trace starts, saved
    /// after it finishes.  Loaded entries are trusted no further than
    /// in-memory ones — every hit passes the full structural replay
    /// validation — so a stale or corrupt file costs cold solves, not
    /// correctness.
    pub solve_cache_file: Option<PathBuf>,
}

impl<'a> AutoscaleRunner<'a> {
    pub fn new(coordinator: &'a Coordinator) -> AutoscaleRunner<'a> {
        AutoscaleRunner {
            coordinator,
            config: AutoscaleConfig::default(),
            solve_cache_file: None,
        }
    }

    pub fn with_config(mut self, config: AutoscaleConfig) -> AutoscaleRunner<'a> {
        self.config = config;
        self
    }

    pub fn with_solve_cache_file(mut self, path: Option<PathBuf>) -> AutoscaleRunner<'a> {
        self.solve_cache_file = path;
        self
    }

    /// Run every requested policy over the trace (the comparison
    /// harness behind `camcloud trace --policy all`).
    pub fn compare(
        &self,
        trace: &WorkloadTrace,
        policies: &[ScalePolicy],
    ) -> Vec<(ScalePolicy, Result<AutoscaleOutcome>)> {
        policies
            .iter()
            .map(|&p| (p, self.run(trace, p)))
            .collect()
    }

    /// Run one policy over the trace through the staged epoch pipeline.
    pub fn run(&self, trace: &WorkloadTrace, policy: ScalePolicy) -> Result<AutoscaleOutcome> {
        if trace.epochs.is_empty() {
            return Err(anyhow!("trace {:?} has no epochs", trace.name));
        }
        let strategy = self.config.strategy;
        // Resolve profiles once per epoch up front (stage-0 of the
        // static pipeline; shared by every stage).
        let profiled: Vec<ProfiledWorkload> = (0..trace.epochs.len())
            .map(|i| self.coordinator.profile_workload(trace.workload(i)))
            .collect();
        // The static policies need every epoch's fresh optimal plan up
        // front (peak/mean selection).  Oracle and reactive solve per
        // epoch inside the plan stage, overlapped by the executor.
        let (static_plan, fresh) = match policy {
            ScalePolicy::StaticPeak | ScalePolicy::StaticMean => {
                let mut fresh = Vec::with_capacity(trace.epochs.len());
                for (i, epoch) in trace.epochs.iter().enumerate() {
                    let plan = profiled[i]
                        .allocate(strategy)
                        .with_context(|| format!("epoch {:?} not allocatable", epoch.label))?;
                    fresh.push(plan);
                }
                let held = match policy {
                    ScalePolicy::StaticPeak => pick_peak(&fresh),
                    _ => pick_mean(trace, &fresh),
                };
                (Some(held), fresh)
            }
            _ => (None, Vec::new()),
        };

        let stage = PlanStage {
            policy,
            config: &self.config,
            trace,
            profiled: &profiled,
            static_plan,
            fresh,
            // Only the reactive policy re-solves the same problems
            // across epochs; static/oracle pre-solve exactly once each.
            cache: (policy == ScalePolicy::Reactive && self.config.solve_cache)
                .then(|| Mutex::new(SolveCache::new(32))),
        };
        if let (Some(path), Some(cache)) = (&self.solve_cache_file, &stage.cache) {
            load_cache_file(cache, path);
        }
        let mut driver = EpochDriver {
            trace,
            profiled: &profiled,
            actuate: ActuateStage {
                policy,
                config: &self.config,
                total_s: trace.total_duration_s(),
                now: 0.0,
                state: FleetState::new(strategy),
                peak_fleet: 0,
                reallocations: 0,
                oracle_billed: 0.0,
                warm_streak: 0,
                cold_gap: None,
            },
            simulate: SimulateStage { sim: self.config.sim },
            bill: BillStage { epochs: Vec::with_capacity(trace.epochs.len()) },
        };
        let initial = driver.actuate.seed(driver.actuate.state.plan.clone());
        PipelineExecutor { pipeline: self.config.sim.parallelism.pipeline }.execute(
            trace.epochs.len(),
            initial,
            |i: usize, seed: &PlanSeed| stage.plan(i, seed),
            &mut driver,
        )?;
        if let (Some(path), Some(cache)) = (&self.solve_cache_file, &stage.cache) {
            save_cache_file(cache, path);
        }

        let total_billed = if policy == ScalePolicy::Oracle {
            Dollars::from_f64(driver.actuate.oracle_billed)
        } else {
            driver.actuate.state.settle(driver.actuate.total_s)
        };
        Ok(finish_outcome(
            policy,
            trace,
            strategy,
            driver.bill.epochs,
            total_billed,
            driver.actuate.peak_fleet,
            driver.actuate.reallocations,
        ))
    }
}

/// Load a `--solve-cache-file` into `cache`.  Every failure mode —
/// missing file, bad JSON, stale format — warns and continues with
/// whatever was loadable (usually nothing): the file is a wall-clock
/// optimization, and replay validation already guards correctness.
fn load_cache_file(cache: &Mutex<SolveCache>, path: &Path) {
    let text = match std::fs::read_to_string(path) {
        Ok(text) => text,
        // First run: the file does not exist yet and will be written
        // when the trace finishes.
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return,
        Err(e) => {
            eprintln!("warning: cannot read solve-cache file {}: {e}", path.display());
            return;
        }
    };
    let parsed = match Json::parse(&text) {
        Ok(parsed) => parsed,
        Err(e) => {
            eprintln!("warning: solve-cache file {} is not valid JSON: {e}", path.display());
            return;
        }
    };
    let mut cache = cache.lock().expect("solve cache lock poisoned");
    if let Err(e) = cache.load_json(&parsed) {
        eprintln!("warning: ignoring solve-cache file {}: {e:#}", path.display());
    }
}

/// Save `cache` back to the `--solve-cache-file` (MRU-first, so a
/// later load into a smaller cache keeps the most useful entries).
fn save_cache_file(cache: &Mutex<SolveCache>, path: &Path) {
    let cache = cache.lock().expect("solve cache lock poisoned");
    let text = format!("{}\n", cache.to_json().to_compact());
    if let Err(e) = std::fs::write(path, text) {
        eprintln!("warning: cannot write solve-cache file {}: {e}", path.display());
    }
}

/// The costliest per-epoch plan — "provision for the peak".
fn pick_peak(fresh: &[AllocationPlan]) -> AllocationPlan {
    fresh
        .iter()
        .max_by(|a, b| a.hourly_cost.cmp(&b.hourly_cost))
        .expect("non-empty trace")
        .clone()
}

/// The per-epoch plan closest to the duration-weighted mean hourly
/// cost — "provision for typical demand".
fn pick_mean(trace: &WorkloadTrace, fresh: &[AllocationPlan]) -> AllocationPlan {
    let total: f64 = trace.total_duration_s();
    let mean: f64 = trace
        .epochs
        .iter()
        .zip(fresh)
        .map(|(e, p)| p.hourly_cost.as_f64() * e.duration_s)
        .sum::<f64>()
        / total;
    fresh
        .iter()
        .min_by(|a, b| {
            (a.hourly_cost.as_f64() - mean)
                .abs()
                .total_cmp(&(b.hourly_cost.as_f64() - mean).abs())
        })
        .expect("non-empty trace")
        .clone()
}

fn finish_outcome(
    policy: ScalePolicy,
    trace: &WorkloadTrace,
    strategy: Strategy,
    epochs: Vec<EpochOutcome>,
    total_billed: Dollars,
    peak_fleet: usize,
    reallocations: usize,
) -> AutoscaleOutcome {
    let total_s = trace.total_duration_s();
    let mean_performance = if total_s > 0.0 {
        epochs
            .iter()
            .map(|e| e.performance * e.duration_s)
            .sum::<f64>()
            / total_s
    } else {
        1.0
    };
    AutoscaleOutcome {
        policy,
        trace_name: trace.name.clone(),
        strategy,
        epochs,
        total_billed,
        peak_fleet,
        mean_performance,
        reallocations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::streams::StreamSpec;
    use crate::types::{Program, VGA};
    use crate::workload::trace::WorkloadTrace;

    #[test]
    fn reactive_tracks_the_demand_curve() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(7);
        let out = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert_eq!(out.epochs.len(), 3);
        // Normal: one c4.2xlarge; emergency: two g2.2xlarge; recovery:
        // back to one c4.2xlarge.
        assert_eq!(out.epochs[0].fleet_size, 1);
        assert_eq!(out.epochs[1].fleet_size, 2);
        assert_eq!(out.epochs[2].fleet_size, 1);
        assert_eq!(out.epochs[0].hourly_rate, Dollars::from_f64(0.419));
        assert_eq!(out.epochs[1].hourly_rate, Dollars::from_f64(1.300));
        assert_eq!(out.epochs[2].hourly_rate, Dollars::from_f64(0.419));
        assert!(out.epochs[1].reallocated && out.epochs[2].reallocated);
        assert_eq!(out.reallocations, 2);
        // c4 billed 2 started hours, 2 g2 for 1 hour, c4 again 2 hours.
        assert_eq!(out.total_billed, Dollars::from_f64(2.976));
        assert!(out.mean_performance >= 0.9, "perf {}", out.mean_performance);
        assert_eq!(out.peak_fleet, 2);
        // Epoch 0 is by definition a cold solve.
        assert_eq!(out.epochs[0].mode, SolveMode::Cold);
    }

    #[test]
    fn static_peak_holds_the_burst_fleet() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(7);
        let out = runner.run(&trace, ScalePolicy::StaticPeak).unwrap();
        // Two g2.2xlarge held for the whole 4 h trace.
        assert!(out.epochs.iter().all(|e| e.fleet_size == 2));
        assert_eq!(out.reallocations, 0);
        assert_eq!(out.total_billed, Dollars::from_f64(5.200));
        assert!(out.mean_performance >= 0.9);
    }

    #[test]
    fn static_mean_is_cheap_but_fails_the_burst() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(7);
        let out = runner.run(&trace, ScalePolicy::StaticMean).unwrap();
        // One c4.2xlarge held throughout: cheapest fleet...
        assert_eq!(out.total_billed, Dollars::from_f64(1.676));
        assert_eq!(out.reallocations, 0);
        // ...but ZF at ~1 FPS has no sustainable device on it, so the
        // emergency epoch collapses.
        assert_eq!(out.epochs[1].unserved, 10);
        assert!(out.epochs[1].performance < 0.1);
        assert!(out.mean_performance < 0.9);
    }

    #[test]
    fn oracle_is_a_lower_bound_and_fractional() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(7);
        let oracle = runner.run(&trace, ScalePolicy::Oracle).unwrap();
        // 0.419 * 1.5h + 1.30 * 1h + 0.419 * 1.5h = 2.557.
        assert_eq!(oracle.total_billed, Dollars::from_f64(2.557));
        // The bound applies to policies that *serve* every epoch; an
        // under-provisioned static-mean fleet escapes it by dropping the
        // burst on the floor (its performance shows it).
        for policy in [ScalePolicy::Reactive, ScalePolicy::StaticPeak] {
            let out = runner.run(&trace, policy).unwrap();
            assert!(
                out.total_billed >= oracle.total_billed,
                "{policy}: {} < oracle {}",
                out.total_billed,
                oracle.total_billed
            );
            assert!(out.mean_performance >= 0.9, "{policy} must actually serve");
        }
        let mean = runner.run(&trace, ScalePolicy::StaticMean).unwrap();
        assert!(mean.total_billed < oracle.total_billed);
        assert!(mean.mean_performance < 0.9);
    }

    #[test]
    fn hysteresis_keeps_fleet_when_churn_beats_savings() {
        // Two epochs: a burst, then a 90-second wind-down.  Scaling
        // down for the last sliver wastes more than it saves, so the
        // reactive policy keeps the GPU fleet and serves normal ops on
        // it via repack.
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let burst = StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0);
        let quiet = StreamSpec::replicate(0, 3, VGA, Program::Zf, 0.2);
        let trace = WorkloadTrace::new("winddown", Catalog::paper_experiments())
            .epoch("burst", 3000.0, burst)
            .epoch("tail", 90.0, quiet);
        let out = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert!(!out.epochs[1].reallocated, "tail must not churn");
        assert_eq!(out.reallocations, 0);
        assert_eq!(out.epochs[1].fleet_size, 2);
        // Kept fleet still serves the quiet epoch at full performance.
        assert!(out.epochs[1].performance >= 0.9);
        // One billed hour for each g2: churning would have added a c4
        // hour on top.
        assert_eq!(out.total_billed, Dollars::from_f64(1.300));
    }

    #[test]
    fn reactive_epochs_report_warm_start_provenance() {
        // Stable stream ids under a CPU-only strategy (tight certified
        // bound): epoch 0 solves cold, epoch 1 must be served by the
        // warm-start incremental repack, and every solved epoch carries
        // a finite certified gap.
        let c = Coordinator::new();
        let config = AutoscaleConfig {
            strategy: Strategy::St1,
            ..AutoscaleConfig::default()
        };
        let runner = AutoscaleRunner::new(&c).with_config(config);
        let base = StreamSpec::replicate(0, 4, VGA, Program::Zf, 0.5);
        let mut grown = base.clone();
        grown.extend(StreamSpec::replicate(100, 2, VGA, Program::Zf, 0.5));
        let trace = WorkloadTrace::new("grow", Catalog::paper_experiments())
            .epoch("base", 3600.0, base)
            .epoch("grow", 3600.0, grown);
        let out = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert_eq!(out.epochs[0].solver, SolverKind::Exact);
        assert_eq!(out.epochs[0].mode, SolveMode::Cold);
        assert_eq!(out.epochs[1].solver, SolverKind::WarmStart);
        assert_eq!(out.epochs[1].mode, SolveMode::Warm);
        for e in &out.epochs {
            let gap = e.gap.expect("solved epochs carry a certified gap");
            assert!(gap.is_finite() && (0.0..=1.0).contains(&gap), "{gap}");
        }
    }

    #[test]
    fn kept_epochs_reuse_the_warm_plan_for_the_feasibility_probe() {
        // Steady demand with stable stream ids: from epoch 1 on the
        // warm target fits the incumbent exactly, so the hysteresis
        // probe reuses it — the kept epoch is served by the WarmStart
        // plan (full-catalog certificate retained) instead of an extra
        // repack_onto restricted solve.
        let c = Coordinator::new();
        let config = AutoscaleConfig { strategy: Strategy::St1, ..AutoscaleConfig::default() };
        let runner = AutoscaleRunner::new(&c).with_config(config);
        let base = StreamSpec::replicate(0, 4, VGA, Program::Zf, 0.5);
        let trace = WorkloadTrace::new("steady", Catalog::paper_experiments())
            .epoch("e0", 1800.0, base.clone())
            .epoch("e1", 1800.0, base.clone())
            .epoch("e2", 1800.0, base);
        let out = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        for e in &out.epochs[1..] {
            assert!(!e.reallocated, "steady epochs must keep the fleet");
            assert_eq!(e.solver, SolverKind::WarmStart, "epoch {}", e.label);
            assert_eq!(e.mode, SolveMode::Warm);
            assert!(e.gap.is_some(), "warm serving plans keep their certificate");
        }
        assert_eq!(out.reallocations, 0);
    }

    #[test]
    fn cold_refresh_recurs_every_k_warm_epochs() {
        // Six identical epochs with cold_refresh_every = 2.  The
        // workload is the tight CPU instance whose warm repack
        // certifies gap 0, so with the default `refresh_skip_gap` the
        // periodic refresh keeps the warm plan (its certificate proves
        // a cold solve could recoup nothing); disabling the skip gate
        // restores the classic warm/warm/refresh cycle.
        let c = Coordinator::new();
        let base = StreamSpec::replicate(0, 4, VGA, Program::Zf, 0.5);
        let mut trace = WorkloadTrace::new("refresh", Catalog::paper_experiments());
        for i in 0..6 {
            trace = trace.epoch(format!("e{i}"), 1800.0, base.clone());
        }

        let config = AutoscaleConfig {
            strategy: Strategy::St1,
            cold_refresh_every: 2,
            ..AutoscaleConfig::default()
        };
        let skipping = AutoscaleRunner::new(&c)
            .with_config(config)
            .run(&trace, ScalePolicy::Reactive)
            .unwrap();
        let modes: Vec<SolveMode> = skipping.epochs.iter().map(|e| e.mode).collect();
        assert_eq!(
            modes,
            vec![
                SolveMode::Cold,
                SolveMode::Warm,
                SolveMode::Warm,
                SolveMode::Warm,
                SolveMode::Warm,
                SolveMode::Warm,
            ],
            "gap-0 certificates skip every periodic refresh"
        );

        let strict = AutoscaleConfig {
            strategy: Strategy::St1,
            cold_refresh_every: 2,
            // A negative threshold no certificate can meet: every
            // refresh epoch must pay for the cold solve again.
            refresh_skip_gap: -1.0,
            ..AutoscaleConfig::default()
        };
        let out = AutoscaleRunner::new(&c)
            .with_config(strict)
            .run(&trace, ScalePolicy::Reactive)
            .unwrap();
        let modes: Vec<SolveMode> = out.epochs.iter().map(|e| e.mode).collect();
        assert_eq!(
            modes,
            vec![
                SolveMode::Cold,
                SolveMode::Warm,
                SolveMode::Warm,
                SolveMode::ColdRefresh,
                SolveMode::Warm,
                SolveMode::Warm,
            ]
        );
        // The refresh epoch re-solves cold (exact at this scale) but
        // the fleet itself never churns.
        assert_eq!(out.epochs[3].solver, SolverKind::Exact);
        assert!(out.epochs.iter().skip(1).all(|e| !e.reallocated));
        // Cost is flat either way: refreshes change provenance, not the
        // fleet.
        for run in [&skipping, &out] {
            assert!(run.epochs.iter().skip(1).all(|e| !e.reallocated));
            assert!(run
                .epochs
                .iter()
                .all(|e| e.hourly_rate == run.epochs[0].hourly_rate));
        }
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::emergency_burst(13);
        let a = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        let b = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert_eq!(a.total_billed, b.total_billed);
        assert_eq!(a.mean_performance, b.mean_performance);
        assert_eq!(a.reallocations, b.reallocations);
    }

    #[test]
    fn empty_trace_is_an_error() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::new("empty", Catalog::paper_experiments());
        assert!(runner.run(&trace, ScalePolicy::Reactive).is_err());
    }

    #[test]
    fn policy_parse_round_trip() {
        for p in ScalePolicy::ALL {
            assert_eq!(p.to_string().parse::<ScalePolicy>().unwrap(), p);
        }
        assert_eq!("peak".parse::<ScalePolicy>().unwrap(), ScalePolicy::StaticPeak);
        assert_eq!("mean".parse::<ScalePolicy>().unwrap(), ScalePolicy::StaticMean);
        assert!("elastic".parse::<ScalePolicy>().is_err());
    }

    #[test]
    fn solve_mode_display_names() {
        assert_eq!(SolveMode::Warm.to_string(), "warm");
        assert_eq!(SolveMode::Cold.to_string(), "cold");
        assert_eq!(SolveMode::ColdRefresh.to_string(), "refresh");
    }

    #[test]
    fn spot_revocations_repack_and_recover() {
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let trace = WorkloadTrace::spot_market(7);
        let out = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert_eq!(out.epochs.len(), 6);
        // The tiered catalog's cheapest offerings are spot, so the
        // scheduled reclaims find victims and force mid-epoch repacks.
        assert!(out.epochs[1].revoked > 0, "epoch 1 reclaim must fire");
        assert!(out.epochs[3].revoked > 0, "epoch 3 reclaim must fire");
        for i in [0usize, 2, 4, 5] {
            assert_eq!(out.epochs[i].revoked, 0, "epoch {i} has no reclaim");
        }
        // Orphaned streams are re-placed: every epoch still serves its
        // full demand.
        assert!(out.epochs.iter().all(|e| e.unserved == 0));
        assert!(out.mean_performance >= 0.9, "perf {}", out.mean_performance);
        // Emergency repacks count as reallocations.
        assert!(out.reallocations >= 2);
        assert!(out.total_billed > Dollars::ZERO);
        // Seed-determinism: same trace, same numbers.
        let again = runner.run(&trace, ScalePolicy::Reactive).unwrap();
        assert_eq!(out.total_billed, again.total_billed);
        assert_eq!(
            out.epochs.iter().map(|e| e.revoked).collect::<Vec<_>>(),
            again.epochs.iter().map(|e| e.revoked).collect::<Vec<_>>()
        );
    }

    #[test]
    fn revoked_spot_fleet_bills_less_than_on_demand() {
        // Same demand and the same reclaim schedule, two catalogs:
        // tiered (spot discount, revocations bite) vs the flat
        // single-price catalog (all on-demand, reclaims find no
        // victims).  Even paying for revocation churn, the spot fleet
        // is cheaper end to end.
        let c = Coordinator::new();
        let runner = AutoscaleRunner::new(&c);
        let spot = runner
            .run(&WorkloadTrace::spot_market(7), ScalePolicy::Reactive)
            .unwrap();
        let mut flat = WorkloadTrace::spot_market(7);
        flat.catalog = Catalog::paper_experiments();
        let ondemand = runner.run(&flat, ScalePolicy::Reactive).unwrap();
        // On-demand instances are never revoked.
        assert!(ondemand.epochs.iter().all(|e| e.revoked == 0));
        assert!(
            spot.total_billed < ondemand.total_billed,
            "spot {} must undercut on-demand {}",
            spot.total_billed,
            ondemand.total_billed
        );
    }

    #[test]
    fn fits_within_compares_per_type_counts() {
        let c = Coordinator::new();
        let mgr = crate::manager::ResourceManager::new(Catalog::paper_experiments(), &c);
        let small = mgr
            .allocate(&StreamSpec::replicate(0, 3, VGA, Program::Zf, 0.2), Strategy::St3)
            .unwrap();
        let big = mgr
            .allocate(&StreamSpec::replicate(0, 10, VGA, Program::Zf, 1.0), Strategy::St3)
            .unwrap();
        assert!(fits_within(&small, &small));
        assert!(!fits_within(&big, &small), "GPU fleet cannot fit in one CPU instance");
        // An empty plan fits any non-empty fleet; nothing fits an empty
        // fleet except another empty plan.
        let empty = FleetState::new(Strategy::St3).plan;
        assert!(fits_within(&empty, &small));
        assert!(!fits_within(&small, &empty));
        assert!(fits_within(&empty, &empty));
    }
}
