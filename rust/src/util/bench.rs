//! Benchmark measurement harness (criterion substitute).
//!
//! `rust/benches/*.rs` are `harness = false` binaries that use this
//! module: warmup, timed samples, and a mean / p50 / p95 report in both
//! human and JSON-lines form (`target/bench-results.jsonl`) so the
//! EXPERIMENTS.md tables can be regenerated mechanically.

use crate::util::json::Json;
use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    fn percentile(&self, p: f64) -> f64 {
        let mut sorted = self.samples.clone();
        // total_cmp sorts NaN samples to the end instead of panicking —
        // a wild measurement must not abort a whole bench suite.
        sorted.sort_by(f64::total_cmp);
        let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
        sorted[idx]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Peak resident-set size of this process in bytes (Linux `VmHWM`
/// high-water mark; `None` where `/proc` is unavailable).  The memory
/// gates in `benches/solver_scaling.rs` use it to fail a bench run
/// whose solve exceeds its RSS budget.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Reset the peak-RSS high-water mark to the *current* resident size
/// (Linux `/proc/self/clear_refs`, code 5), so a subsequent
/// [`peak_rss_bytes`] reflects only the work since the reset instead of
/// the process-lifetime maximum.  Returns `false` where unsupported —
/// callers should then treat the reading as cumulative.
pub fn reset_peak_rss() -> bool {
    std::fs::write("/proc/self/clear_refs", "5").is_ok()
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner for one `bench` binary.
pub struct Bench {
    suite: String,
    results: Vec<Measurement>,
    /// Extra key/value rows to include in the JSON record (workload
    /// parameters, derived metrics).
    extra: Vec<(String, Json)>,
}

impl Bench {
    pub fn new(suite: &str) -> Bench {
        println!("== bench suite: {suite} ==");
        Bench { suite: suite.to_string(), results: Vec::new(), extra: Vec::new() }
    }

    /// Measure `f` for `samples` timed runs after `warmup` untimed runs.
    pub fn measure<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        samples: usize,
        mut f: F,
    ) -> &Measurement {
        for _ in 0..warmup {
            f();
        }
        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement { name: name.to_string(), samples: times };
        println!(
            "{:<48} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
            m.name,
            fmt_secs(m.mean()),
            fmt_secs(m.p50()),
            fmt_secs(m.p95()),
            m.samples.len()
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record a derived scalar (e.g. "speedup", "savings_pct") for the
    /// JSON record and print it.
    pub fn record(&mut self, key: &str, value: f64) {
        println!("{key:<48} {value:.4}");
        self.extra.push((key.to_string(), Json::Num(value)));
    }

    /// Record a free-form note / table row.
    pub fn note(&mut self, key: &str, value: &str) {
        println!("{key:<48} {value}");
        self.extra.push((key.to_string(), Json::Str(value.to_string())));
    }

    /// Append the suite record to `target/bench-results.jsonl`.
    pub fn finish(self) {
        let mut obj = vec![("suite".to_string(), Json::Str(self.suite.clone()))];
        let measurements: Vec<Json> = self
            .results
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name".to_string(), Json::Str(m.name.clone())),
                    ("mean_s".to_string(), Json::Num(m.mean())),
                    ("p50_s".to_string(), Json::Num(m.p50())),
                    ("p95_s".to_string(), Json::Num(m.p95())),
                    ("min_s".to_string(), Json::Num(m.min())),
                    ("n".to_string(), Json::Num(m.samples.len() as f64)),
                ])
            })
            .collect();
        obj.push(("measurements".to_string(), Json::Arr(measurements)));
        obj.extend(self.extra);
        let record = Json::obj(obj).to_compact();
        let path = std::path::Path::new("target/bench-results.jsonl");
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
            let _ = writeln!(f, "{record}");
        }
        println!("== suite {} done ==\n", self.suite);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_stats() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0, 100.0],
        };
        assert_eq!(m.mean(), 22.0);
        assert_eq!(m.p50(), 3.0);
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.p95(), 100.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: partial_cmp().unwrap() panicked on NaN samples;
        // total_cmp sorts them after every finite value instead.
        let m = Measurement {
            name: "t".into(),
            samples: vec![2.0, f64::NAN, 1.0],
        };
        assert_eq!(m.p50(), 2.0);
        assert!(m.p95().is_nan());
    }

    #[test]
    fn peak_rss_reads_proc_when_available() {
        // On Linux the high-water mark exists and is nonzero; elsewhere
        // the probe degrades to None instead of failing.
        if let Some(rss) = peak_rss_bytes() {
            assert!(rss > 0);
            // Resetting (where supported) re-bases to current RSS; the
            // reading stays sane either way.
            let _ = reset_peak_rss();
            assert!(peak_rss_bytes().unwrap() > 0);
        }
    }

    #[test]
    fn fmt_secs_scales() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
        assert_eq!(fmt_secs(2.5e-9), "2.5 ns");
    }

    #[test]
    fn measure_runs_and_reports() {
        let mut b = Bench::new("selftest");
        let mut count = 0;
        b.measure("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].samples.len(), 5);
    }
}
