//! In-tree utility substrates.
//!
//! The build environment is offline (the optional `xla` crate behind
//! the `pjrt` feature is the single external dependency), so the small
//! libraries a crate like this would normally pull from crates.io are
//! implemented here instead (DESIGN.md §Substitutions):
//!
//! * [`error`] — message-chain error type + macros (`anyhow` substitute);
//! * [`json`] — JSON parser/serializer (manifest, profiles, reports);
//! * [`rng`] — SplitMix64/xoshiro PRNG (workload generators);
//! * [`cli`] — argument parsing for the `camcloud` binary;
//! * [`bench`] — measurement harness used by `rust/benches/*`
//!   (criterion-style warmup + timed samples + percentile report);
//! * [`proptest`] — seeded randomized property-testing harness;
//! * [`profiling`] — per-phase wall-clock registry behind the
//!   off-by-default `profiling` feature (zero-cost pass-through
//!   otherwise).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod profiling;
pub mod proptest;
pub mod rng;
