//! Minimal JSON: full RFC 8259 parser + compact/pretty serializer.
//!
//! Replaces `serde_json` in this offline build.  Used for the artifact
//! manifest (`meta.json`), golden outputs, profile persistence, scenario
//! configs, and machine-readable reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order for deterministic output.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Json {
    // ----- constructors -------------------------------------------------

    pub fn obj(pairs: impl IntoIterator<Item = (String, Json)>) -> Json {
        Json::Obj(pairs.into_iter().collect())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    // ----- accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest-style field access.
    pub fn field(&self, key: &str) -> crate::util::error::Result<&Json> {
        self.get(key)
            .ok_or_else(|| crate::anyhow!("missing field {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed field accessors used by manifest/config loaders.
    pub fn f64_field(&self, key: &str) -> crate::util::error::Result<f64> {
        self.field(key)?
            .as_f64()
            .ok_or_else(|| crate::anyhow!("field {key:?} is not a number"))
    }

    pub fn u64_field(&self, key: &str) -> crate::util::error::Result<u64> {
        self.field(key)?
            .as_u64()
            .ok_or_else(|| crate::anyhow!("field {key:?} is not a non-negative integer"))
    }

    pub fn str_field(&self, key: &str) -> crate::util::error::Result<&str> {
        self.field(key)?
            .as_str()
            .ok_or_else(|| crate::anyhow!("field {key:?} is not a string"))
    }

    pub fn arr_field(&self, key: &str) -> crate::util::error::Result<&[Json]> {
        self.field(key)?
            .as_arr()
            .ok_or_else(|| crate::anyhow!("field {key:?} is not an array"))
    }

    // ----- parsing ------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialization --------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty, 2-space-indented serialization with trailing newline.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", n as i64));
    } else {
        let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> ParseError {
        ParseError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(byte) if byte < 0x20 => return Err(self.err("control char in string")),
                Some(byte) => {
                    // Reassemble UTF-8 multibyte sequences.
                    if byte < 0x80 {
                        out.push(byte as char);
                    } else {
                        let len = match byte {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Convenience: `json_obj! { "a" => Json::Num(1.0), ... }`.
#[macro_export]
macro_rules! json_obj {
    ($($key:expr => $value:expr),* $(,)?) => {
        $crate::util::json::Json::obj(vec![
            $(($key.to_string(), $value)),*
        ])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""line\nquote\" tab\t ué pair😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "line\nquote\" tab\t ué pair😀");
        // Raw UTF-8 passthrough.
        let raw = Json::parse("\"naïve — テスト\"").unwrap();
        assert_eq!(raw.as_str().unwrap(), "naïve — テスト");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "tru", "{\"a\" 1}", "\"unterminated", "1 2", "{\"a\":}"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"vgg16","sizes":[1,2.5,-3],"ok":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\n  \"name\": \"vgg16\""));
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        assert_eq!(Json::Num(124478464.0).to_compact(), "124478464");
        assert_eq!(Json::Num(0.5).to_compact(), "0.5");
    }

    #[test]
    fn typed_field_accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1], "f": 0.5}"#).unwrap();
        assert_eq!(v.u64_field("n").unwrap(), 3);
        assert_eq!(v.str_field("s").unwrap(), "x");
        assert_eq!(v.arr_field("a").unwrap().len(), 1);
        assert!((v.f64_field("f").unwrap() - 0.5).abs() < 1e-12);
        assert!(v.u64_field("missing").is_err());
        assert!(v.u64_field("s").is_err());
        assert!(v.u64_field("f").is_err()); // 0.5 is not an integer
    }

    #[test]
    fn macro_builds_objects() {
        let v = json_obj! { "a" => Json::Num(1.0), "b" => Json::Str("x".into()) };
        assert_eq!(v.u64_field("a").unwrap(), 1);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = crate::runtime::default_artifacts_dir();
        if let Ok(text) = std::fs::read_to_string(dir.join("meta.json")) {
            let v = Json::parse(&text).unwrap();
            assert_eq!(v.arr_field("models").unwrap().len(), 6);
        }
    }
}
