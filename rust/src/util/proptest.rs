//! Seeded randomized property-testing harness (proptest substitute).
//!
//! Runs a property over many generated cases; on failure it reports the
//! seed and case index so the exact case replays deterministically, and
//! performs greedy input shrinking when the generator supports it.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // CAMCLOUD_PROPTEST_CASES / _SEED override for soak runs.
        let cases = std::env::var("CAMCLOUD_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("CAMCLOUD_PROPTEST_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases, seed }
    }
}

/// Run `property` over `cases` inputs from `generate`.
///
/// `property` returns `Err(reason)` to fail.  Panics with seed/case info
/// on failure so CI logs are actionable.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    config: Config,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let mut rng = Rng::new(config.seed.wrapping_add(case as u64 * 0x9E3779B9));
        let input = generate(&mut rng);
        if let Err(reason) = property(&input) {
            panic!(
                "property {name:?} failed at case {case} (seed {}): {reason}\ninput: {input:#?}",
                config.seed
            );
        }
    }
}

/// Like [`check`], but with greedy shrinking: `shrink` proposes smaller
/// variants of a failing input; the smallest still-failing input is
/// reported.
pub fn check_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    config: Config,
    mut generate: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let mut rng = Rng::new(config.seed.wrapping_add(case as u64 * 0x9E3779B9));
        let input = generate(&mut rng);
        if let Err(first_reason) = property(&input) {
            // Greedy shrink loop.
            let mut smallest = input.clone();
            let mut reason = first_reason;
            'outer: loop {
                for candidate in shrink(&smallest) {
                    if let Err(r) = property(&candidate) {
                        smallest = candidate;
                        reason = r;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed at case {case} (seed {}): {reason}\n\
                 shrunk input: {smallest:#?}",
                config.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-commutes",
            Config { cases: 32, seed: 1 },
            |rng| (rng.below(100), rng.below(100)),
            |(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            Config { cases: 4, seed: 2 },
            |rng| rng.below(10),
            |_| Err("always fails".into()),
        );
    }

    #[test]
    #[should_panic(expected = "shrunk input: 10")]
    fn shrinking_finds_minimal_failure() {
        // Property: value must be < 10. Generator produces 0..100; the
        // shrinker decrements; minimal failing input is exactly 10.
        check_shrink(
            "lt-ten",
            Config { cases: 50, seed: 3 },
            |rng| rng.below(100),
            |&v| if v > 0 { vec![v - 1] } else { vec![] },
            |&v| if v < 10 { Ok(()) } else { Err(format!("{v} >= 10")) },
        );
    }
}
