//! Per-phase wall-clock profiling, compiled out by default.
//!
//! Built behind the off-by-default `profiling` Cargo feature so the hot
//! paths carry zero instrumentation cost in normal builds:
//! [`time_phase`] is a plain pass-through closure call unless the
//! feature is on, in which case every call records its duration into a
//! global registry keyed by a `&'static str` label (labels are static
//! so the *disabled* path never formats or allocates either).
//!
//! Instrumented phases:
//!
//! * the autoscale epoch loop — `epoch:solve`, `epoch:actuate`,
//!   `epoch:simulate`, `epoch:bill` (`coordinator::autoscale`);
//! * billing actuation — `billing:actuate` around each fleet
//!   transition applied to the meter, at epoch boundaries and inside
//!   mid-epoch spot-revocation repacks (`coordinator::autoscale`);
//! * the warm-start repack delta — `warm:repack-delta` around the
//!   incremental re-pack of orphaned/new streams against the kept
//!   fleet (`manager`);
//! * the portfolio arms — `arm:ff-*` / `arm:bf-*` per (greedy,
//!   ordering) pair, `arm:*-shard` on the sharded path, and
//!   `arm:exact-polish` (`packing::solver`);
//! * the distributed coordinator — `net:serialize` around encoding a
//!   shipped shard or task batch, `net:rpc` around each worker
//!   round trip, and `net:merge` around decoding + folding a worker's
//!   reply (`net::fleet`, `packing::exact`, `sched::shard`);
//! * event counters (via [`bump`], the `calls` column is the count) —
//!   `exact:seed-dropped` when the exact search discards an invalid
//!   incumbent (`packing::exact`), the solve cache's `cache:hit` /
//!   `cache:miss` / `cache:reject` (`manager::solve_cache`), and the
//!   fleet's per-cause failure counters (`net::fleet`):
//!   `net:rpc:connect` / `net:rpc:timeout` / `net:rpc:disconnect` per
//!   transient RPC failure by cause, `net:rpc:garbage` per worker
//!   quarantined for a protocol violation, `net:rpc:retried` per RPC
//!   that succeeded only after retries, `net:rpc:hedged` per straggler
//!   claim re-dispatched locally, and `net:fleet:readmitted` per
//!   circuit-breaker re-admission of a recovered worker.
//!
//! The `camcloud trace --profile` flag prints the table via
//! [`report`]; in a build without the feature it prints a rebuild hint
//! instead (see [`COMPILED`]).

/// Whether profiling support is compiled into this binary.
pub const COMPILED: bool = cfg!(feature = "profiling");

/// Aggregated timings for one phase label.
#[derive(Clone, Debug)]
pub struct PhaseStat {
    pub label: &'static str,
    pub calls: u64,
    pub total: std::time::Duration,
    pub max: std::time::Duration,
}

#[cfg(feature = "profiling")]
mod registry {
    use super::PhaseStat;
    use std::collections::BTreeMap;
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    struct Totals {
        calls: u64,
        total: Duration,
        max: Duration,
    }

    static REGISTRY: Mutex<BTreeMap<&'static str, Totals>> = Mutex::new(BTreeMap::new());

    pub fn record<T>(label: &'static str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        let elapsed = start.elapsed();
        let mut registry = REGISTRY.lock().expect("profiling registry");
        let entry = registry
            .entry(label)
            .or_insert(Totals { calls: 0, total: Duration::ZERO, max: Duration::ZERO });
        entry.calls += 1;
        entry.total += elapsed;
        entry.max = entry.max.max(elapsed);
        out
    }

    pub fn snapshot() -> Vec<PhaseStat> {
        REGISTRY
            .lock()
            .expect("profiling registry")
            .iter()
            .map(|(&label, t)| PhaseStat { label, calls: t.calls, total: t.total, max: t.max })
            .collect()
    }

    pub fn reset() {
        REGISTRY.lock().expect("profiling registry").clear();
    }
}

/// Run `f`, attributing its wall-clock time to `label`.  A direct call
/// with no timing when the `profiling` feature is off.
#[inline]
pub fn time_phase<T>(label: &'static str, f: impl FnOnce() -> T) -> T {
    #[cfg(feature = "profiling")]
    {
        registry::record(label, f)
    }
    #[cfg(not(feature = "profiling"))]
    {
        let _ = label;
        f()
    }
}

/// Count one occurrence of `label`: a zero-duration [`time_phase`], so
/// the `calls` column doubles as an event counter.  Free (and
/// unrecorded) without the `profiling` feature.
#[inline]
pub fn bump(label: &'static str) {
    time_phase(label, || ());
}

/// Everything recorded so far, sorted by label.  Always empty without
/// the `profiling` feature.
pub fn snapshot() -> Vec<PhaseStat> {
    #[cfg(feature = "profiling")]
    {
        registry::snapshot()
    }
    #[cfg(not(feature = "profiling"))]
    {
        Vec::new()
    }
}

/// Clear the registry (benches and tests isolate measurements with
/// this).  No-op without the feature.
pub fn reset() {
    #[cfg(feature = "profiling")]
    registry::reset();
}

/// Render the phase table (label, calls, total, mean, max).  Returns
/// the rebuild hint when profiling is not compiled in, so callers can
/// print unconditionally.
pub fn report() -> String {
    if !COMPILED {
        return "profiling not compiled in; rebuild with `--features profiling`".to_string();
    }
    let stats = snapshot();
    if stats.is_empty() {
        return "no phases recorded".to_string();
    }
    let mut out = String::from(
        "phase                     calls      total         mean          max\n",
    );
    for s in &stats {
        let mean = s.total / (s.calls.max(1) as u32);
        out.push_str(&format!(
            "{:<24} {:>7} {:>10.3?} {:>12.3?} {:>12.3?}\n",
            s.label, s.calls, s.total, mean, s.max
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_phase_is_transparent() {
        // The closure's value passes through untouched with or without
        // the feature.
        let v = time_phase("test:transparent", || 41 + 1);
        assert_eq!(v, 42);
    }

    #[cfg(feature = "profiling")]
    #[test]
    fn registry_accumulates_calls() {
        reset();
        for _ in 0..3 {
            time_phase("test:accumulate", || std::hint::black_box(0u64));
        }
        let stats = snapshot();
        let stat = stats
            .iter()
            .find(|s| s.label == "test:accumulate")
            .expect("phase recorded");
        assert!(stat.calls >= 3);
        assert!(stat.max <= stat.total);
        assert!(!report().is_empty());
    }

    #[cfg(not(feature = "profiling"))]
    #[test]
    fn disabled_build_reports_the_rebuild_hint() {
        time_phase("test:disabled", || ());
        assert!(snapshot().is_empty());
        assert!(report().contains("--features profiling"));
    }
}
