//! Deterministic PRNG (SplitMix64 + xoshiro256**), replacing `rand`.
//!
//! Used by workload generators and the property-test harness.  Not
//! cryptographic; determinism and speed are what matter here.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    state: [u64; 4],
}

fn splitmix64(seed: &mut u64) -> u64 {
    *seed = seed.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *seed;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut s = seed;
        Rng {
            state: [
                splitmix64(&mut s),
                splitmix64(&mut s),
                splitmix64(&mut s),
                splitmix64(&mut s),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.state;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s2n = s2 ^ s0;
        let s3n = s3 ^ s1;
        let s1n = s1 ^ s2n;
        let s0n = s0 ^ s3n;
        s2n ^= t;
        self.state = [s0n, s1n, s2n, s3n.rotate_left(45)];
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`; panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection for unbiased sampling.
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let wide = (x as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return hi;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Pick a uniform element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1_000 {
            match r.range_u64(1, 3) {
                1 => saw_lo = true,
                3 => saw_hi = true,
                2 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(v, (0..20).collect::<Vec<_>>()); // astronomically unlikely
    }
}
