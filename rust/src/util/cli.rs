//! Tiny argument parser for the `camcloud` binary (clap substitute).
//!
//! Supports `subcommand --flag value --switch positional` grammars with
//! typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, `--key value` options, bare `--switch`
/// flags, and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positionals: Vec<String>,
}

/// Declared flags a command accepts (for validation + usage text).
#[derive(Clone, Debug)]
pub struct Spec {
    /// `(name, takes_value, help)`.
    pub flags: Vec<(&'static str, bool, &'static str)>,
}

impl Args {
    /// Parse raw args (without argv[0]).  The first non-flag token is the
    /// subcommand; later non-flag tokens are positionals.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty flag name".into());
                }
                if let Some((key, value)) = name.split_once('=') {
                    out.options.insert(key.to_string(), value.to_string());
                } else if iter.peek().map_or(false, |next| !next.starts_with("--")) {
                    out.options.insert(name.to_string(), iter.next().unwrap());
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn u32_opt(&self, key: &str) -> Result<Option<u32>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Parse `--key a,b,c` as a comma-separated list (entries trimmed,
    /// empty ones dropped) — the `--workers host:port,...` grammar.
    /// `None` when the flag is absent; a flag whose entries are all
    /// empty yields an empty vec for the caller to reject with its own
    /// message.
    pub fn list_opt(&self, key: &str) -> Option<Vec<String>> {
        self.opt(key).map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
    }

    /// Parse `--key on|off` (also accepts true/false, yes/no, 1/0) —
    /// the `--pipeline on|off` grammar.
    pub fn bool_opt(&self, key: &str) -> Result<Option<bool>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => match v.to_ascii_lowercase().as_str() {
                "on" | "true" | "yes" | "1" => Ok(Some(true)),
                "off" | "false" | "no" | "0" => Ok(Some(false)),
                other => Err(format!("--{key} expects on or off, got {other:?}")),
            },
        }
    }

    /// Parse `--key` as one value of `T`, expanding a missing flag or
    /// the literal `all` to the full `all` slice — the shared
    /// "`--strategy st3 | all`" / "`--policy reactive | all`" grammar
    /// every subcommand uses.
    pub fn one_or_all<T>(&self, key: &str, all: &[T]) -> Result<Vec<T>, String>
    where
        T: Clone + std::str::FromStr<Err = String>,
    {
        match self.opt(key) {
            None | Some("all") => Ok(all.to_vec()),
            Some(v) => v.parse::<T>().map(|t| vec![t]),
        }
    }

    /// Reject unknown flags against a spec (catches typos).
    pub fn validate(&self, spec: &Spec) -> Result<(), String> {
        for key in self.options.keys() {
            match spec.flags.iter().find(|(n, _, _)| n == key) {
                None => return Err(format!("unknown option --{key}")),
                Some((_, takes_value, _)) if !takes_value => {
                    return Err(format!("--{key} does not take a value"))
                }
                _ => {}
            }
        }
        for key in &self.switches {
            match spec.flags.iter().find(|(n, _, _)| n == key) {
                None => return Err(format!("unknown flag --{key}")),
                Some((_, takes_value, _)) if *takes_value => {
                    return Err(format!("--{key} requires a value"))
                }
                _ => {}
            }
        }
        Ok(())
    }
}

impl Spec {
    pub fn usage(&self, command: &str, summary: &str) -> String {
        let mut out = format!("{summary}\n\nUsage: camcloud {command} [options]\n\nOptions:\n");
        for (name, takes_value, help) in &self.flags {
            let arg = if *takes_value {
                format!("--{name} <value>")
            } else {
                format!("--{name}")
            };
            out.push_str(&format!("  {arg:<28} {help}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn parses_subcommand_options_switches() {
        // NB: flags greedily consume the next non-flag token as a value,
        // so positionals must precede bare switches.
        let a = parse("allocate --scenario 1 --strategy st3 extra --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("allocate"));
        assert_eq!(a.opt("scenario"), Some("1"));
        assert_eq!(a.opt("strategy"), Some("st3"));
        assert!(a.has("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --fps=2.5");
        assert_eq!(a.f64_opt("fps").unwrap(), Some(2.5));
    }

    #[test]
    fn typed_errors() {
        let a = parse("run --fps abc");
        assert!(a.f64_opt("fps").is_err());
        assert!(a.u32_opt("fps").is_err());
        assert_eq!(a.f64_opt("missing").unwrap(), None);
    }

    #[test]
    fn bool_opt_accepts_on_off_spellings() {
        assert_eq!(parse("x --pipeline on").bool_opt("pipeline").unwrap(), Some(true));
        assert_eq!(parse("x --pipeline off").bool_opt("pipeline").unwrap(), Some(false));
        assert_eq!(parse("x --pipeline TRUE").bool_opt("pipeline").unwrap(), Some(true));
        assert_eq!(parse("x --pipeline 0").bool_opt("pipeline").unwrap(), Some(false));
        assert_eq!(parse("x").bool_opt("pipeline").unwrap(), None);
        assert!(parse("x --pipeline maybe").bool_opt("pipeline").is_err());
    }

    #[test]
    fn list_opt_splits_commas_and_trims() {
        let a = parse("trace --workers 127.0.0.1:9001,127.0.0.1:9002");
        assert_eq!(
            a.list_opt("workers").unwrap(),
            vec!["127.0.0.1:9001".to_string(), "127.0.0.1:9002".to_string()]
        );
        assert_eq!(parse("trace").list_opt("workers"), None);
        assert_eq!(parse("trace --workers ,,").list_opt("workers").unwrap(), Vec::<String>::new());
    }

    #[test]
    fn trailing_switch_is_switch() {
        let a = parse("report --table2 --json");
        assert!(a.has("table2"));
        assert!(a.has("json"));
    }

    #[test]
    fn one_or_all_expands_missing_and_all() {
        #[derive(Clone, PartialEq, Debug)]
        struct Flag(u32);
        impl std::str::FromStr for Flag {
            type Err = String;
            fn from_str(s: &str) -> Result<Self, String> {
                s.parse::<u32>().map(Flag).map_err(|_| format!("bad flag {s:?}"))
            }
        }
        const ALL: [Flag; 2] = [Flag(1), Flag(2)];
        assert_eq!(parse("x").one_or_all("f", &ALL).unwrap(), ALL.to_vec());
        assert_eq!(parse("x --f all").one_or_all("f", &ALL).unwrap(), ALL.to_vec());
        assert_eq!(parse("x --f 2").one_or_all("f", &ALL).unwrap(), vec![Flag(2)]);
        assert!(parse("x --f nope").one_or_all("f", &ALL).is_err());
    }

    #[test]
    fn validation_catches_unknown_and_misused() {
        let spec = Spec {
            flags: vec![
                ("fps", true, "desired rate"),
                ("json", false, "machine output"),
            ],
        };
        assert!(parse("x --fps 1").validate(&spec).is_ok());
        assert!(parse("x --nope 1").validate(&spec).is_err());
        assert!(parse("x --json 1").validate(&spec).is_err()); // value to switch
        assert!(parse("x --fps").validate(&spec).is_err()); // switch use of option
        assert!(spec.usage("x", "test").contains("--fps <value>"));
    }
}
