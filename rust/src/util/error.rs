//! Minimal error handling (`anyhow` substitute).
//!
//! Provides the small surface the crate actually uses: an opaque
//! [`Error`] holding a message chain, the [`Result`] alias, the
//! [`anyhow!`](crate::anyhow) and [`ensure!`](crate::ensure) macros,
//! and a [`Context`] extension trait for `Result`/`Option`.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does *not* implement
//! `std::error::Error` so that a blanket `From<E: std::error::Error>`
//! conversion can exist alongside the reflexive `From<Error>`.

use std::fmt;

/// Opaque application error: a root message plus context layers.
pub struct Error {
    /// Outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    /// `{}` shows the outermost message; `{:#}` shows the whole chain.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n  caused by: {cause}")?;
        }
        Ok(())
    }
}

/// Format-and-return-an-[`Error`] macro (`anyhow!` substitute).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

pub use crate::{anyhow, ensure};

/// Attach context to errors (`anyhow::Context` substitute).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_error() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn macro_formats_message() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
    }

    #[test]
    fn context_layers_chain() {
        let r: Result<()> = Err(io_error().into());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
        assert!(format!("{e:?}").contains("caused by: no such file"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(1).context("present").unwrap(), 1);
    }

    #[test]
    fn ensure_returns_error() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(30).unwrap_err().to_string(), "x too big: 30");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }
}
