//! Profile persistence: "the test runs are conducted once and the
//! estimations ... can be used for future executions" (§3.1.1).

use super::ResourceProfile;
use crate::types::{FrameSize, Program};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// JSON-backed store of resource profiles keyed by (program, frame size).
#[derive(Clone, Default, Debug)]
pub struct ProfileStore {
    profiles: BTreeMap<String, ResourceProfile>,
}

fn key(program: Program, size: FrameSize) -> String {
    program.variant(size)
}

impl ResourceProfile {
    /// Serialize to a JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("program".to_string(), Json::Str(self.program.name().to_string())),
            ("frame_h".to_string(), Json::Num(self.frame_size.h as f64)),
            ("frame_w".to_string(), Json::Num(self.frame_size.w as f64)),
            ("cpu_work_cpu_mode".to_string(), Json::Num(self.cpu_work_cpu_mode)),
            ("cpu_work_gpu_mode".to_string(), Json::Num(self.cpu_work_gpu_mode)),
            ("gpu_work".to_string(), Json::Num(self.gpu_work)),
            ("mem_gb_cpu_mode".to_string(), Json::Num(self.mem_gb_cpu_mode)),
            ("mem_gb_gpu_mode".to_string(), Json::Num(self.mem_gb_gpu_mode)),
            ("gpu_mem_gb".to_string(), Json::Num(self.gpu_mem_gb)),
            ("max_fps_cpu".to_string(), Json::Num(self.max_fps_cpu)),
            ("max_fps_gpu".to_string(), Json::Num(self.max_fps_gpu)),
            (
                "measured_cpu_latency".to_string(),
                Json::Num(self.measured_cpu_latency),
            ),
        ])
    }

    /// Parse from a JSON object.
    pub fn from_json(v: &Json) -> crate::util::error::Result<ResourceProfile> {
        Ok(ResourceProfile {
            program: v.str_field("program")?.parse().map_err(crate::util::error::Error::msg)?,
            frame_size: FrameSize::new(
                v.u64_field("frame_h")? as u32,
                v.u64_field("frame_w")? as u32,
            ),
            cpu_work_cpu_mode: v.f64_field("cpu_work_cpu_mode")?,
            cpu_work_gpu_mode: v.f64_field("cpu_work_gpu_mode")?,
            gpu_work: v.f64_field("gpu_work")?,
            mem_gb_cpu_mode: v.f64_field("mem_gb_cpu_mode")?,
            mem_gb_gpu_mode: v.f64_field("mem_gb_gpu_mode")?,
            gpu_mem_gb: v.f64_field("gpu_mem_gb")?,
            max_fps_cpu: v.f64_field("max_fps_cpu")?,
            max_fps_gpu: v.f64_field("max_fps_gpu")?,
            measured_cpu_latency: v.f64_field("measured_cpu_latency")?,
        })
    }
}

impl ProfileStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, profile: ResourceProfile) {
        self.profiles
            .insert(key(profile.program, profile.frame_size), profile);
    }

    pub fn get(&self, program: Program, size: FrameSize) -> Option<&ResourceProfile> {
        self.profiles.get(&key(program, size))
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ResourceProfile> {
        self.profiles.values()
    }

    pub fn save(&self, path: &Path) -> crate::util::error::Result<()> {
        let obj = Json::obj(
            self.profiles
                .iter()
                .map(|(k, p)| (k.clone(), p.to_json())),
        );
        std::fs::write(path, obj.to_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> crate::util::error::Result<ProfileStore> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text)?;
        let map = v
            .as_obj()
            .ok_or_else(|| crate::anyhow!("profile store root must be an object"))?;
        let mut store = ProfileStore::new();
        for profile in map.values() {
            store.insert(ResourceProfile::from_json(profile)?);
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::calibration::Calibration;
    use crate::types::VGA;

    fn temp_path(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!(
            "camcloud-test-{}-{}-{name}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        p
    }

    #[test]
    fn insert_get_roundtrip() {
        let mut store = ProfileStore::new();
        assert!(store.is_empty());
        let p = Calibration::paper().profile(Program::Vgg16, VGA);
        store.insert(p.clone());
        assert_eq!(store.len(), 1);
        assert_eq!(store.get(Program::Vgg16, VGA), Some(&p));
        assert!(store.get(Program::Zf, VGA).is_none());
    }

    #[test]
    fn insert_overwrites_same_key() {
        let mut store = ProfileStore::new();
        let mut p = Calibration::paper().profile(Program::Zf, VGA);
        store.insert(p.clone());
        p.max_fps_cpu = 99.0;
        store.insert(p.clone());
        assert_eq!(store.len(), 1);
        assert_eq!(store.get(Program::Zf, VGA).unwrap().max_fps_cpu, 99.0);
    }

    #[test]
    fn json_roundtrip_preserves_profile() {
        let p = Calibration::paper().profile(Program::Vgg16, VGA);
        let back = ResourceProfile::from_json(&Json::parse(&p.to_json().to_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn save_load_roundtrip() {
        let path = temp_path("profiles.json");
        let mut store = ProfileStore::new();
        let cal = Calibration::paper();
        store.insert(cal.profile(Program::Vgg16, VGA));
        store.insert(cal.profile(Program::Zf, VGA));
        store.save(&path).unwrap();
        let loaded = ProfileStore::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.len(), 2);
        assert_eq!(
            loaded.get(Program::Vgg16, VGA),
            store.get(Program::Vgg16, VGA)
        );
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(ProfileStore::load(Path::new("/nonexistent/p.json")).is_err());
    }
}
